"""Graceful degradation when ``hypothesis`` is not installed.

The property-based tests import ``given``/``settings``/``st`` from here
via a try/except fallback.  Each stubbed ``@given`` test becomes a
zero-argument test that calls ``pytest.importorskip("hypothesis")`` at
run time — so ONLY the property tests skip, and every plain test in the
same module keeps running.  (A module-level importorskip would silently
drop whole files of non-property coverage.)
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def hypothesis_missing():
            pytest.importorskip(
                "hypothesis",
                reason="property test needs hypothesis "
                       "(pip install -e .[dev])")
        hypothesis_missing.__name__ = fn.__name__
        hypothesis_missing.__doc__ = fn.__doc__
        return hypothesis_missing
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    """Accepts any ``st.<strategy>(...)`` call and returns None; the
    values are only ever passed to the stubbed ``given`` above."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
