"""Sharding rules + a reduced-mesh dry-run executed in a subprocess (so the
512-device XLA flag never leaks into this test process)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as shd
from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro.sharding import param_pspecs


def test_param_pspec_rules():
    cfg = get_smoke_config("mixtral-8x22b")
    params = jax.eval_shape(
        lambda k: tf.init_params(k, cfg), jax.random.PRNGKey(0))
    specs = param_pspecs(params)
    flat = {jax.tree_util.keystr(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(specs)[0]}
    emb = [v for k, v in flat.items() if "embed" in k and "table" in k][0]
    # vocab rows sharded over model (padded_vocab guarantees divisibility)
    assert emb == P("model", None)
    wq = [v for k, v in flat.items() if "attn" in k and "wq" in k][0]
    assert wq == P(None, None, "model")        # stacked: leading periods dim
    w_in = [v for k, v in flat.items() if "moe" in k and "'w_in'" in k][0]
    assert w_in == P(None, None, None, "model")  # tensor mode: ff sharded
    router = [v for k, v in flat.items() if "router" in k][0]
    assert all(a is None for a in router)


def test_param_pspec_expert_mode():
    cfg = get_smoke_config("dbrx-132b")
    params = jax.eval_shape(
        lambda k: tf.init_params(k, cfg), jax.random.PRNGKey(0))
    specs = param_pspecs(params, moe_mode="expert")
    flat = {jax.tree_util.keystr(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(specs)[0]}
    w_in = [v for k, v in flat.items() if "moe" in k and "'w_in'" in k][0]
    assert w_in == P(None, "model", None, None)  # expert dim sharded


def test_constrain_is_noop_without_mesh():
    shd.set_mesh(None)
    x = jax.numpy.ones((4, 4))
    y = shd.constrain(x, ("data", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constrain_skips_indivisible_dims():
    devs = np.array(jax.devices()).reshape(1, -1)
    mesh = Mesh(devs, ("data", "model"))
    shd.set_mesh(mesh)
    try:
        x = jax.numpy.ones((3, 4))       # 3 not divisible by any axis > 1
        y = jax.jit(lambda a: shd.constrain(a, ("model", None)))(x)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    finally:
        shd.set_mesh(None)


DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import jax, functools, json
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro import sharding as shd
from repro.sharding import param_pspecs

cfg = get_smoke_config({arch!r})
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
shd.set_mesh(mesh)
params = jax.eval_shape(functools.partial(tf.init_params, cfg=cfg),
                        jax.random.PRNGKey(0))
pspecs = param_pspecs(params)
ns = shd.tree_named_shardings(mesh, pspecs)
batch = {{
    "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
    "targets": jax.ShapeDtypeStruct((8, 64), jnp.int32),
}}
if cfg.encoder is not None:
    batch["frames"] = jax.ShapeDtypeStruct((8, 64, cfg.d_model),
                                           cfg.jnp_dtype)
if cfg.vision_stub:
    batch["image_embeds"] = jax.ShapeDtypeStruct(
        (8, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype)
bns = jax.tree.map(lambda l: NamedSharding(
    mesh, P(("pod", "data")) if l.shape[0] == 8 else P()), batch)

def step(params, batch):
    loss, m = tf.train_loss(params, batch, cfg, remat=False)
    return loss

with mesh:
    compiled = jax.jit(step, in_shardings=(ns, bns)).lower(
        params, batch).compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):
    cost = cost[0]
print(json.dumps({{"flops": float(cost.get("flops", 0.0))}}))
"""


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x22b",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-medium"])
def test_reduced_mesh_multipod_lowering(arch):
    """(pod, data, model) = (2, 2, 2) mesh lower+compile in a subprocess."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = DRYRUN_SNIPPET.format(src=src, arch=arch)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
