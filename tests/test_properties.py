"""Property tests (hypothesis) for the serving invariants, with concrete
smoke variants that run even when hypothesis is absent:

* beta re-exploration floor: monotone non-increasing schedule, never
  below the floor, O(sqrt T) extra exploration (the no-regret bound);
* pre-split tick RNG: no draw collisions across (tick, level, draw)
  purposes — the discipline every parity contract rests on;
* queue-drain invariants: under randomized worker latencies, every
  annotation commits exactly once, within the D-tick bound, in
  deterministic (submit-tick, lane) order, and the engine trajectory is
  bitwise latency-invariant.

Each property's body lives in a ``_check_*`` helper so the concrete
smoke tests exercise the same logic with pinned inputs (the property
tests skip gracefully via tests/_hypothesis_stubs.py when hypothesis is
not installed)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade gracefully: only property tests skip
    from _hypothesis_stubs import given, settings, st

from harness import batched_engine, state_leaves
from repro.core import CascadeConfig, LevelSpec
from repro.core.batched import lanes_due
from repro.core.deferral import reexploration_floor
from repro.core.rng import tick_rngs
from repro.data import make_stream


# ---------------------------------------------------------------------------
# beta re-exploration floor
# ---------------------------------------------------------------------------
def _check_beta_floor(beta0, decay, floor0, T):
    """The engine's beta recurrence: monotone non-increasing, floored at
    floor0/sqrt(t), and the floor's cumulative exploration cost is
    O(sqrt T) (<= 2 * floor0 * sqrt(T)) — Theorem 3.2's no-regret bound
    survives the re-exploration trickle."""
    beta = beta0
    betas = []
    for t in range(1, T + 1):
        floor = reexploration_floor(floor0, t)
        assert floor == floor0 / np.sqrt(max(t, 1))
        new = max(beta * decay, floor)
        assert new <= beta + 1e-15          # monotone non-increasing
        assert new >= floor                 # never below the floor
        assert new <= beta0                 # never above the start
        beta = new
        betas.append(beta)
    # no-regret: the floor adds at most sum floor0/sqrt(t) <= 2F sqrt(T)
    floor_mass = sum(reexploration_floor(floor0, t)
                     for t in range(1, T + 1))
    assert floor_mass <= 2.0 * floor0 * np.sqrt(T) + 1e-12
    # vanishing average exploration => no-regret preserved
    if T >= 4:
        assert floor_mass / T <= 2.0 * floor0 / np.sqrt(T) + 1e-12
    # floor0 = 0 disables the trickle exactly
    if floor0 == 0:
        np.testing.assert_allclose(
            betas, [beta0 * decay ** t for t in range(1, T + 1)])


@given(beta0=st.floats(0.1, 1.0), decay=st.floats(0.5, 0.999),
       floor0=st.floats(0.0, 0.2), T=st.integers(1, 400))
@settings(max_examples=50, deadline=None)
def test_beta_floor_monotone_no_regret(beta0, decay, floor0, T):
    _check_beta_floor(beta0, decay, floor0, T)


def test_beta_floor_concrete():
    """Pinned cases of the property (run even without hypothesis)."""
    _check_beta_floor(1.0, 0.97, 0.05, 300)
    _check_beta_floor(1.0, 0.95, 0.0, 100)
    _check_beta_floor(0.5, 0.999, 0.2, 50)


# ---------------------------------------------------------------------------
# pre-split tick RNG non-collision
# ---------------------------------------------------------------------------
def _check_rng_no_collision(seed, n_streams, n_ticks, n_levels):
    """Across every (lane, tick, level, purpose) the pre-split
    generators yield distinct draw sequences: no jump/action/cache
    stream ever collides with another (float64 uniforms — collision of
    honest independent streams has probability ~0, so equality means a
    key-derivation bug)."""
    seen = {}
    for s in range(n_streams):
        for t in range(1, n_ticks + 1):
            r = tick_rngs(seed, s, t, n_levels)
            draws = {"jump": tuple(r.jump.random(n_levels)),
                     "action": tuple(r.action.random(n_levels))}
            for lev in range(n_levels):
                draws[f"cache{lev}"] = tuple(r.cache[lev].random(3))
            for purpose, v in draws.items():
                assert v not in seen, (
                    f"draw collision: ({s},{t},{purpose}) vs "
                    f"{seen[v]}")
                seen[v] = (s, t, purpose)


@given(seed=st.integers(0, 2**31 - 1), n_streams=st.integers(1, 4),
       n_ticks=st.integers(1, 8), n_levels=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_tick_rng_no_collision(seed, n_streams, n_ticks, n_levels):
    _check_rng_no_collision(seed, n_streams, n_ticks, n_levels)


def test_tick_rng_no_collision_concrete():
    _check_rng_no_collision(0, 4, 16, 2)
    _check_rng_no_collision(12345, 2, 8, 3)


# ---------------------------------------------------------------------------
# commit schedule (pure function)
# ---------------------------------------------------------------------------
def _check_lanes_due(k, D, per_lane):
    """lanes_due is a monotone cumulative schedule: 0 at age 0 (D >= 1),
    everything at age >= D, never decreasing, never out of [0, k]."""
    prev = 0
    for age in range(0, D + 3):
        cur = lanes_due(k, age, D, per_lane)
        assert 0 <= cur <= k
        assert cur >= prev
        prev = cur
    if D >= 1:
        assert lanes_due(k, 0, D, per_lane) == 0
    assert lanes_due(k, D, D, per_lane) == k
    if not per_lane:
        for age in range(0, D):
            assert lanes_due(k, age, D, False) == 0


@given(k=st.integers(0, 64), D=st.integers(0, 6), per_lane=st.booleans())
@settings(max_examples=100, deadline=None)
def test_lanes_due_properties(k, D, per_lane):
    _check_lanes_due(k, D, per_lane)


def test_lanes_due_concrete():
    for k in (0, 1, 5, 8, 33):
        for D in (0, 1, 2, 4):
            _check_lanes_due(k, D, True)
            _check_lanes_due(k, D, False)


# ---------------------------------------------------------------------------
# queue-drain invariants under randomized worker latencies
# ---------------------------------------------------------------------------
_DRAIN_CACHE = {}


def _drain_reference(D):
    """Zero-latency single-worker reference run (cached per delay)."""
    if D not in _DRAIN_CACHE:
        stream = make_stream("imdb", seed=0, n_samples=64)
        levels = (LevelSpec(kind="lr", cost=1.0, cache_size=8,
                            batch_size=8, student_lr=0.5, beta_decay=0.9,
                            calibration_factor=0.4),)
        cfg = CascadeConfig(levels=levels, n_classes=2, expert_cost=1.0e6,
                            mu=3e-7, n_features=256, seed=0)
        eng = batched_engine(cfg, stream, n_streams=4, max_delay=D,
                             per_lane=True)
        m = eng.run(stream)
        _DRAIN_CACHE[D] = (stream, cfg, eng, m)
    return _DRAIN_CACHE[D]


def _check_queue_drain(D, workers, lat_a, lat_b):
    """Run the per-lane engine under a pseudo-random worker-latency
    schedule and assert: every annotation commits exactly once within D
    ticks in sorted (tick, lane) order, and predictions/params/commit
    schedule are bitwise identical to the zero-latency reference."""
    stream, cfg, ref, m_ref = _drain_reference(D)
    eng = batched_engine(
        cfg, stream, n_streams=4, max_delay=D, per_lane=True,
        expert_kw={"workers": workers,
                   "latency": lambda seq, j: (seq * lat_a + j * lat_b) % 7})
    m = eng.run(stream)
    log = eng.commit_log
    called = np.concatenate(list(eng.history["expert_called"]))
    assert len(log) == int(called.sum())             # exactly once
    keys = [(t, s) for t, s, _c in log]
    assert len(set(keys)) == len(keys)
    assert keys == sorted(keys)                      # deterministic order
    assert all(0 <= c - t <= D for t, _s, c in log)  # the <= D bound
    # latency moves wall-clock only: trajectory is bitwise identical
    np.testing.assert_array_equal(m_ref["predictions"], m["predictions"])
    assert log == ref.commit_log
    for a, b in zip(state_leaves(ref.levels), state_leaves(eng.levels)):
        np.testing.assert_array_equal(a, b)


@given(D=st.integers(0, 3), workers=st.integers(1, 4),
       lat_a=st.integers(0, 997), lat_b=st.integers(0, 97))
@settings(max_examples=10, deadline=None)
def test_queue_drain_invariants(D, workers, lat_a, lat_b):
    _check_queue_drain(D, workers, lat_a, lat_b)


def test_queue_drain_invariants_concrete():
    _check_queue_drain(2, 2, 13, 5)
    _check_queue_drain(0, 3, 2, 1)
    _check_queue_drain(1, 4, 101, 0)
