"""cascade-lint suite: each checker catches its bug class, the good twin
stays clean, the suppression/baseline machinery works, and THE TREE IS
CLEAN under --strict.

The regression fixtures at the bottom are the acceptance contract: the
PR-1 salted-``hash()`` seeding bug and an unguarded ``ExpertTicket``
access are re-introduced into the *real* module sources and must be
caught — that is what the CI `analysis` job guards.
"""
from __future__ import annotations

import ast
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (
    ALL_RULES, fingerprint, load_baseline, render_baseline, run_analysis)
from repro.analysis.cli import _render_github, find_repo_root, main
from repro.analysis.engine import Finding, ModuleContext
from repro.analysis.rules import (
    DeterminismRule, DocsContractRule, JitPurityRule, KernelContractRule,
    LockDisciplineRule, RngDisciplineRule, RngFlowRule,
    ShardingContractRule)

REPO_ROOT = Path(__file__).resolve().parents[1]


def ctx_for(src: str, rel: str = "src/repro/core/sample.py",
            root: Path = REPO_ROOT) -> ModuleContext:
    src = textwrap.dedent(src)
    return ModuleContext(root=root, path=root / rel, rel=rel, source=src,
                         lines=src.splitlines(), tree=ast.parse(src))


def run_rule(rule, src: str, rel: str = "src/repro/core/sample.py"):
    return list(rule.check_module(ctx_for(src, rel)))


# ---------------------------------------------------------------------------
# CAS001 — RNG discipline
# ---------------------------------------------------------------------------
class TestRngDiscipline:
    def test_per_tick_construction_in_core_flagged(self):
        bad = """
            import numpy as np
            class Engine:
                def process_tick(self, t):
                    rng = np.random.default_rng(self.seed * 1000 + t)
                    return rng.uniform()
        """
        fs = run_rule(RngDisciplineRule(), bad, "src/repro/core/batched.py")
        assert len(fs) == 1 and fs[0].rule == "CAS001"
        assert "tick_rngs" in fs[0].message

    def test_tick_rngs_usage_is_clean(self):
        good = """
            from repro.core.rng import sample_cache_indices, tick_rngs
            class Engine:
                def process_tick(self, t):
                    rngs = tick_rngs(self.seed, 0, t, n_levels=2)
                    return sample_cache_indices(rngs.cache[0], 8, 4)
        """
        assert run_rule(RngDisciplineRule(), good,
                        "src/repro/core/batched.py") == []

    def test_init_and_training_contexts_exempt(self):
        good = """
            import jax
            import numpy as np
            class Engine:
                def __init__(self, config):
                    self.key = jax.random.PRNGKey(config.seed)
            def train_expert(seed):
                return np.random.default_rng(seed)
        """
        assert run_rule(RngDisciplineRule(), good,
                        "src/repro/core/batched.py") == []

    def test_unseeded_construction_flagged_everywhere(self):
        bad = """
            from numpy.random import default_rng
            def demo():
                return default_rng().integers(0, 10)
        """
        fs = run_rule(RngDisciplineRule(), bad, "examples/demo.py")
        assert len(fs) == 1 and "unseeded" in fs[0].message

    def test_seeded_construction_outside_core_clean(self):
        good = """
            import numpy as np
            def bench(seed=0):
                return np.random.default_rng(seed).normal(size=4)
        """
        assert run_rule(RngDisciplineRule(), good, "benchmarks/b.py") == []

    def test_whitelisted_core_module_clean(self):
        src = """
            import numpy as np
            def tick_rngs(seed, s, t):
                return np.random.default_rng(
                    np.random.SeedSequence((seed, s, t)))
        """
        assert run_rule(RngDisciplineRule(), src,
                        "src/repro/core/rng.py") == []


# ---------------------------------------------------------------------------
# CAS002 — determinism hazards
# ---------------------------------------------------------------------------
class TestDeterminism:
    def test_pr1_salted_hash_seeding_bug_regression(self):
        # the exact bug class PR 1 fixed in make_stream/expert_labels:
        # builtin hash() of an f-string fed a generator seed, so corpora
        # changed between processes (PYTHONHASHSEED salting)
        bad = """
            import numpy as np
            def make_stream(name, seed):
                rng = np.random.default_rng(
                    abs(hash(f"{seed}:{name}")) % (2 ** 31))
                return rng.permutation(100)
        """
        fs = run_rule(DeterminismRule(), bad, "src/repro/data/streams.py")
        assert len(fs) == 1 and fs[0].rule == "CAS002"
        assert "salted" in fs[0].message and "crc32" in fs[0].message

    def test_crc32_twin_is_clean(self):
        good = """
            import zlib
            import numpy as np
            def make_stream(name, seed):
                rng = np.random.default_rng(
                    zlib.crc32(f"{seed}:{name}".encode()))
                return rng.permutation(100)
        """
        assert run_rule(DeterminismRule(), good,
                        "src/repro/data/streams.py") == []

    def test_wall_clock_seed_flagged(self):
        fs = run_rule(DeterminismRule(), """
            import time
            import numpy as np
            rng = np.random.default_rng(int(time.time()))
        """, "benchmarks/b.py")
        assert len(fs) == 1 and "time.time" in fs[0].message

    def test_seed_variable_from_urandom_flagged(self):
        fs = run_rule(DeterminismRule(), """
            import os
            seed = int.from_bytes(os.urandom(4), "little")
        """, "benchmarks/b.py")
        assert len(fs) == 1 and "os.urandom" in fs[0].message

    def test_timing_measurement_is_clean(self):
        good = """
            import time
            def bench(fn):
                t0 = time.time()
                fn()
                return time.time() - t0
        """
        assert run_rule(DeterminismRule(), good, "benchmarks/b.py") == []

    def test_legacy_global_sampler_flagged(self):
        fs = run_rule(DeterminismRule(), """
            import numpy as np
            noise = np.random.randn(8)
        """, "examples/e.py")
        assert len(fs) == 1 and "global-state" in fs[0].message

    def test_id_sort_key_flagged(self):
        fs = run_rule(DeterminismRule(), """
            def order(objs):
                return sorted(objs, key=id)
        """)
        assert len(fs) == 1 and "id()" in fs[0].message

    def test_set_iteration_flagged_sorted_clean(self):
        fs = run_rule(DeterminismRule(), """
            for name in {"imdb", "hatespeech"}:
                print(name)
        """, "benchmarks/b.py")
        assert len(fs) == 1 and "set" in fs[0].message
        good = """
            for name in sorted({"imdb", "hatespeech"}):
                print(name)
        """
        assert run_rule(DeterminismRule(), good, "benchmarks/b.py") == []


# ---------------------------------------------------------------------------
# CAS003 — jit purity
# ---------------------------------------------------------------------------
class TestJitPurity:
    def test_self_mutation_in_jitted_method_flagged(self):
        fs = run_rule(JitPurityRule(), """
            import jax
            class Engine:
                @jax.jit
                def step(self, x):
                    self.calls += 1
                    return x * 2
        """)
        assert any("mutates self.calls" in f.message for f in fs)

    def test_item_and_tracer_cast_flagged(self):
        fs = run_rule(JitPurityRule(), """
            import jax
            def loss(params, batch):
                return (params * batch).sum()
            step = jax.jit(loss)
            @jax.jit
            def bad(x):
                return float(x) + x.sum().item()
        """)
        msgs = " | ".join(f.message for f in fs)
        assert ".item()" in msgs and "float()" in msgs

    def test_static_args_exempt_from_cast_check(self):
        good = """
            import functools
            import jax
            @functools.partial(jax.jit, static_argnames=("block",))
            def op(x, *, block):
                return x.reshape(int(block), -1)
        """
        assert run_rule(JitPurityRule(), good) == []

    def test_pure_jitted_fn_clean(self):
        good = """
            import jax
            import jax.numpy as jnp
            @jax.jit
            def step(params, grads):
                return jax.tree_util.tree_map(
                    lambda p, g: p - 0.1 * g, params, grads)
        """
        assert run_rule(JitPurityRule(), good) == []

    def test_donated_buffer_read_after_call_flagged(self):
        fs = run_rule(JitPurityRule(), """
            import jax
            def scatter(buf, x):
                return buf.at[0].set(x)
            step = jax.jit(scatter, donate_argnums=(0,))
            def run(buf, x):
                out = step(buf, x)
                return buf.sum() + out.sum()
        """)
        assert len(fs) == 1 and "donated" in fs[0].message

    def test_donated_buffer_reassigned_clean(self):
        good = """
            import jax
            def scatter(buf, x):
                return buf.at[0].set(x)
            step = jax.jit(scatter, donate_argnums=(0,))
            def run(buf, x):
                buf = step(buf, x)
                return buf.sum()
        """
        assert run_rule(JitPurityRule(), good) == []

    def test_repo_jit_factory_convention_staged(self):
        fs = run_rule(JitPurityRule(), """
            from repro.sharding.specs import jit_route_pass
            class Level:
                def make(self):
                    def route(self, feats):
                        self.count += 1
                        return feats
                    return jit_route_pass(route, None)
        """)
        assert any("mutates self.count" in f.message for f in fs)


# ---------------------------------------------------------------------------
# CAS004 — lock discipline
# ---------------------------------------------------------------------------
_TICKET_TEMPLATE = """
    import threading
    class Ticket:
        def __init__(self):
            self._lock = threading.RLock()
            self._shards = []   # guarded-by: _lock
        def done(self):
            {done_body}
        def add(self, s):
            with self._lock:
                self._shards.append(s)
"""


class TestLockDiscipline:
    def test_unguarded_read_flagged(self):
        bad = textwrap.dedent(_TICKET_TEMPLATE).format(
            done_body="return all(s.done() for s in self._shards)")
        fs = run_rule(LockDisciplineRule(), bad)
        assert len(fs) == 1 and fs[0].rule == "CAS004"
        assert "_shards" in fs[0].message and "_lock" in fs[0].message

    def test_guarded_access_clean(self):
        good = textwrap.dedent(_TICKET_TEMPLATE).format(
            done_body="""with self._lock:
                return all(s.done() for s in self._shards)""")
        assert run_rule(LockDisciplineRule(), good) == []

    def test_constructor_family_exempt(self):
        src = """
            import threading
            class T:
                def __init__(self):
                    self._lock = threading.RLock()
                    self._q = []   # guarded-by: _lock
                    self._q.append(0)
                def __del__(self):
                    self._q.clear()
        """
        assert run_rule(LockDisciplineRule(), src) == []

    def test_unannotated_class_ignored(self):
        src = """
            class Plain:
                def __init__(self):
                    self._shards = []
                def peek(self):
                    return self._shards
        """
        assert run_rule(LockDisciplineRule(), src) == []

    def test_real_experts_module_conforms(self):
        src = (REPO_ROOT / "src/repro/core/experts.py").read_text()
        fs = run_rule(LockDisciplineRule(), src, "src/repro/core/experts.py")
        assert fs == []

    def test_regression_unguarding_real_ticket_is_caught(self):
        # strip ONE lock enclosure from the real ExpertTicket — the
        # acceptance fixture: this is exactly the edit the CI job must
        # refuse
        src = (REPO_ROOT / "src/repro/core/experts.py").read_text()
        broken = src.replace(
            """        with self._lock:
            return all([self._shard_done(s) for s in self._shards])""",
            """        return all([self._shard_done(s) for s in self._shards])""")
        assert broken != src, "ExpertTicket.done() body changed upstream"
        fs = run_rule(LockDisciplineRule(), broken,
                      "src/repro/core/experts.py")
        assert any(f.rule == "CAS004" and "_shards" in f.message
                   for f in fs)


# ---------------------------------------------------------------------------
# CAS005 — kernel/level contract (fixture tree)
# ---------------------------------------------------------------------------
def _write_kernel_pkg(root: Path, ops_src: str, ref_src: str,
                      init_src: str, kernel_src: str = None):
    pkg = root / "src/repro/kernels/toyop"
    pkg.mkdir(parents=True)
    (pkg / "kernel.py").write_text(kernel_src or textwrap.dedent("""
        def toyop_tiled(x, w):
            return x @ w
    """))
    (pkg / "ops.py").write_text(textwrap.dedent(ops_src))
    (pkg / "ref.py").write_text(textwrap.dedent(ref_src))
    (pkg / "__init__.py").write_text(textwrap.dedent(init_src))
    return pkg


class TestKernelContract:
    GOOD_OPS = """
        from repro.kernels.toyop.kernel import toyop_tiled
        def toyop(x, w, *, interpret=None):
            return toyop_tiled(x, w)
    """
    GOOD_REF = """
        def toyop_ref(x, w):
            return x @ w
    """
    GOOD_INIT = """
        from repro.kernels.toyop.ops import toyop
        __all__ = ["toyop"]
    """

    def _findings(self, tmp_path):
        res = run_analysis(tmp_path, paths=["src"],
                           rules=[KernelContractRule()])
        return res.findings

    def test_conforming_package_clean(self, tmp_path):
        _write_kernel_pkg(tmp_path, self.GOOD_OPS, self.GOOD_REF,
                          self.GOOD_INIT)
        assert self._findings(tmp_path) == []

    def test_missing_ref_twin_flagged(self, tmp_path):
        _write_kernel_pkg(tmp_path, self.GOOD_OPS, """
            def toyop_ref(x, w, scale):
                return x @ w * scale
        """, self.GOOD_INIT)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "ref.py twin" in fs[0].message

    def test_missing_all_export_flagged(self, tmp_path):
        _write_kernel_pkg(tmp_path, self.GOOD_OPS, self.GOOD_REF, """
            from repro.kernels.toyop.ops import toyop
            __all__ = []
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "__all__" in fs[0].message

    def test_unconsumed_kernel_entry_flagged(self, tmp_path):
        _write_kernel_pkg(tmp_path, """
            def toyop(x, w, *, interpret=None):
                return x @ w
        """, self.GOOD_REF, self.GOOD_INIT)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "not consumed by ops.py" in fs[0].message

    def test_level_kind_without_flop_model_flagged(self, tmp_path):
        (tmp_path / "src/repro/metrics").mkdir(parents=True)
        (tmp_path / "src/repro/metrics/costs.py").write_text(
            "def lr_flops(spec):\n    return 1.0\n")
        (tmp_path / "src/repro/core").mkdir(parents=True)
        (tmp_path / "src/repro/core/cascade.py").write_text(textwrap.dedent(
            """
            def config(LevelSpec):
                return [LevelSpec(kind="lr", cost=1.0),
                        LevelSpec(kind="quantum", cost=9.9)]
            """))
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "'quantum'" in fs[0].message

    def test_real_tree_conforms(self):
        res = run_analysis(REPO_ROOT, paths=["src"],
                           rules=[KernelContractRule()])
        assert res.findings == []


# ---------------------------------------------------------------------------
# CAS006 — docs contract (fixture tree)
# ---------------------------------------------------------------------------
class TestDocsContract:
    def _tree(self, tmp_path, readme: str):
        (tmp_path / "benchmarks").mkdir()
        (tmp_path / "benchmarks/speed.py").write_text("x = 1\n")
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/demo.py").write_text("x = 1\n")
        for doc in ("ARCHITECTURE.md", "MODELS.md", "ANALYSIS.md"):
            (tmp_path / "docs").mkdir(exist_ok=True)
            (tmp_path / f"docs/{doc}").write_text("stub\n")
        (tmp_path / "README.md").write_text(textwrap.dedent(readme))

    FULL_README = """
        All of benchmarks/speed.py and examples/demo.py, documented in
        docs/ARCHITECTURE.md, docs/MODELS.md and docs/ANALYSIS.md.
    """

    def _findings(self, tmp_path):
        res = run_analysis(tmp_path, paths=["benchmarks", "examples"],
                           rules=[DocsContractRule()])
        return res.findings

    def test_complete_readme_clean(self, tmp_path):
        self._tree(tmp_path, self.FULL_README)
        assert self._findings(tmp_path) == []

    def test_unmentioned_example_flagged(self, tmp_path):
        self._tree(tmp_path, """
            Only benchmarks/speed.py here, plus docs/ARCHITECTURE.md,
            docs/MODELS.md and docs/ANALYSIS.md.
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "examples/demo.py" in fs[0].message

    def test_token_match_rejects_substring(self, tmp_path):
        # "batched_speed.py" must NOT satisfy the mention of "speed.py"
        self._tree(tmp_path, """
            benchmarks/batched_speed.py and examples/demo.py;
            docs/ARCHITECTURE.md docs/MODELS.md docs/ANALYSIS.md
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "speed.py" in fs[0].message

    def test_missing_doc_flagged(self, tmp_path):
        self._tree(tmp_path, self.FULL_README)
        (tmp_path / "docs/ANALYSIS.md").unlink()
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "docs/ANALYSIS.md is missing" in \
            fs[0].message


# ---------------------------------------------------------------------------
# CAS007 — interprocedural tick-RNG dataflow (fixture tree)
# ---------------------------------------------------------------------------
def _write_core_module(root: Path, src: str, name: str = "engine.py"):
    pkg = root / "src/repro/core"
    pkg.mkdir(parents=True, exist_ok=True)
    (pkg / name).write_text(textwrap.dedent(src))


class TestRngFlow:
    def _findings(self, tmp_path):
        res = run_analysis(tmp_path, paths=["src"], rules=[RngFlowRule()])
        return res.findings

    def test_double_draw_same_purpose_flagged(self, tmp_path):
        _write_core_module(tmp_path, """
            from repro.core.rng import tick_rngs
            class Engine:
                def process_tick(self, t):
                    r = tick_rngs(self.seed, 0, t, n_levels=2)
                    u1 = r.jump.random(2)
                    u2 = r.jump.random(2)
                    return u1 + u2
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and fs[0].rule == "CAS007"
        assert "consumed again" in fs[0].message
        assert "'r.jump'" in fs[0].message

    def test_draw_plus_consuming_callee_flagged(self, tmp_path):
        # interprocedural half of the reuse check: helper() draws from
        # its parameter (the summary pass must discover that), so passing
        # r.jump after drawing from it directly is a second consumption
        _write_core_module(tmp_path, """
            from repro.core.rng import tick_rngs
            def helper(gen):
                return gen.random(4)
            class Engine:
                def process_tick(self, t):
                    r = tick_rngs(self.seed, 0, t, n_levels=2)
                    u = r.jump.random(2)
                    return u + helper(r.jump)
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "passed to helper()" in fs[0].message

    def test_transitive_consumer_chain_resolved(self, tmp_path):
        # helper -> inner -> draw: the summary fixpoint must propagate
        # consumption through TWO call hops before the reuse is visible
        _write_core_module(tmp_path, """
            from repro.core.rng import tick_rngs
            def inner(gen):
                return gen.integers(0, 8)
            def helper(gen):
                return inner(gen)
            class Engine:
                def process_tick(self, t):
                    r = tick_rngs(self.seed, 0, t, n_levels=2)
                    a = helper(r.cache[0])
                    b = helper(r.cache[0])
                    return a + b
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "'r.cache[0]'" in fs[0].message

    def test_escape_to_self_flagged(self, tmp_path):
        _write_core_module(tmp_path, """
            from repro.core.rng import tick_rngs
            class Engine:
                def process_tick(self, t):
                    r = tick_rngs(self.seed, 0, t, n_levels=2)
                    self._rng = r.action
                    return self._rng.random()
        """)
        fs = self._findings(tmp_path)
        assert any("escapes" in f.message and "self._rng" in f.message
                   for f in fs)

    def test_escape_via_storing_callee_flagged(self, tmp_path):
        # the store is one call away: stash() assigns its parameter to
        # self, so passing a purpose into it caches live generator state
        _write_core_module(tmp_path, """
            from repro.core.rng import tick_rngs
            class Engine:
                def stash(self, gen):
                    self._gen = gen
                def process_tick(self, t):
                    r = tick_rngs(self.seed, 0, t, n_levels=2)
                    self.stash(r.action)
                    return 0
        """)
        fs = self._findings(tmp_path)
        assert any("escapes" in f.message and "stash()" in f.message
                   for f in fs)

    def test_one_consumer_per_purpose_clean(self, tmp_path):
        # the good twin mirrors the real engines: one draw per purpose,
        # record-class transport exempt, unknown consumers count once
        _write_core_module(tmp_path, """
            from repro.core.rng import sample_cache_indices, tick_rngs
            class TickRecord:
                pass
            class Engine:
                def process_tick(self, t):
                    r = tick_rngs(self.seed, 0, t, n_levels=2)
                    u = r.jump.random(2)
                    rec = TickRecord(r.action)
                    for i in range(2):
                        sample_cache_indices(r.cache[i], 8, 4)
                    return u, rec
        """)
        assert self._findings(tmp_path) == []

    def test_real_core_tree_conforms(self):
        res = run_analysis(REPO_ROOT, paths=["src"], rules=[RngFlowRule()])
        assert res.findings == [], "\n".join(
            f.render() for f in res.findings)


# ---------------------------------------------------------------------------
# CAS008 — sharding-spec consistency (fixture tree)
# ---------------------------------------------------------------------------
class TestShardingContract:
    SPECS = """
        import jax
        def lane_spec(mesh):
            return None
        def put_lanes(x, mesh=None):
            return x
        def jit_scatter(fn):
            return jax.jit(fn, donate_argnums=(0,))
    """
    INIT = """
        from repro.sharding.specs import jit_scatter, lane_spec, put_lanes
        __all__ = ["lane_spec", "put_lanes", "jit_scatter"]
    """

    def _tree(self, tmp_path, core_src: str):
        pkg = tmp_path / "src/repro/sharding"
        pkg.mkdir(parents=True)
        (pkg / "specs.py").write_text(textwrap.dedent(self.SPECS))
        (pkg / "__init__.py").write_text(textwrap.dedent(self.INIT))
        _write_core_module(tmp_path, core_src, "batched.py")

    def _findings(self, tmp_path):
        res = run_analysis(tmp_path, paths=["src"],
                           rules=[ShardingContractRule()])
        return res.findings

    def test_conforming_core_clean(self, tmp_path):
        self._tree(tmp_path, """
            from repro.sharding import jit_scatter, put_lanes
            class Engine:
                def __init__(self, fn):
                    self._scatter = jit_scatter(fn)
                    self._cache = put_lanes([0.0])
                def step(self):
                    out = self._scatter(self._cache)
                    self._cache = out
                    return out
        """)
        assert self._findings(tmp_path) == []

    def test_import_of_missing_helper_flagged(self, tmp_path):
        self._tree(tmp_path, """
            from repro.sharding import put_lanes_v2
            x = put_lanes_v2([0.0])
        """)
        fs = self._findings(tmp_path)
        assert any("no such helper" in f.message for f in fs)

    def test_unexported_helper_flagged(self, tmp_path):
        pkg = tmp_path / "src/repro/sharding"
        pkg.mkdir(parents=True)
        (pkg / "specs.py").write_text(textwrap.dedent(self.SPECS))
        (pkg / "__init__.py").write_text(textwrap.dedent("""
            from repro.sharding.specs import lane_spec
            __all__ = ["lane_spec"]
        """))
        _write_core_module(tmp_path, """
            from repro.sharding import put_lanes
            x = put_lanes([0.0])
        """, "batched.py")
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "__all__" in fs[0].message

    def test_bare_device_put_flagged_explicit_clean(self, tmp_path):
        self._tree(tmp_path, """
            import jax
            class Engine:
                def __init__(self, x, sharding):
                    self.a = jax.device_put(x)
                    self.b = jax.device_put(x, sharding)
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "bare jax.device_put" in fs[0].message

    def test_donated_self_attr_without_rebind_flagged(self, tmp_path):
        # the cross-module donation hole CAS003 cannot see: the
        # donate_argnums annotation lives in sharding/specs.py while the
        # stale self._cache read-after-donation sits in core/
        self._tree(tmp_path, """
            from repro.sharding import jit_scatter
            class Engine:
                def __init__(self, fn):
                    self._scatter = jit_scatter(fn)
                def step(self):
                    out = self._scatter(self._cache)
                    return out
        """)
        fs = self._findings(tmp_path)
        assert len(fs) == 1 and "donated position 0" in fs[0].message
        assert "_cache" in fs[0].message

    def test_real_core_tree_conforms(self):
        res = run_analysis(REPO_ROOT, paths=["src"],
                           rules=[ShardingContractRule()])
        assert res.findings == [], "\n".join(
            f.render() for f in res.findings)


# ---------------------------------------------------------------------------
# engine: suppressions, baseline, CLI
# ---------------------------------------------------------------------------
class TestEngine:
    def test_same_line_suppression(self, tmp_path):
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/e.py").write_text(
            "import numpy as np\n"
            "r = np.random.default_rng()"
            "  # cascade-lint: disable=CAS001 demo entropy source\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        assert res.findings == [] and res.suppressed == 1

    def test_next_line_and_file_suppression(self, tmp_path):
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/a.py").write_text(
            "import numpy as np\n"
            "# cascade-lint: disable-next-line=CAS001 demo entropy\n"
            "r = np.random.default_rng()\n")
        (tmp_path / "examples/b.py").write_text(
            "# cascade-lint: disable-file=CAS001 demo entropy\n"
            "import numpy as np\n"
            "r = np.random.default_rng()\n"
            "q = np.random.default_rng()\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        assert res.findings == [] and res.suppressed == 3

    def test_wrong_id_not_suppressed(self, tmp_path):
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/e.py").write_text(
            "import numpy as np\n"
            "r = np.random.default_rng()"
            "  # cascade-lint: disable=CAS002 wrong rule on purpose\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        assert len(res.findings) == 1

    def test_baseline_roundtrip_ignores_line_moves(self, tmp_path):
        (tmp_path / "examples").mkdir()
        src = tmp_path / "examples/e.py"
        src.write_text("import numpy as np\n"
                       "r = np.random.default_rng()\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        bl = tmp_path / "analysis-baseline.txt"
        bl.write_text(render_baseline(res.findings))
        prints = load_baseline(bl)
        assert len(prints) == 1
        # move the finding two lines down: fingerprint must not change
        src.write_text("import numpy as np\n\n\n"
                       "r = np.random.default_rng()\n")
        res2 = run_analysis(tmp_path, paths=["examples"],
                            rules=[RngDisciplineRule()])
        assert {fingerprint(f) for f in res2.findings} == prints

    def test_cli_strict_exit_codes(self, tmp_path, capsys):
        (tmp_path / "pyproject.toml").write_text("[project]\n")
        (tmp_path / "src").mkdir()
        (tmp_path / "src/clean.py").write_text("x = 1\n")
        assert main(["--root", str(tmp_path), "--strict", "src"]) == 0
        (tmp_path / "src/dirty.py").write_text(
            "import numpy as np\nr = np.random.default_rng()\n")
        assert main(["--root", str(tmp_path), "--strict", "src"]) == 1
        capsys.readouterr()

    def test_cli_baseline_gates_old_but_not_new(self, tmp_path, capsys):
        (tmp_path / "pyproject.toml").write_text("[project]\n")
        (tmp_path / "src").mkdir()
        (tmp_path / "src/old.py").write_text(
            "import numpy as np\nr = np.random.default_rng()\n")
        assert main(["--root", str(tmp_path), "--write-baseline",
                     "src"]) == 0
        assert main(["--root", str(tmp_path), "--strict", "src"]) == 0
        (tmp_path / "src/new.py").write_text(
            "import numpy as np\nq = np.random.default_rng()\n")
        assert main(["--root", str(tmp_path), "--strict", "src"]) == 1
        out = capsys.readouterr().out
        assert "[baselined]" in out

    def test_cli_list_rules(self, capsys):
        assert main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for cls in ALL_RULES:
            assert cls.id in out

    def test_find_repo_root(self):
        assert find_repo_root(Path(__file__).parent) == REPO_ROOT

    def test_syntax_error_reported_as_cas000(self, tmp_path):
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/broken.py").write_text("def f(:\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        assert len(res.findings) == 1 and res.findings[0].rule == "CAS000"


# ---------------------------------------------------------------------------
# suppression-justification policy + --format github
# ---------------------------------------------------------------------------
class TestSuppressionPolicy:
    def test_bare_suppression_still_suppresses_but_is_flagged(
            self, tmp_path):
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/e.py").write_text(
            "import numpy as np\n"
            "r = np.random.default_rng()"
            "  # cascade-lint: disable=CAS001\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        # the waiver the author intended stays effective ...
        assert res.suppressed == 1
        # ... but the missing "why" is a CAS000 finding of its own
        assert len(res.findings) == 1
        assert res.findings[0].rule == "CAS000"
        assert "no justification" in res.findings[0].message
        assert res.findings[0].line == 2

    def test_justified_suppression_is_clean(self, tmp_path):
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/e.py").write_text(
            "import numpy as np\n"
            "r = np.random.default_rng()"
            "  # cascade-lint: disable=CAS001 -- demo entropy, not "
            "engine state\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        assert res.findings == [] and res.suppressed == 1

    def test_justification_policy_is_not_waivable(self, tmp_path):
        # a disable-file=CAS000 cannot hide the bare-suppression report:
        # the policy findings are appended after the suppression filter
        (tmp_path / "examples").mkdir()
        (tmp_path / "examples/e.py").write_text(
            "# cascade-lint: disable-file=CAS000 trying to hide\n"
            "import numpy as np\n"
            "r = np.random.default_rng()"
            "  # cascade-lint: disable=CAS001\n")
        res = run_analysis(tmp_path, paths=["examples"],
                           rules=[RngDisciplineRule()])
        assert any(f.rule == "CAS000" and "no justification" in f.message
                   for f in res.findings)


class TestGithubFormat:
    def _dirty_tree(self, tmp_path):
        (tmp_path / "pyproject.toml").write_text("[project]\n")
        (tmp_path / "src").mkdir()
        (tmp_path / "src/dirty.py").write_text(
            "import numpy as np\nr = np.random.default_rng()\n")

    def test_cli_emits_workflow_commands(self, tmp_path, capsys):
        self._dirty_tree(tmp_path)
        main(["--root", str(tmp_path), "--format", "github", "src"])
        out = capsys.readouterr().out
        assert "::error file=src/dirty.py,line=2," in out
        assert "title=CAS001::" in out
        assert "cascade-lint: 1 finding(s)" in out

    def test_baselined_findings_annotate_as_notices(self, tmp_path,
                                                    capsys):
        self._dirty_tree(tmp_path)
        assert main(["--root", str(tmp_path), "--write-baseline",
                     "src"]) == 0
        capsys.readouterr()
        main(["--root", str(tmp_path), "--format", "github", "src"])
        out = capsys.readouterr().out
        assert "::notice file=src/dirty.py" in out
        assert "title=CAS001 [baselined]::" in out

    def test_message_escaping(self):
        f = Finding("CAS999", "a.py", 3, 0, "50% of\nlines")
        line = _render_github(f)
        assert "%25" in line and "%0A" in line
        assert "\n" not in line

    def test_json_alias_still_works(self, tmp_path, capsys):
        self._dirty_tree(tmp_path)
        main(["--root", str(tmp_path), "--json", "src"])
        out = capsys.readouterr().out
        assert out.lstrip().startswith("[") and '"CAS001"' in out


# ---------------------------------------------------------------------------
# the tree itself
# ---------------------------------------------------------------------------
class TestTreeIsClean:
    def test_run_analysis_clean_on_repo(self):
        res = run_analysis(REPO_ROOT)
        baseline = load_baseline(REPO_ROOT / "analysis-baseline.txt")
        fresh = [f for f in res.findings if fingerprint(f) not in baseline]
        assert fresh == [], "\n".join(f.render() for f in fresh)

    def test_committed_baseline_is_empty(self):
        # satellite contract: violations are FIXED, not waived
        assert load_baseline(REPO_ROOT / "analysis-baseline.txt") == set()

    def test_cli_strict_exits_zero(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--strict"],
            cwd=REPO_ROOT, capture_output=True, text=True,
            env={"PYTHONPATH": "src", "PATH": "/usr/local/bin:/usr/bin:/bin"})
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_regression_salted_hash_in_streams_is_caught(self):
        # re-introduce the PR-1 bug into the real module source: seed
        # derived via builtin hash() instead of zlib.crc32
        src = (REPO_ROOT / "src/repro/data/streams.py").read_text()
        broken = src.replace('zlib.crc32(f"{seed}:{name}".encode())',
                             'hash(f"{seed}:{name}")')
        assert broken != src, "streams.py seeding changed upstream"
        fs = run_rule(DeterminismRule(), broken, "src/repro/data/streams.py")
        assert any(f.rule == "CAS002" and "salted" in f.message
                   for f in fs)


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
