"""Per-kernel allclose sweeps vs the pure-jnp oracles (+ hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade gracefully: only property tests skip
    from _hypothesis_stubs import given, settings, st

from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm import moe_expert_ffn, moe_gmm
from repro.kernels.moe_gmm.ref import expert_ffn_ref, gmm_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(key, shape, dtype) * scale


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # (B, Sq, H, K, hd, causal, window, dtype)
    (2, 256, 8, 4, 64, True, None, jnp.float32),
    (1, 512, 4, 4, 128, True, 128, jnp.float32),
    (2, 128, 8, 2, 120, True, None, jnp.float32),   # danube head_dim
    (1, 256, 4, 2, 64, False, None, jnp.float32),   # encoder (non-causal)
    (1, 256, 8, 8, 64, True, 64, jnp.float32),      # MHA + tight window
    (2, 128, 4, 2, 64, True, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_allclose(case):
    B, S, H, K, hd, causal, window, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, K, hd), dtype)
    v = _rand(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bkv=st.sampled_from([32, 64, 128]),
    s=st.sampled_from([128, 256]),
    h=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
)
def test_flash_attention_block_shape_invariance(bq, bkv, s, h, g):
    """Property: output is independent of the VMEM tile decomposition."""
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    K = h
    H = h * g
    q = _rand(ks[0], (1, s, H, 64))
    k = _rand(ks[1], (1, s, K, 64))
    v = _rand(ks[2], (1, s, K, 64))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv)
    ref = flash_attention(q, k, v, causal=True, block_q=s, block_kv=s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
DECODE_CASES = [
    (2, 256, 8, 4, 64, 100),
    (1, 512, 4, 2, 128, 512),
    (2, 128, 8, 8, 120, 64),
    (4, 64, 4, 4, 64, 1),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_allclose(case):
    B, W, H, K, hd, nvalid = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, 1, H, hd))
    k = _rand(ks[1], (B, W, K, hd))
    v = _rand(ks[2], (B, W, K, hd))
    pos = jnp.where(jnp.arange(W) < nvalid, jnp.arange(W), -1)
    out = decode_attention(q, k, v, pos, block_kv=64)
    G = H // K
    ref = decode_attention_ref(
        q[:, 0].reshape(B, K, G, hd), k, v,
        jnp.broadcast_to(pos[None], (B, W))).reshape(B, 1, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_semantics():
    """Ring-buffer: result must only depend on valid slots."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, W, H, K, hd = 1, 64, 4, 4, 64
    q = _rand(ks[0], (B, 1, H, hd))
    k = _rand(ks[1], (B, W, K, hd))
    v = _rand(ks[2], (B, W, K, hd))
    pos = jnp.where(jnp.arange(W) < 10, jnp.arange(W), -1)
    out1 = decode_attention(q, k, v, pos, block_kv=32)
    # scramble the invalid region — output must not change
    k2 = k.at[:, 10:].set(999.0)
    v2 = v.at[:, 10:].set(-999.0)
    out2 = decode_attention(q, k2, v2, pos, block_kv=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# moe grouped matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [
    (4, 64, 256, 512), (8, 32, 128, 128), (2, 128, 512, 256)])
def test_moe_gmm_allclose(shape):
    E, C, D, F = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = _rand(ks[0], (E, C, D))
    w = _rand(ks[1], (E, D, F), scale=0.05)
    out = moe_gmm(x, w, block_c=32, block_f=64, block_d=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gmm_ref(x, w)),
                               atol=1e-3, rtol=1e-3)


def test_moe_expert_ffn_allclose():
    E, C, D, F = 4, 64, 128, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = _rand(ks[0], (E, C, D))
    w_in = _rand(ks[1], (E, D, F), scale=0.05)
    w_g = _rand(ks[2], (E, D, F), scale=0.05)
    w_o = _rand(ks[3], (E, F, D), scale=0.05)
    out = moe_expert_ffn(x, w_in, w_g, w_o)
    ref = expert_ffn_ref(x, w_in, w_g, w_o)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(e=st.sampled_from([2, 4]), c=st.sampled_from([16, 64]),
       d=st.sampled_from([64, 128]), f=st.sampled_from([64, 256]))
def test_moe_gmm_property(e, c, d, f):
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    x = _rand(ks[0], (e, c, d))
    w = _rand(ks[1], (e, d, f), scale=0.1)
    out = moe_gmm(x, w, block_c=16, block_f=64, block_d=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gmm_ref(x, w)),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_ssd_scan_allclose(chunk):
    Bsz, S, H, hp, N = 2, 128, 4, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = _rand(ks[0], (Bsz, S, H, hp))
    dt = jax.nn.softplus(_rand(ks[1], (Bsz, S, H)))
    adt = -0.5 * dt
    B = _rand(ks[2], (Bsz, S, N))
    C = _rand(ks[3], (Bsz, S, N))
    out = ssd_scan(x, adt, dt, B, C, chunk=chunk)
    ref = ssd_scan_ref(x, adt, dt, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_ssd_scan_matches_model_path():
    """Kernel == model-level jnp chunked path == sequential oracle."""
    from repro.models.ssm import ssd_chunked
    Bsz, S, H, hp, N = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = _rand(ks[0], (Bsz, S, H, hp))
    dt = jax.nn.softplus(_rand(ks[1], (Bsz, S, H)))
    adt = -0.3 * dt
    B = _rand(ks[2], (Bsz, S, N))
    C = _rand(ks[3], (Bsz, S, N))
    y_kernel = ssd_scan(x, adt, dt, B, C, chunk=16)
    y_model, _ = ssd_chunked(x, adt, dt, B, C, 16)
    y_ref = ssd_scan_ref(x, adt, dt, B, C)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=6, deadline=None)
@given(s=st.sampled_from([32, 64]), h=st.sampled_from([1, 2]),
       hp=st.sampled_from([16, 32]), n=st.sampled_from([8, 16]),
       decay=st.floats(0.05, 2.0))
def test_ssd_scan_property(s, h, hp, n, decay):
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    x = _rand(ks[0], (1, s, h, hp))
    dt = jax.nn.softplus(_rand(ks[1], (1, s, h)))
    adt = -decay * dt
    B = _rand(ks[2], (1, s, n))
    C = _rand(ks[3], (1, s, n))
    out = ssd_scan(x, adt, dt, B, C, chunk=16)
    ref = ssd_scan_ref(x, adt, dt, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-3, rtol=3e-3)
