"""BatchedCascadeEngine: parity with the sequential reference and
multi-stream accounting (see core/batched.py for the contract; the
parity assertions live in tests/harness.py)."""
import numpy as np
import pytest

from harness import (assert_run_parity, batched_engine, make_setup,
                     run_pair, sequential_engine)
from repro.core import (BatchedCascadeEngine, SimulatedExpert,
                        default_cascade_config)


def _engines(mu, n, dataset="imdb", seed=0, hard_budget=None, n_streams=1):
    cfg_kw = {} if hard_budget is None else {"hard_budget": hard_budget}
    stream, cfg = make_setup(mu, n, dataset=dataset, seed=seed, **cfg_kw)
    seq = sequential_engine(cfg, stream)
    bat = batched_engine(cfg, stream, n_streams=n_streams)
    return stream, seq, bat


# ---------------------------------------------------------------------------
# batch-size-1 parity: the acceptance contract
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dataset,mu,n", [
    ("imdb", 3e-6, 400),
    ("hatespeech", 3e-7, 400),
])
def test_batch1_bitwise_parity(dataset, mu, n):
    """S == 1 must reproduce OnlineCascade bit-for-bit: identical
    predictions, chosen levels, expert calls, and parameter state."""
    stream, seq, bat = _engines(mu, n, dataset=dataset)
    m_seq, m_bat = run_pair(seq, bat, stream)
    assert_run_parity(seq, m_seq, bat, m_bat)


def test_batch1_parity_with_hard_budget():
    stream, seq, bat = _engines(3e-7, 300, hard_budget=40)
    m_seq, m_bat = run_pair(seq, bat, stream)
    assert_run_parity(seq, m_seq, bat, m_bat)
    assert m_seq["expert_calls"] <= 40


# ---------------------------------------------------------------------------
# multi-stream semantics
# ---------------------------------------------------------------------------
def test_multi_stream_per_lane_accounting():
    """Per-lane expert_calls / level_fractions are tracked independently
    and reconcile with the aggregate."""
    n_streams, ticks = 8, 30
    stream, _, bat = _engines(3e-7, n_streams * ticks,
                              dataset="hatespeech", n_streams=n_streams)
    for tk in range(ticks):
        idxs = list(range(tk * n_streams, (tk + 1) * n_streams))
        out = bat.process_tick(idxs, [stream.docs[i] for i in idxs])
        assert out["predictions"].shape == (n_streams,)
    per = bat.stream_metrics()
    assert per["expert_calls"].shape == (n_streams,)
    np.testing.assert_array_equal(per["items_seen"],
                                  np.full(n_streams, ticks))
    assert per["expert_calls"].sum() == bat.expert_calls_total
    # each lane's level fractions are a distribution over exits
    fr = per["level_fractions"]
    assert fr.shape == (n_streams, len(bat.levels) + 1)
    np.testing.assert_allclose(fr.sum(axis=1), np.ones(n_streams),
                               atol=1e-9)
    # per-lane level counts reconcile with the aggregate history
    lv = np.stack(bat.history["level"])          # (ticks, S)
    for s in range(n_streams):
        for lev in range(len(bat.levels) + 1):
            assert bat.level_counts[s, lev] == int(np.sum(lv[:, s] == lev))


def test_multi_stream_hard_budget_respected():
    n_streams = 8
    stream, _, bat = _engines(1e-7, 240, dataset="imdb",
                              hard_budget=25, n_streams=n_streams)
    m = bat.run(stream)
    assert m["expert_calls"] <= 25


def test_partial_final_tick():
    """Streams whose length is not a multiple of n_streams still serve
    every item exactly once."""
    stream, _, bat = _engines(3e-7, 100, dataset="imdb", n_streams=8)
    m = bat.run(stream)
    assert len(m["predictions"]) == 100
    assert int(bat.items_seen.sum()) == 100
    assert m["predictions"].min() >= 0


def test_reset_reproduces_run():
    """reset() restores the exact initial state (the serving reuse path:
    warm once, serve many streams)."""
    stream, _, bat = _engines(3e-6, 192, dataset="imdb", n_streams=8)
    m1 = bat.run(stream)
    bat.reset()
    m2 = bat.run(stream)
    np.testing.assert_array_equal(m1["predictions"], m2["predictions"])
    assert m1["expert_calls"] == m2["expert_calls"]


# ---------------------------------------------------------------------------
# update-step scheduling (updates_per_tick="scaled")
# ---------------------------------------------------------------------------
def test_scaled_updates_close_expert_call_gap():
    """ROADMAP item 3 regression: one weighted update per tick adapts too
    slowly in item-space at S=64 (expert-call counts 2-8x the sequential
    reference on streams where the gates close early).  The lr-scaled
    mode (one step standing in for the tick's k per-item steps via
    Optimizer.step_k) must pin the count to within 1.5x of the
    reference."""
    n, mu = 2048, 1e-6
    stream, cfg = make_setup(mu, n)
    seq = sequential_engine(cfg, stream)
    m_seq = seq.run(stream)
    bat = batched_engine(cfg, stream, n_streams=64,
                         updates_per_tick="scaled")
    m_bat = bat.run(stream)
    ratio = m_bat["expert_calls"] / max(m_seq["expert_calls"], 1)
    assert ratio <= 1.5, (
        f"scaled updates: {m_bat['expert_calls']} expert calls vs "
        f"sequential {m_seq['expert_calls']} ({ratio:.2f}x > 1.5x)")


def test_updates_per_tick_validated():
    stream, _, _ = _engines(3e-7, 8)
    cfg = default_cascade_config(n_classes=2, mu=3e-7, seed=0)
    with pytest.raises(ValueError):
        BatchedCascadeEngine(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                             n_streams=8, updates_per_tick="double")


# ---------------------------------------------------------------------------
# vectorized ring buffer
# ---------------------------------------------------------------------------
def test_ring_buffer_matches_fifo_overwrite_order():
    """A tick inserting more demonstrations than a cache holds keeps the
    LAST cache_size items, like sequential FIFO inserts would."""
    n_streams = 24
    stream, _, bat = _engines(3e-7, n_streams, dataset="imdb",
                              n_streams=n_streams)
    # tick 1: beta0 == 1 so every lane DAgger-jumps to the expert
    idxs = list(range(n_streams))
    out = bat.process_tick(idxs, [stream.docs[i] for i in idxs])
    assert out["expert_called"].all()
    lvl0 = bat.levels[0]
    size = lvl0.spec.cache_size
    assert bat._cache_n[0] == size
    assert bat._cache_ptr[0] == n_streams % size
    # the cache must hold the last `size` lanes' labels, in ring order
    got = np.asarray(bat._cache_y[0])
    expect = np.zeros(size, np.int32)
    labels = out["expert_labels"]
    for j in range(n_streams):
        expect[j % size] = labels[j]
    np.testing.assert_array_equal(got, expect)
