"""cascade-san suite: the runtime sanitizers.

Covers the trace differ on hand-built divergent traces (exact
first-divergence coordinates), the end-to-end acceptance fixtures —
corrupt one engine's level params mid-run and the differ must name the
exact (tick, level, attr); touch ``ExpertTicket._shards`` without the
lock and the lock sanitizer must raise at the access — plus lock-order
cycle detection, retrace counting, the env/contextmanager enable
surface, trace persistence, and the ``reset()`` reuse pin (a reset
engine must be indistinguishable from a fresh one, traces included).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import (
    assert_run_parity, batched_engine, first_divergence, make_setup,
    run_pair, sequential_engine)
from repro.analysis import sanitize as san
from repro.core.experts import ExpertTicket


@pytest.fixture(autouse=True)
def _sanitizer_state_restored():
    """Every test starts from an all-off switchboard and the ambient
    state (e.g. the CI sanitizer job's CASCADE_SANITIZE env enable from
    conftest.py) is restored afterwards — the on/off assertions below
    must hold regardless of how the suite was launched."""
    prior = san.active_modes()
    san.disable()
    san.reset_retrace()
    yield
    san.disable()
    san.reset_retrace()
    if prior:
        san.enable(prior)


# ---------------------------------------------------------------------------
# trace differ on hand-built records
# ---------------------------------------------------------------------------
def rec(t, *, level=(0, 0), called=(0, 0), pred=(1, 1), rng=(11, 22),
        cache_n=(4, 4), cache_ptr=(0, 0), state=None):
    """One synthetic 2-lane, 2-level tick record."""
    return {
        "t": t,
        "level": list(level), "called": list(called), "pred": list(pred),
        "rng": list(rng),
        "cache_n": list(cache_n), "cache_ptr": list(cache_ptr),
        "state": dict(state) if state else
        {f"{li}.{a}": 7 for li in range(2)
         for a in ("params", "opt_state", "dparams", "dopt_state")},
    }


class TestDiffTraces:
    def test_identical_traces_clean(self):
        a = [rec(t) for t in range(5)]
        b = [rec(t) for t in range(5)]
        assert san.diff_traces(a, b) is None

    def test_rng_divergence_names_tick_and_lane(self):
        a = [rec(0), rec(1), rec(2)]
        b = [rec(0), rec(1), rec(2, rng=(11, 99))]
        d = san.diff_traces(a, b)
        assert (d.tick, d.lane, d.field) == (2, 1, "rng")
        assert (d.a, d.b) == (22, 99)
        assert "tick 2, lane 1" in d.describe()

    def test_routing_divergence_names_lane(self):
        a = [rec(0), rec(1, level=(0, 2), called=(0, 1))]
        b = [rec(0), rec(1, level=(0, 1), called=(0, 1))]
        d = san.diff_traces(a, b)
        assert (d.tick, d.lane, d.field) == (1, 1, "level")

    def test_state_divergence_names_level_and_attr(self):
        bad = {f"{li}.{a}": 7 for li in range(2)
               for a in ("params", "opt_state", "dparams", "dopt_state")}
        bad["1.opt_state"] = 8
        a = [rec(0), rec(1)]
        b = [rec(0), rec(1, state=bad)]
        d = san.diff_traces(a, b)
        assert (d.tick, d.level, d.attr) == (1, 1, "opt_state")
        assert d.field == "state" and d.lane is None
        assert "attr 'opt_state'" in d.describe()

    def test_params_reported_before_downstream_echoes(self):
        # a corrupted params tree perturbs dparams/opt_state digests in
        # the SAME tick record; the differ must name the cause, not an
        # alphabetically-earlier echo (dparams < params)
        bad = {f"{li}.{a}": 7 for li in range(2)
               for a in ("params", "opt_state", "dparams", "dopt_state")}
        for a in ("params", "opt_state", "dparams", "dopt_state"):
            bad[f"1.{a}"] = 9
        d = san.diff_traces([rec(3)], [rec(3, state=bad)])
        assert (d.tick, d.level, d.attr) == (3, 1, "params")

    def test_rng_checked_before_state(self):
        # a diverged key stream also moves state; the differ must name
        # the upstream cause (the lane's RNG), not the state echo
        bad = {f"{li}.{a}": 9 for li in range(2)
               for a in ("params", "opt_state", "dparams", "dopt_state")}
        d = san.diff_traces([rec(0)], [rec(0, rng=(11, 99), state=bad)])
        assert d.field == "rng" and d.lane == 1

    def test_cache_mirror_divergence_names_level(self):
        a = [rec(0, cache_ptr=(0, 3))]
        b = [rec(0, cache_ptr=(0, 4))]
        d = san.diff_traces(a, b)
        assert (d.field, d.level) == ("cache_ptr", 1)

    def test_length_mismatch_diverges_at_first_missing(self):
        a = [rec(0), rec(1), rec(2)]
        b = [rec(0), rec(1)]
        d = san.diff_traces(a, b)
        assert (d.field, d.tick, d.index) == ("length", 2, 2)
        assert (d.a, d.b) == (3, 2)

    def test_tick_number_mismatch(self):
        d = san.diff_traces([rec(0), rec(1)], [rec(0), rec(5)])
        assert d.field == "t" and (d.a, d.b) == (1, 5)

    def test_trace_objects_accepted(self):
        ta, tb = san.Trace(), san.Trace()
        for t in range(3):
            ta.append(rec(t))
            tb.append(rec(t))
        assert san.diff_traces(ta, tb) is None
        assert len(ta) == 3


class TestTracePersistence:
    def test_save_load_roundtrip(self, tmp_path):
        tr = san.Trace()
        for t in range(4):
            tr.append(rec(t, rng=(t, t + 1)))
        path = str(tmp_path / "trace.jsonl")
        tr.save(path)
        back = san.Trace.load(path)
        assert back.ticks == tr.ticks
        assert san.diff_traces(tr, back) is None


# ---------------------------------------------------------------------------
# enable surface
# ---------------------------------------------------------------------------
class TestEnableSurface:
    def test_enable_disable_roundtrip(self):
        san.enable({"determinism"})
        assert san.determinism_on()
        san.disable({"determinism"})
        assert not san.determinism_on()

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="unknown sanitize mode"):
            san.enable({"quantum"})

    def test_enable_from_env(self, monkeypatch):
        monkeypatch.setenv(san.ENV_VAR, "determinism, retrace")
        assert san.enable_from_env() == {"determinism", "retrace"}
        assert san.determinism_on() and san.retrace_on()

    def test_enable_from_env_unset_is_noop(self, monkeypatch):
        monkeypatch.delenv(san.ENV_VAR, raising=False)
        before = san.active_modes()
        assert san.enable_from_env() == set()
        assert san.active_modes() == before

    def test_determinism_trace_restores_prior_state(self):
        assert not san.determinism_on()
        with san.determinism_trace():
            assert san.determinism_on()
        assert not san.determinism_on()
        san.enable({"determinism"})
        with san.determinism_trace():
            pass
        assert san.determinism_on()   # pre-existing enable survives


# ---------------------------------------------------------------------------
# determinism sanitizer on the real engines
# ---------------------------------------------------------------------------
class TestDeterminismSanitizer:
    def test_sequential_and_batched_traces_align(self):
        stream, cfg = make_setup(mu=0.05, n=40)
        seq = sequential_engine(cfg, stream)
        bat = batched_engine(cfg, stream, n_streams=1)
        m_ref, m_new = run_pair(seq, bat, stream)
        ta, tb = san.trace_of(seq), san.trace_of(bat)
        assert ta is not None and len(ta) == 40
        assert tb is not None and len(tb) == 40
        assert san.diff_traces(ta, tb) is None
        assert first_divergence(seq, bat) is None
        assert_run_parity(seq, m_ref, bat, m_new)

    def test_corrupted_lane_params_named_exactly(self):
        # THE acceptance fixture: corrupt one engine's level-1 params
        # mid-run and the differ must name the exact (tick, level, attr)
        # — not "params mismatch somewhere" at stream end
        stream, cfg = make_setup(mu=0.05, n=40)
        a = batched_engine(cfg, stream, n_streams=2)
        b = batched_engine(cfg, stream, n_streams=2)
        S = 2
        with san.determinism_trace():
            for start in range(0, len(stream), S):
                idxs = list(range(start, min(start + S, len(stream))))
                docs = [stream.docs[i] for i in idxs]
                if b.t == 7:
                    leaves, tdef = jax.tree.flatten(b.levels[1].params)
                    leaves[0] = leaves[0].at[0].add(1.0)
                    b.levels[1].params = jax.tree.unflatten(tdef, leaves)
                a.process_tick(idxs, docs)
                b.process_tick(idxs, docs)
            a.flush(), b.flush()
        d = san.diff_traces(san.trace_of(a), san.trace_of(b))
        assert d is not None
        assert d.field == "state"
        # tick labels are 1-based (dispatch pre-increments self.t), so
        # the first tick served AFTER the b.t==7 injection is tick 8 —
        # and the attr must be the corrupted 'params', not a same-tick
        # optimizer/deferral echo
        assert (d.tick, d.level, d.attr) == (8, 1, "params"), d.describe()
        assert "level 1, attr 'params'" in d.describe()

    def test_no_trace_recorded_when_off(self):
        stream, cfg = make_setup(mu=0.05, n=8)
        eng = batched_engine(cfg, stream, n_streams=2)
        assert not san.determinism_on()
        eng.run(stream)
        assert san.trace_of(eng) is None

    def test_reset_drops_trace(self):
        stream, cfg = make_setup(mu=0.05, n=8)
        eng = batched_engine(cfg, stream, n_streams=2)
        with san.determinism_trace():
            eng.run(stream)
        assert san.trace_of(eng) is not None
        eng.reset()
        assert san.trace_of(eng) is None


# ---------------------------------------------------------------------------
# reset() reuse pin: a reset engine is indistinguishable from a fresh one
# ---------------------------------------------------------------------------
class TestResetReuse:
    def test_reset_engine_replays_stream_identically(self):
        stream, cfg = make_setup(mu=0.05, n=32)
        fresh = batched_engine(cfg, stream, n_streams=2)
        reused = batched_engine(cfg, stream, n_streams=2)
        with san.determinism_trace():
            reused.run(stream)        # warm-up serve on the same stream
            reused.reset()
            m_fresh, m_reused = fresh.run(stream), reused.run(stream)
        assert_run_parity(fresh, m_fresh, reused, m_reused,
                          history_keys=("level", "expert_called"),
                          costs=True)
        d = san.diff_traces(san.trace_of(fresh), san.trace_of(reused))
        assert d is None, d.describe()

    def test_reset_zeroes_the_stats_surface(self):
        stream, cfg = make_setup(mu=0.05, n=16)
        eng = batched_engine(cfg, stream, n_streams=2)
        eng.run(stream)
        eng.reset()
        assert eng.t == 0
        assert not np.any(eng.expert_calls)
        assert not np.any(eng.total_cost)
        assert not np.any(eng.level_counts)
        assert not np.any(eng.items_seen)
        assert not np.any(eng.J_cum)
        assert eng.commit_stats == {"lanes": 0, "age_sum": 0,
                                    "age_max": 0, "wall_sum": 0.0}
        assert all(v == 0 for v in eng.pipeline_stats.values())
        assert eng._cache_n == [0] * len(eng.levels)
        assert eng._cache_ptr == [0] * len(eng.levels)
        assert all(len(v) == 0 for v in (eng.history or {}).values())


# ---------------------------------------------------------------------------
# lock sanitizer
# ---------------------------------------------------------------------------
class TestLockSanitizer:
    def test_unguarded_shards_access_raises(self):
        # runtime twin of the CAS004 static acceptance fixture: a bare
        # read of ExpertTicket._shards outside the lock must raise AT
        # THE ACCESS, and a guarded read must pass untouched
        san.enable({"locks"})
        ticket = ExpertTicket(labels=np.array([1, 0, 1]))
        with pytest.raises(san.LockSanitizerError,
                           match=r"_shards read .* guarded-by"):
            ticket._shards
        with ticket._lock:
            assert len(ticket._shards) == 1
        assert ticket.done()          # the guarded API is unaffected

    def test_unguarded_write_raises(self):
        san.enable({"locks"})
        ticket = ExpertTicket(labels=np.array([1]))
        with pytest.raises(san.LockSanitizerError, match="write"):
            ticket._shards = []

    def test_disable_restores_bare_access(self):
        san.enable({"locks"})
        ticket = ExpertTicket(labels=np.array([1, 0]))
        san.disable({"locks"})
        assert len(ticket._shards) == 1   # instrumentation fully undone

    def test_instrumentation_is_idempotent(self):
        first = san.instrument_locks()
        again = san.instrument_locks()
        assert first == again and "ExpertTicket._shards" in first
        san.uninstrument_locks()

    def test_expert_pool_runs_clean_under_lock_sanitizer(self):
        # the real engine's concurrent ticket traffic must not trip the
        # sanitizer: every access in experts.py honours its annotation
        san.enable({"locks"})
        stream, cfg = make_setup(mu=0.05, n=24)
        eng = batched_engine(cfg, stream, n_streams=2,
                             expert_kw={"workers": 4})
        eng.run(stream)
        assert san.lock_order_violations() == []

    def test_lock_order_cycle_detected(self):
        la = san.tracked_rlock("A")
        lb = san.tracked_rlock("B")
        try:
            with la:
                with lb:
                    pass
            with pytest.raises(san.LockOrderError, match="cycle"):
                with lb:
                    with la:
                        pass
            assert len(san.lock_order_violations()) == 1
        finally:
            san._held.stack = []      # the raising acquire left a frame
            san.uninstrument_locks()  # clears the order graph


# ---------------------------------------------------------------------------
# retrace sanitizer
# ---------------------------------------------------------------------------
class TestRetraceSanitizer:
    def test_probe_is_identity_when_off(self):
        def f(x):
            return x
        assert san.trace_probe("f", f) is f

    def test_counts_compiles_not_calls(self):
        san.enable({"retrace"})
        san.reset_retrace()
        step = jax.jit(san.trace_probe("step", lambda x: x * 2))
        step(jnp.ones((4,)))
        step(jnp.ones((4,)))          # cache hit: no retrace
        assert san.retrace_report() == {"step": 1}
        step(jnp.ones((8,)))          # new shape: one retrace
        assert san.retrace_report() == {"step": 2}
        assert san.retrace_check(limit=2) == {}
        assert san.retrace_check(limit=1) == {"step": 2}

    def test_engine_compile_counts_are_bounded(self):
        san.enable({"retrace"})
        san.reset_retrace()
        stream, cfg = make_setup(mu=0.05, n=24)
        eng = batched_engine(cfg, stream, n_streams=2)
        eng.run(stream)
        report = san.retrace_report()
        assert report, "no probed step function compiled"
        # bucketing bounds compiled shapes at O(log S); a leak would
        # show up as one compile per tick (12 ticks here)
        assert san.retrace_check(limit=8) == {}, report


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
