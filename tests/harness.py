"""Shared parity harness for the engine test suite.

Every engine feature — batching, lane sharding, the async expert queue,
pipelined route passes, the expert pool / per-lane commit drain — must
pass the SAME contract: on identical tick keys it reproduces the
reference's predictions, chosen levels, and expert-call counts, and
(unless the feature documents a float-tolerance carve-out, e.g. SPMD
reduction reassociation) bitwise-identical parameters and optimizer
state.  Before this harness the contract lived as four copy-pasted
loops in test_batched / test_sharded / test_async / test_pipelined;
those files (including the multi-device subprocess snippets, which add
tests/ to sys.path) now all drive these helpers, and any new engine
feature should too.

The helpers deliberately accept both engine shapes: the sequential
``OnlineCascade`` (scalar accounting, per-item history) and the
``BatchedCascadeEngine`` (per-lane accounting, per-tick array history).
"""
from dataclasses import replace

import jax
import numpy as np

from repro.analysis import sanitize as _san
from repro.core import (BatchedCascadeEngine, OnlineCascade,
                        SimulatedExpert, default_cascade_config)
from repro.core.cascade import STATE_ATTRS
from repro.data import make_stream

EXPERT_NAME = "gpt-3.5-turbo"

# The documented float tolerance for lane-sharded runs: SPMD partitioning
# may reassociate the weighted-update reductions at the ulp level.
MESH_RTOL = 1e-4
MESH_ATOL = 1e-6


# ---------------------------------------------------------------------------
# fixtures: streams, configs, engines (the shared tick-key discipline)
# ---------------------------------------------------------------------------
def make_setup(mu, n, dataset="imdb", seed=0, **cfg_kw):
    """Stream + cascade config sharing one tick-key universe.

    Engines built from the same (dataset, seed, mu, cfg_kw) draw
    identical per-tick RNG (core/rng.py), which is what every parity
    assertion below relies on.  ``cfg_kw`` are ``CascadeConfig`` field
    overrides (hard_budget, sample_actions, ...).
    """
    stream = make_stream(dataset, seed=seed, n_samples=n)
    cfg = default_cascade_config(n_classes=stream.spec.n_classes, mu=mu,
                                 seed=seed)
    if cfg_kw:
        cfg = replace(cfg, **cfg_kw)
    return stream, cfg


def make_expert(stream, **kw):
    """The stream's simulated noisy-LLM expert (table lookup)."""
    return SimulatedExpert(stream, EXPERT_NAME, **kw)


def sequential_engine(cfg, stream, **kw):
    """The per-item Algorithm-1 reference loop (the semantics oracle)."""
    return OnlineCascade(cfg, make_expert(stream), **kw)


def batched_engine(cfg, stream, n_streams=1, expert_kw=None, **kw):
    """A BatchedCascadeEngine over the stream's simulated expert;
    ``expert_kw`` (workers=, latency=) configures the expert pool."""
    return BatchedCascadeEngine(cfg, make_expert(stream,
                                                 **(expert_kw or {})),
                                n_streams=n_streams, **kw)


# ---------------------------------------------------------------------------
# state equality
# ---------------------------------------------------------------------------
def state_leaves(levels, attrs=STATE_ATTRS):
    """Flat list of every state-tree leaf across levels, in a stable
    (level, attr, leaf) order — the canonical comparison layout."""
    return [np.asarray(x) for lvl in levels for attr in attrs
            for x in jax.tree.leaves(getattr(lvl, attr))]


def assert_state_equal(a_levels, b_levels, attrs=STATE_ATTRS,
                       rtol=None, atol=0.0):
    """Leaf-by-leaf state comparison: bitwise when ``rtol`` is None,
    else allclose (the mesh carve-out)."""
    a_leaves = state_leaves(a_levels, attrs)
    b_leaves = state_leaves(b_levels, attrs)
    assert len(a_leaves) == len(b_leaves)
    for a, b in zip(a_leaves, b_leaves):
        if rtol is None:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=rtol, atol=atol)


def states_equal(a_levels, b_levels, attrs=STATE_ATTRS) -> bool:
    """Boolean form of the bitwise comparison (for tests that assert a
    state has NOT changed yet, e.g. delay-timing tests)."""
    return all(bool(np.array_equal(a, b))
               for a, b in zip(state_leaves(a_levels, attrs),
                               state_leaves(b_levels, attrs)))


# ---------------------------------------------------------------------------
# the parity contract
# ---------------------------------------------------------------------------
def flat_history(engine, key):
    """An engine's per-item history for ``key``, flattened to one 1-D
    array (sequential: list of scalars; batched: list of per-tick
    lane arrays — identical tick shapes concatenate identically)."""
    h = engine.history[key]
    if len(h) and np.ndim(h[0]):
        return np.concatenate([np.asarray(x) for x in h])
    return np.asarray(list(h))


def expert_calls_total(engine) -> int:
    """Total expert calls (scalar for sequential, per-lane summed for
    batched)."""
    return int(np.sum(engine.expert_calls))


def run_pair(ref, new, stream):
    """Serve ``stream`` on both engines; returns (m_ref, m_new).

    Both runs execute under the determinism sanitizer
    (``repro.analysis.sanitize``), so each engine carries a per-tick
    trace afterwards and a failing ``assert_run_parity`` can name the
    first diverging (tick, lane, level, attr) instead of "params
    mismatch somewhere".
    """
    with _san.determinism_trace():
        return ref.run(stream), new.run(stream)


def first_divergence(ref, new):
    """The engines' first trace divergence (None when traces are
    missing — engines run outside ``run_pair`` — or identical)."""
    ta, tb = _san.trace_of(ref), _san.trace_of(new)
    if ta is None or tb is None:
        return None
    return _san.diff_traces(ta, tb)


def assert_run_parity(ref, m_ref, new, m_new, *, state="bitwise",
                      history_keys=("level",), costs=False,
                      attrs=STATE_ATTRS, rtol=MESH_RTOL, atol=MESH_ATOL):
    """The parity contract, in one place.

    Asserts identical predictions, identical per-item history for
    ``history_keys`` (chosen levels by default; add "expert_called",
    ...), equal expert-call totals, and — per ``state`` — "bitwise"
    state equality over ``attrs``, "allclose" (mesh tolerance), or
    ``None`` to skip the state check (delay-semantics comparisons where
    trajectories legitimately differ).  ``costs=True`` additionally
    pins per-item cost_units (the fallback-costing contract).

    When the engines ran through ``run_pair`` their determinism-
    sanitizer traces are compared on failure and the first diverging
    (tick, lane, level, attr) is appended to the assertion message.
    Trace differences alone never fail a passing contract: allclose-
    mode runs legitimately differ in state digests at the ulp level.
    """
    try:
        np.testing.assert_array_equal(m_ref["predictions"],
                                      m_new["predictions"])
        for key in history_keys:
            np.testing.assert_array_equal(flat_history(ref, key),
                                          flat_history(new, key))
        if costs:
            np.testing.assert_allclose(
                flat_history(ref, "cost").astype(np.float64),
                flat_history(new, "cost").astype(np.float64))
        assert expert_calls_total(ref) == expert_calls_total(new)
        if state == "bitwise":
            assert_state_equal(ref.levels, new.levels, attrs)
        elif state == "allclose":
            assert_state_equal(ref.levels, new.levels, attrs,
                               rtol=rtol, atol=atol)
        elif state is not None:
            raise ValueError(f"unknown state mode {state!r}")
    except AssertionError as err:
        div = first_divergence(ref, new)
        if div is not None:
            raise AssertionError(
                f"{err}\n[cascade-san] {div.describe()}") from err
        raise


# ---------------------------------------------------------------------------
# chaos + checkpoint/resume helpers (tests/test_faults.py, test_checkpoint.py)
# ---------------------------------------------------------------------------
def flaky_engine(cfg, stream, n_streams=1, expert_kw=None, flaky_kw=None,
                 **kw):
    """A batched engine whose expert pool is wrapped in ``FlakyExpert``
    (core/experts.py): ``flaky_kw`` carries the fault schedule/rates
    (schedule=, timeout_rate=, death_rate=, slow_rate=, seed=),
    ``expert_kw`` the inner pool (workers=, latency=).  The wrapper is
    reachable as ``engine.expert`` (``.injected`` counts the faults)."""
    from repro.core import FlakyExpert
    inner = make_expert(stream, **(expert_kw or {}))
    return BatchedCascadeEngine(cfg, FlakyExpert(inner, **(flaky_kw or {})),
                                n_streams=n_streams, **kw)


def run_ticks(engine, stream, lo, hi):
    """Serve global ticks [lo, hi) (tick t = items [t*S, (t+1)*S)) and
    return the outputs that resolved — with pipelining these may lag and
    include older ticks'; each carries its own ``out["tick"]``."""
    S = engine.n_streams
    outs = []
    for t in range(lo, hi):
        idxs = np.arange(t * S, (t + 1) * S)
        docs = [stream.docs[i] for i in idxs]
        if engine.pipeline_depth:
            outs.extend(engine.submit_tick(idxs, docs))
        else:
            outs.append(engine.process_tick(idxs, docs))
    return outs


def finish_run(engine, outs):
    """Drain the route ring and flush pending annotations; extends and
    returns ``outs`` (the run's complete output list)."""
    outs.extend(engine.drain())
    engine.flush()
    return outs


def collate_outputs(outs):
    """Tick-sorted output arrays {predictions, levels, expert_called},
    one row per item — the comparable form of a ``run_ticks`` run."""
    outs = sorted(outs, key=lambda o: o["tick"])
    return {
        "predictions": np.concatenate(
            [np.asarray(o["predictions"]) for o in outs]),
        "levels": np.concatenate([np.asarray(o["levels"]) for o in outs]),
        "expert_called": np.concatenate(
            [np.asarray(o["expert_called"]) for o in outs]),
    }


def resume_pair(build, stream, n_ticks, cut, path):
    """The checkpoint/resume parity scaffold: one uninterrupted run vs
    a run checkpointed at tick ``cut``, restored into a FRESH engine
    (``build()`` again) and finished.  Returns
    ``(full_engine, full_outs, resumed_engine, resumed_outs)`` with both
    output lists collated-comparable; callers assert bitwise equality
    of outputs, level state, and expert-call accounting."""
    full = build()
    full_outs = finish_run(full, run_ticks(full, stream, 0, n_ticks))
    part = build()
    part_outs = run_ticks(part, stream, 0, cut)
    part_outs.extend(part.drain())
    part.save_state(path)
    part.close()
    resumed = build()
    resumed.restore_state(path)
    resumed_outs = finish_run(
        resumed, run_ticks(resumed, stream, cut, n_ticks))
    return full, full_outs, resumed, part_outs + resumed_outs


def assert_resume_parity(full, full_outs, resumed, resumed_outs,
                         state="bitwise"):
    """Bitwise resume contract: identical collated outputs, identical
    (or allclose, for mesh runs) level state, identical expert-call
    accounting and costs."""
    a, b = collate_outputs(full_outs), collate_outputs(resumed_outs)
    for key in ("predictions", "levels", "expert_called"):
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)
    if state == "bitwise":
        assert_state_equal(full.levels, resumed.levels)
    else:
        assert_state_equal(full.levels, resumed.levels,
                           rtol=MESH_RTOL, atol=MESH_ATOL)
    np.testing.assert_array_equal(np.asarray(full.expert_calls),
                                  np.asarray(resumed.expert_calls))
    np.testing.assert_allclose(np.asarray(full.total_cost, np.float64),
                               np.asarray(resumed.total_cost, np.float64))


# ---------------------------------------------------------------------------
# continuous-batching front-end (core/admission.py) helpers
# ---------------------------------------------------------------------------
def frontend_engine(cfg, stream, lane_budget, expert_kw=None, **kw):
    """A batched engine sized as a lane pool for the admission
    front-end, with the per-lane commit log on (the front-end's
    per-stream records consume it)."""
    return batched_engine(cfg, stream, n_streams=lane_budget,
                          expert_kw=expert_kw, commit_log=True, **kw)


def run_frontend(engine, stream, requests, **fe_kw):
    """Serve an arrival schedule through the admission front-end under
    the determinism sanitizer; returns ``(frontend, metrics)`` — the
    metrics dict carries base-corpus ``predictions`` so it drops
    straight into ``assert_run_parity`` against a lockstep run."""
    from repro.core import CascadeFrontEnd
    fe = CascadeFrontEnd(engine, stream, **fe_kw)
    with _san.determinism_trace():
        fe.serve(requests)
    return fe, fe.metrics()


def run_frontend_pair(ref, engine, stream, requests, **fe_kw):
    """Lockstep reference run + front-end run over one trace window:
    ``(m_ref, frontend, m_fe)``, comparable via ``assert_run_parity``
    (the all-at-t=0 schedule makes tick compositions identical, so
    per-tick histories and traces line up tick-for-tick)."""
    from repro.core import CascadeFrontEnd
    with _san.determinism_trace():
        m_ref = ref.run(stream)
        fe = CascadeFrontEnd(engine, stream, **fe_kw)
        fe.serve(requests)
    return m_ref, fe, fe.metrics()


def sequential_stream_reference(cfg, stream, request):
    """The dedicated-lane oracle for one request: a fresh sequential
    cascade keyed as RNG stream ``request.rid`` (core/rng.py), serving
    just that request's items.  In the frozen regime (hard_budget=0 —
    no jumps, expert calls or updates) a dynamically-admitted stream
    must reproduce this trajectory bitwise, whatever lane, global tick
    or co-occupants served it (tests/test_admission.py)."""
    casc = sequential_engine(cfg, stream)
    casc.stream_id = request.rid
    preds, levels = [], []
    for gi in request.items:
        out = casc.process(gi, stream.docs[gi])
        preds.append(int(out["prediction"]))
        levels.append(int(out["level"]))
    return preds, levels
