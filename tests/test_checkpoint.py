"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint


def test_roundtrip_nested(tmp_path):
    tree = {
        "params": {
            "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "layers": [
                {"a": jnp.ones((2,), jnp.bfloat16)},
                {"a": jnp.zeros((2,), jnp.bfloat16)},
            ],
        },
        "step": jnp.int32(7),
    }
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, tree, metadata={"arch": "test", "n": 3})
    restored, meta = restore_checkpoint(path)
    assert meta == {"arch": "test", "n": 3}
    assert int(restored["step"]) == 7
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  restored["params"]["w"])
    assert restored["params"]["layers"][0]["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(tree["params"]["layers"][0]["a"], np.float32),
        np.asarray(restored["params"]["layers"][0]["a"], np.float32))


def test_roundtrip_model_params(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models import transformer as tf
    cfg = get_smoke_config("internlm2-1.8b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "m")
    save_checkpoint(path, params)
    restored, _ = restore_checkpoint(path)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
