"""Checkpoint roundtrip tests: pytree store (repro.checkpoint) plus the
engines' live-state save/restore (bitwise resume parity — the contract
that makes mid-stream preemption invisible)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import harness as H
from repro.checkpoint import (CheckpointError, restore_checkpoint,
                              save_checkpoint)


def test_roundtrip_nested(tmp_path):
    tree = {
        "params": {
            "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "layers": [
                {"a": jnp.ones((2,), jnp.bfloat16)},
                {"a": jnp.zeros((2,), jnp.bfloat16)},
            ],
        },
        "step": jnp.int32(7),
    }
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, tree, metadata={"arch": "test", "n": 3})
    restored, meta = restore_checkpoint(path)
    assert meta == {"arch": "test", "n": 3}
    assert int(restored["step"]) == 7
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  restored["params"]["w"])
    assert restored["params"]["layers"][0]["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(tree["params"]["layers"][0]["a"], np.float32),
        np.asarray(restored["params"]["layers"][0]["a"], np.float32))


def test_roundtrip_model_params(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models import transformer as tf
    cfg = get_smoke_config("internlm2-1.8b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "m")
    save_checkpoint(path, params)
    restored, _ = restore_checkpoint(path)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# dtype pins: low-precision round trips must be bit-preserving
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_low_precision_roundtrip_bitwise(tmp_path, dtype):
    """bfloat16 (stored via uint16 view) and float16 leaves round-trip
    with exact bit patterns — including NaN payloads and subnormals."""
    bits = np.array([0x0000, 0x0001, 0x7F80, 0x7FC1, 0x8000, 0x3F80,
                     0xFF80, 0x0080], np.uint16)
    arr = jnp.asarray(bits.view(np.float16)).astype(dtype) \
        if dtype == jnp.float16 else jnp.asarray(bits).view(jnp.bfloat16)
    path = str(tmp_path / "lp")
    save_checkpoint(path, {"x": arr})
    restored, _ = restore_checkpoint(path)
    assert restored["x"].dtype == dtype
    np.testing.assert_array_equal(
        np.asarray(restored["x"]).view(np.uint16),
        np.asarray(arr).view(np.uint16))


def test_empty_and_degenerate_trees(tmp_path):
    for i, (tree, kind) in enumerate([({}, dict), ([], list),
                                      (None, type(None))]):
        path = str(tmp_path / f"empty{i}")
        save_checkpoint(path, tree, metadata={"i": i})
        restored, meta = restore_checkpoint(path)
        assert isinstance(restored, kind) or restored is None
        assert restored == tree or (tree is None and restored is None)
        assert meta == {"i": i}


def test_long_list_restores_in_numeric_order(tmp_path):
    """Lists with > 10 elements must restore positionally (a
    lexicographic '#10' < '#2' sort would scramble them)."""
    tree = {"lst": [jnp.full((2,), i, jnp.int32) for i in range(13)]}
    path = str(tmp_path / "lst")
    save_checkpoint(path, tree)
    restored, _ = restore_checkpoint(path)
    assert len(restored["lst"]) == 13
    for i, leaf in enumerate(restored["lst"]):
        np.testing.assert_array_equal(np.asarray(leaf), [i, i])


def test_metadata_fidelity(tmp_path):
    meta = {"t": 42, "beta": [0.5, 0.25], "nested": {"a": [1, 2], "b":
            "s"}, "f": 1.5, "flag": True, "none": None}
    path = str(tmp_path / "meta")
    save_checkpoint(path, {"x": jnp.zeros((1,))}, metadata=meta)
    _, restored = restore_checkpoint(path)
    assert restored == meta


# ---------------------------------------------------------------------------
# damage paths: corruption is an error, never silent garbage
# ---------------------------------------------------------------------------
def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(CheckpointError, match="manifest"):
        restore_checkpoint(str(tmp_path / "nope"))


def test_corrupted_manifest_raises(tmp_path):
    path = str(tmp_path / "bad")
    save_checkpoint(path, {"x": jnp.zeros((2,))})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointError, match="corrupted manifest"):
        restore_checkpoint(path)


def test_missing_arrays_file_raises(tmp_path):
    path = str(tmp_path / "partial")
    save_checkpoint(path, {"x": jnp.zeros((2,))})
    os.remove(os.path.join(path, "arrays.npz"))
    with pytest.raises(CheckpointError, match="missing"):
        restore_checkpoint(path)


def test_truncated_arrays_file_raises(tmp_path):
    path = str(tmp_path / "trunc")
    save_checkpoint(path, {"x": jnp.arange(1024, dtype=jnp.float32)})
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.raises(CheckpointError):
        restore_checkpoint(path)


def test_manifest_array_mismatch_raises(tmp_path):
    """An arrays.npz that lost a manifest-named array (torn write) is
    reported as truncation, not a KeyError deep in numpy."""
    path = str(tmp_path / "torn")
    save_checkpoint(path, {"x": jnp.zeros((2,)), "y": jnp.ones((2,))})
    data = dict(np.load(os.path.join(path, "arrays.npz")))
    data.pop("y")
    np.savez(os.path.join(path, "arrays"), **data)
    with pytest.raises(CheckpointError, match="missing"):
        restore_checkpoint(path)


# ---------------------------------------------------------------------------
# engine live-state checkpointing: save -> restore -> bitwise resume
# ---------------------------------------------------------------------------
N = 64
MU = 3e-6


def test_sequential_save_restore_bitwise(tmp_path):
    stream, cfg = H.make_setup(mu=MU, n=N, dataset="hatespeech")
    full = H.sequential_engine(cfg, stream)
    for i in range(N):
        full.process(i, stream.docs[i])

    part = H.sequential_engine(cfg, stream)
    for i in range(N // 2):
        part.process(i, stream.docs[i])
    path = str(tmp_path / "seq")
    part.save_state(path)
    resumed = H.sequential_engine(cfg, stream)
    resumed.restore_state(path)
    assert resumed.t == part.t
    preds_full, preds_res = [], []
    for i in range(N // 2, N):
        preds_res.append(resumed.process(i, stream.docs[i])["prediction"])
    full2 = H.sequential_engine(cfg, stream)
    for i in range(N):
        out = full2.process(i, stream.docs[i])
        if i >= N // 2:
            preds_full.append(out["prediction"])
    assert preds_full == preds_res
    H.assert_state_equal(full.levels, resumed.levels)
    assert full.expert_calls == resumed.expert_calls
    assert full.total_cost == resumed.total_cost


def test_sequential_fingerprint_mismatch_raises(tmp_path):
    stream, cfg = H.make_setup(mu=MU, n=16, dataset="hatespeech")
    eng = H.sequential_engine(cfg, stream)
    for i in range(8):
        eng.process(i, stream.docs[i])
    path = str(tmp_path / "fp")
    eng.save_state(path)
    import dataclasses
    other_cfg = dataclasses.replace(cfg, seed=99)
    other = H.sequential_engine(other_cfg, stream)
    with pytest.raises(CheckpointError, match="mismatch"):
        other.restore_state(path)


@pytest.mark.parametrize("kw,cut,id_", [
    (dict(n_streams=1), 32, "S1"),
    (dict(n_streams=4, max_delay=2, expert_kw={"workers": 2,
                                               "latency": 1}), 8, "D2"),
    (dict(n_streams=4, max_delay=2, per_lane=True,
          expert_kw={"workers": 2, "latency": 1}), 8, "D2-lane"),
    (dict(n_streams=4, max_delay=2, pipeline_depth=1,
          expert_kw={"workers": 2}), 8, "D2-P1"),
], ids=lambda v: v if isinstance(v, str) else "")
def test_engine_resume_bitwise(tmp_path, kw, cut, id_):
    """The tentpole acceptance pin: a run interrupted by save_state and
    resumed in a FRESH engine is bitwise the uninterrupted run —
    predictions, levels, expert calls, params, opt state, costs — at
    S=1 and at (D=2, P, per_lane) corners."""
    stream, cfg = H.make_setup(mu=MU, n=N, dataset="imdb")
    S = kw.get("n_streams", 1)
    n_ticks = N // S

    def build():
        return H.batched_engine(cfg, stream, **kw)

    got = H.resume_pair(build, stream, n_ticks, cut,
                        str(tmp_path / "ck"))
    H.assert_resume_parity(*got)


@pytest.mark.multidevice
def test_engine_resume_mesh_corner(tmp_path):
    """(mesh, D=2, P=1, per_lane) corner: resume parity at the
    documented SPMD float tolerance for state, exact for outputs."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (multi-device CI job)")
    from repro.launch.mesh import make_mesh
    stream, cfg = H.make_setup(mu=MU, n=N, dataset="imdb")
    S = 8

    def build():
        return H.batched_engine(
            cfg, stream, n_streams=S, max_delay=2, pipeline_depth=1,
            per_lane=True, mesh=make_mesh((8, 1), ("data", "model")),
            expert_kw={"workers": 2})

    got = H.resume_pair(build, stream, N // S, 4, str(tmp_path / "ck"))
    H.assert_resume_parity(*got, state="allclose")


def test_engine_checkpoint_requires_drained_ring(tmp_path):
    stream, cfg = H.make_setup(mu=MU, n=32, dataset="imdb")
    eng = H.batched_engine(cfg, stream, n_streams=4, pipeline_depth=2)
    H.run_ticks(eng, stream, 0, 4)
    if len(eng._ring):
        with pytest.raises(RuntimeError, match="in-flight"):
            eng.save_state(str(tmp_path / "ck"))
    eng.drain()
    eng.save_state(str(tmp_path / "ck"))


def test_engine_restore_fingerprint_mismatch(tmp_path):
    stream, cfg = H.make_setup(mu=MU, n=32, dataset="imdb")
    eng = H.batched_engine(cfg, stream, n_streams=4)
    H.run_ticks(eng, stream, 0, 4)
    path = str(tmp_path / "ck")
    eng.save_state(path)
    other = H.batched_engine(cfg, stream, n_streams=8)
    with pytest.raises(CheckpointError, match="mismatch"):
        other.restore_state(path)


def test_run_checkpoint_every_and_restore_resume(tmp_path):
    """engine.run(checkpoint_every=...) writes mid-run checkpoints; a
    fresh engine restored from one finishes the stream with the same
    final metrics as the uninterrupted run."""
    stream, cfg = H.make_setup(mu=MU, n=N, dataset="hatespeech")
    path = str(tmp_path / "live")
    full = H.batched_engine(cfg, stream, n_streams=4, max_delay=2)
    m_full = full.run(stream)

    ck = H.batched_engine(cfg, stream, n_streams=4, max_delay=2)
    ck.run(stream, checkpoint_every=8, checkpoint_path=path)
    assert os.path.isdir(path)

    resumed = H.batched_engine(cfg, stream, n_streams=4, max_delay=2)
    resumed.restore_state(path)
    assert 0 < resumed.t < N // 4
    m_res = resumed.run(stream)
    # the resumed tail serves items [t*S, N); its predictions match the
    # full run's on that suffix, and final state is bitwise equal
    first = (N // 4 - (N // 4 - resumed.t)) * 4  # = resumed-start item
    np.testing.assert_array_equal(m_res["predictions"][first:],
                                  m_full["predictions"][first:])
    H.assert_state_equal(full.levels, resumed.levels)
    np.testing.assert_array_equal(np.asarray(full.expert_calls),
                                  np.asarray(resumed.expert_calls))


def test_frontend_save_restore_resume(tmp_path):
    """Admission front-end checkpoint: serve part of a schedule, save,
    restore into a fresh front-end, finish — records and engine state
    match the uninterrupted serve."""
    from repro.data import poisson_requests
    stream, cfg = H.make_setup(mu=MU, n=N, dataset="hatespeech")
    reqs = poisson_requests(N, rate=0.8, mean_len=5, seed=3)

    full_eng = H.frontend_engine(cfg, stream, 4, max_delay=2)
    full_fe, full_m = H.run_frontend(full_eng, stream, reqs)

    part_eng = H.frontend_engine(cfg, stream, 4, max_delay=2)
    from repro.core import CascadeFrontEnd
    part_fe = CascadeFrontEnd(part_eng, stream)
    part_fe.serve(reqs, max_ticks=6, finalize=False)
    path = str(tmp_path / "fe")
    part_fe.save_state(path)

    res_eng = H.frontend_engine(cfg, stream, 4, max_delay=2)
    res_fe = CascadeFrontEnd(res_eng, stream)
    res_fe.restore_state(path, reqs)
    res_fe.serve(reqs)
    m_res = res_fe.metrics()

    assert res_fe.admission_log == full_fe.admission_log
    np.testing.assert_array_equal(m_res["predictions"],
                                  full_m["predictions"])
    for rid, rec in full_fe.records.items():
        other = res_fe.records[rid]
        assert (rec.admit, rec.done, rec.retired, rec.lane) == \
            (other.admit, other.done, other.retired, other.lane)
        assert rec.predictions == other.predictions
    H.assert_state_equal(full_eng.levels, res_eng.levels)


def test_frontend_restore_policy_mismatch(tmp_path):
    from repro.core import CascadeFrontEnd
    from repro.data import poisson_requests
    stream, cfg = H.make_setup(mu=MU, n=32, dataset="hatespeech")
    reqs = poisson_requests(32, rate=0.8, mean_len=4, seed=1)
    eng = H.frontend_engine(cfg, stream, 4)
    fe = CascadeFrontEnd(eng, stream)
    fe.serve(reqs, max_ticks=3, finalize=False)
    path = str(tmp_path / "fe")
    fe.save_state(path)
    other = CascadeFrontEnd(H.frontend_engine(cfg, stream, 4), stream,
                            admission="shed", queue_limit=2)
    with pytest.raises(ValueError, match="policy mismatch"):
        other.restore_state(path, reqs)


def test_trace_concat_across_restore(tmp_path):
    """docs/ANALYSIS.md 'tracing across restore': the pre-checkpoint
    trace concatenated with the resumed engine's trace equals the
    uninterrupted run's trace (cascade-san concat_traces)."""
    from repro.analysis import sanitize as _san
    stream, cfg = H.make_setup(mu=MU, n=N, dataset="imdb")
    S, cut = 4, 8

    def build():
        return H.batched_engine(cfg, stream, n_streams=S, max_delay=2)

    with _san.determinism_trace():
        full = build()
        H.finish_run(full, H.run_ticks(full, stream, 0, N // S))

        part = build()
        H.run_ticks(part, stream, 0, cut)
        part.drain()
        path = str(tmp_path / "tr")
        part.save_state(path)
        resumed = build()
        resumed.restore_state(path)
        H.finish_run(resumed, H.run_ticks(resumed, stream, cut, N // S))

    joined = _san.concat_traces(_san.trace_of(part),
                                _san.trace_of(resumed))
    div = _san.diff_traces(_san.trace_of(full), joined)
    assert div is None, div.describe()


def test_trace_concat_rejects_gap():
    from repro.analysis import sanitize as _san
    a, b = _san.Trace(), _san.Trace()
    a.append({"t": 3})
    b.append({"t": 7})
    with pytest.raises(ValueError, match="abut"):
        _san.concat_traces(a, b)
