"""Composition matrix: one parametrized smoke over the full
(mesh x max_delay x pipeline_depth x updates_per_tick x expert_workers)
grid, asserting the parity contract on every supported combination.

Each cell runs a small stream on a cheap two-level (LR + small MLP)
cascade and must match the plain engine (no mesh, no pipeline, one
worker) that shares its SEMANTIC axes — max_delay, updates_per_tick,
and commit granularity (``per_lane`` rides the workers axis: the pool
cells commit per lane, which is a different, documented update
trajectory, so their reference does too).  mesh/pipeline/workers are
pure execution axes and must change nothing; mesh cells compare params
at the documented SPMD float tolerance and are marked ``multidevice``
(they run under the 8-virtual-device CI job and skip elsewhere).
"""
import jax
import pytest

from harness import (MESH_ATOL, MESH_RTOL, assert_run_parity,
                     batched_engine)
from repro.core import CascadeConfig, LevelSpec
from repro.data import make_stream
from repro.models.students import MLPSpec

N, S = 96, 8
MESHES = ("none", "data8")
DELAYS = (0, 2)
DEPTHS = (0, 2)
UPDATES = ("single", "scaled")
WORKERS = (1, 2)

_CACHE = {}


def _stream_cfg():
    if "setup" not in _CACHE:
        stream = make_stream("hatespeech", seed=0, n_samples=N)
        levels = (
            LevelSpec(kind="lr", cost=1.0, cache_size=8, batch_size=8,
                      student_lr=0.5, beta_decay=0.9,
                      calibration_factor=0.4),
            LevelSpec(kind="mlp", cost=50.0, cache_size=16, batch_size=8,
                      student_lr=1e-3, beta_decay=0.9,
                      calibration_factor=0.3),
        )
        cfg = CascadeConfig(
            levels=levels, n_classes=stream.spec.n_classes,
            expert_cost=1.0e6, mu=3e-6, n_features=512,
            mlp_spec=MLPSpec(n_features=512, hidden=64, n_layers=2),
            seed=0)
        _CACHE["setup"] = (stream, cfg)
    return _CACHE["setup"]


def _reference(max_delay, updates, per_lane):
    """The plain engine sharing the cell's semantic axes (cached: one
    build + run per (max_delay, updates, per_lane) key)."""
    key = ("ref", max_delay, updates, per_lane)
    if key not in _CACHE:
        stream, cfg = _stream_cfg()
        eng = batched_engine(cfg, stream, n_streams=S,
                             max_delay=max_delay, updates_per_tick=updates,
                             per_lane=per_lane)
        _CACHE[key] = (eng, eng.run(stream))
    return _CACHE[key]


def _cells():
    cells = []
    for mesh in MESHES:
        for d in DELAYS:
            for p in DEPTHS:
                for u in UPDATES:
                    for w in WORKERS:
                        marks = ([pytest.mark.multidevice]
                                 if mesh == "data8" else [])
                        cells.append(pytest.param(
                            mesh, d, p, u, w, marks=marks,
                            id=f"{mesh}-D{d}-P{p}-{u}-W{w}"))
    return cells


@pytest.mark.parametrize("mesh_kind,max_delay,depth,updates,workers",
                         _cells())
def test_composition_cell(mesh_kind, max_delay, depth, updates, workers):
    """Every supported knob combination preserves the parity contract
    against its semantic reference."""
    if mesh_kind == "data8" and len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (multi-device CI job: "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    per_lane = workers > 1
    ref, m_ref = _reference(max_delay, updates, per_lane)
    if mesh_kind == "none" and depth == 0 and workers == 1:
        # this cell IS its reference configuration
        return
    stream, cfg = _stream_cfg()
    mesh = None
    if mesh_kind == "data8":
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8, 1), ("data", "model"))
    eng = batched_engine(
        cfg, stream, n_streams=S, mesh=mesh, max_delay=max_delay,
        pipeline_depth=depth, updates_per_tick=updates,
        per_lane=per_lane, expert_kw={"workers": workers})
    m = eng.run(stream)
    if mesh is None:
        assert_run_parity(ref, m_ref, eng, m,
                          history_keys=("level", "expert_called"))
    else:
        assert_run_parity(ref, m_ref, eng, m, state="allclose",
                          attrs=("params", "dparams"),
                          history_keys=("level", "expert_called"),
                          rtol=MESH_RTOL, atol=MESH_ATOL)
    assert len(eng._pending) == 0 and len(eng._ring) == 0
