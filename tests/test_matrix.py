"""Composition matrix: one parametrized smoke over the full
(mesh x max_delay x pipeline_depth x updates_per_tick x expert_workers)
grid, asserting the parity contract on every supported combination.

Each cell runs a small stream on a cheap two-level (LR + small MLP)
cascade and must match the plain engine (no mesh, no pipeline, one
worker) that shares its SEMANTIC axes — max_delay, updates_per_tick,
and commit granularity (``per_lane`` rides the workers axis: the pool
cells commit per lane, which is a different, documented update
trajectory, so their reference does too).  mesh/pipeline/workers are
pure execution axes and must change nothing; mesh cells compare params
at the documented SPMD float tolerance and are marked ``multidevice``
(they run under the 8-virtual-device CI job and skip elsewhere).
"""
import jax
import numpy as np
import pytest

from harness import (MESH_ATOL, MESH_RTOL, assert_run_parity,
                     assert_state_equal, batched_engine, flaky_engine,
                     frontend_engine, run_frontend)
from repro.core import CascadeConfig, LevelSpec
from repro.data import make_stream, poisson_requests
from repro.models.students import MLPSpec

N, S = 96, 8
MESHES = ("none", "data8")
DELAYS = (0, 2)
DEPTHS = (0, 2)
UPDATES = ("single", "scaled")
WORKERS = (1, 2)

_CACHE = {}


def _stream_cfg():
    if "setup" not in _CACHE:
        stream = make_stream("hatespeech", seed=0, n_samples=N)
        levels = (
            LevelSpec(kind="lr", cost=1.0, cache_size=8, batch_size=8,
                      student_lr=0.5, beta_decay=0.9,
                      calibration_factor=0.4),
            LevelSpec(kind="mlp", cost=50.0, cache_size=16, batch_size=8,
                      student_lr=1e-3, beta_decay=0.9,
                      calibration_factor=0.3),
        )
        cfg = CascadeConfig(
            levels=levels, n_classes=stream.spec.n_classes,
            expert_cost=1.0e6, mu=3e-6, n_features=512,
            mlp_spec=MLPSpec(n_features=512, hidden=64, n_layers=2),
            seed=0)
        _CACHE["setup"] = (stream, cfg)
    return _CACHE["setup"]


def _reference(max_delay, updates, per_lane):
    """The plain engine sharing the cell's semantic axes (cached: one
    build + run per (max_delay, updates, per_lane) key)."""
    key = ("ref", max_delay, updates, per_lane)
    if key not in _CACHE:
        stream, cfg = _stream_cfg()
        eng = batched_engine(cfg, stream, n_streams=S,
                             max_delay=max_delay, updates_per_tick=updates,
                             per_lane=per_lane)
        _CACHE[key] = (eng, eng.run(stream))
    return _CACHE[key]


def _cells():
    cells = []
    for mesh in MESHES:
        for d in DELAYS:
            for p in DEPTHS:
                for u in UPDATES:
                    for w in WORKERS:
                        marks = ([pytest.mark.multidevice]
                                 if mesh == "data8" else [])
                        cells.append(pytest.param(
                            mesh, d, p, u, w, marks=marks,
                            id=f"{mesh}-D{d}-P{p}-{u}-W{w}"))
    return cells


@pytest.mark.parametrize("mesh_kind,max_delay,depth,updates,workers",
                         _cells())
def test_composition_cell(mesh_kind, max_delay, depth, updates, workers):
    """Every supported knob combination preserves the parity contract
    against its semantic reference."""
    if mesh_kind == "data8" and len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (multi-device CI job: "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    per_lane = workers > 1
    ref, m_ref = _reference(max_delay, updates, per_lane)
    if mesh_kind == "none" and depth == 0 and workers == 1:
        # this cell IS its reference configuration
        return
    stream, cfg = _stream_cfg()
    mesh = None
    if mesh_kind == "data8":
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8, 1), ("data", "model"))
    eng = batched_engine(
        cfg, stream, n_streams=S, mesh=mesh, max_delay=max_delay,
        pipeline_depth=depth, updates_per_tick=updates,
        per_lane=per_lane, expert_kw={"workers": workers})
    m = eng.run(stream)
    if mesh is None:
        assert_run_parity(ref, m_ref, eng, m,
                          history_keys=("level", "expert_called"))
    else:
        assert_run_parity(ref, m_ref, eng, m, state="allclose",
                          attrs=("params", "dparams"),
                          history_keys=("level", "expert_called"),
                          rtol=MESH_RTOL, atol=MESH_ATOL)
    assert len(eng._pending) == 0 and len(eng._ring) == 0


# ---------------------------------------------------------------------------
# admission-on cells: the continuous-batching front-end across the
# same execution/semantic axes (the admission-OFF grid is the matrix
# above; tests/test_admission.py holds the lockstep/sequential pins)
# ---------------------------------------------------------------------------
def _requests():
    """The shared staggered arrival schedule (seeded Poisson)."""
    if "reqs" not in _CACHE:
        _CACHE["reqs"] = poisson_requests(N, rate=0.7, mean_len=5, seed=3)
    return _CACHE["reqs"]


def _frontend_reference(max_delay, per_lane):
    """The plain-engine front-end run sharing the cell's semantic axes
    (no mesh, no pipeline, one worker)."""
    key = ("fe-ref", max_delay, per_lane)
    if key not in _CACHE:
        stream, cfg = _stream_cfg()
        eng = frontend_engine(cfg, stream, S, max_delay=max_delay,
                              per_lane=per_lane)
        fe, m = run_frontend(eng, stream, _requests())
        _CACHE[key] = (eng, fe, m)
    return _CACHE[key]


def _admission_cells():
    cells = []
    for mesh, d, p, w in (("none", 0, 2, 1), ("none", 2, 0, 1),
                          ("none", 2, 2, 2), ("data8", 0, 0, 1),
                          ("data8", 2, 2, 1)):
        marks = [pytest.mark.multidevice] if mesh == "data8" else []
        cells.append(pytest.param(mesh, d, p, w, marks=marks,
                                  id=f"adm-{mesh}-D{d}-P{p}-W{w}"))
    return cells


@pytest.mark.parametrize("mesh_kind,max_delay,depth,workers",
                         _admission_cells())
def test_admission_cell(mesh_kind, max_delay, depth, workers):
    """A staggered-arrival front-end run is invariant to the pure
    execution axes: same admission log, same per-stream trajectories,
    same final state (bitwise off-mesh, SPMD tolerance on-mesh)."""
    if mesh_kind == "data8" and len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (multi-device CI job: "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    per_lane = workers > 1
    ref_eng, ref_fe, ref_m = _frontend_reference(max_delay, per_lane)
    stream, cfg = _stream_cfg()
    mesh = None
    if mesh_kind == "data8":
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8, 1), ("data", "model"))
    eng = frontend_engine(
        cfg, stream, S, mesh=mesh, max_delay=max_delay,
        pipeline_depth=depth, per_lane=per_lane,
        expert_kw={"workers": workers})
    fe, m = run_frontend(eng, stream, _requests())
    assert fe.admission_log == ref_fe.admission_log
    np.testing.assert_array_equal(m["predictions"], ref_m["predictions"])
    for rid, rec in ref_fe.records.items():
        other = fe.records[rid]
        assert (rec.admit, rec.done, rec.retired, rec.lane) == \
            (other.admit, other.done, other.retired, other.lane)
        assert rec.predictions == other.predictions
        assert rec.levels == other.levels
    if mesh is None:
        assert_state_equal(ref_eng.levels, eng.levels)
    else:
        assert_state_equal(ref_eng.levels, eng.levels,
                           attrs=("params", "dparams"),
                           rtol=MESH_RTOL, atol=MESH_ATOL)
    assert len(eng._pending) == 0 and len(eng._ring) == 0


# ---------------------------------------------------------------------------
# chaos cells: requeue/fault injection across the execution axes — a
# recovering fault schedule must leave every cell bitwise (or SPMD-
# tolerance) equal to its fault-free twin (tests/test_faults.py holds
# the schedule-level chaos contracts; these cells compose them with
# mesh/pipeline/per-lane)
# ---------------------------------------------------------------------------
def _recovering_schedule():
    """First attempt of every 4th submit's shard 0 times out; retries
    (fresh submit seqs) succeed — all annotations eventually land."""
    seen = set()

    def schedule(seq, j):
        if j == 0 and seq % 4 == 0 and seq not in seen:
            seen.add(seq)
            return "timeout"
        return None

    return schedule


def _chaos_cells():
    cells = []
    for mesh, p, per_lane in (("none", 0, False), ("none", 0, True),
                              ("none", 2, False), ("data8", 0, False),
                              ("data8", 2, True)):
        marks = [pytest.mark.multidevice] if mesh == "data8" else []
        cells.append(pytest.param(
            mesh, p, per_lane, marks=marks,
            id=f"chaos-{mesh}-P{p}-{'lane' if per_lane else 'tick'}"))
    return cells


@pytest.mark.parametrize("mesh_kind,depth,per_lane", _chaos_cells())
def test_chaos_cell(mesh_kind, depth, per_lane):
    """Injected-but-recovering faults are a pure execution axis: the
    requeue path re-derives identical labels, so the cell matches its
    fault-free twin and every fault is accounted in fault_stats."""
    if mesh_kind == "data8" and len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (multi-device CI job: "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    stream, cfg = _stream_cfg()
    mesh = None
    if mesh_kind == "data8":
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8, 1), ("data", "model"))
    ref = batched_engine(cfg, stream, n_streams=S, max_delay=2,
                         per_lane=per_lane, expert_kw={"workers": 2})
    m_ref = ref.run(stream)
    eng = flaky_engine(cfg, stream, n_streams=S, mesh=mesh, max_delay=2,
                       pipeline_depth=depth, per_lane=per_lane,
                       expert_kw={"workers": 2},
                       flaky_kw={"schedule": _recovering_schedule()},
                       expert_timeout=0.01, max_requeues=3)
    m = eng.run(stream)
    assert eng.fault_stats["timeouts"] > 0
    assert eng.fault_stats["requeues"] == eng.fault_stats["timeouts"]
    assert eng.fault_stats["dropped_annotations"] == 0
    np.testing.assert_array_equal(m_ref["predictions"], m["predictions"])
    if mesh is None:
        assert_state_equal(ref.levels, eng.levels)
    else:
        assert_state_equal(ref.levels, eng.levels,
                           attrs=("params", "dparams"),
                           rtol=MESH_RTOL, atol=MESH_ATOL)
    assert len(eng._pending) == 0 and len(eng._ring) == 0
