"""Per-lane commit granularity + multi-worker expert pool
(``BatchedCascadeEngine(per_lane=...)``, ``core/experts.py`` pool):

* W-invariance: for any workers in {1, 2, 4} and adversarial worker-
  latency schedules, predictions/levels/expert calls/params are bitwise
  identical (the acceptance contract);
* ``workers=1, per_lane=False`` reproduces the PR-3 per-tick engine
  exactly (legacy single-``submit`` expert interface included);
* per-lane commit schedule: every annotation commits exactly once,
  within the D-tick bound, in deterministic (submit-tick, lane) order,
  with mean commit age below the per-tick drain at D >= 2;
* ``ExpertTicket`` per-item completion and the SimulatedExpert lazy/
  fake-latency ticket fix (labels must flow through the poll path).
"""
import numpy as np
import pytest

from harness import (assert_run_parity, batched_engine, make_expert,
                     make_setup, run_pair, sequential_engine)
from repro.core import BatchedCascadeEngine, ModelExpert
from repro.core.batched import lanes_due
from repro.core.experts import (
    ExpertTicket, poll_ticket_partial, shard_bounds)
from repro.models.students import TinyTFSpec, tinytf_init

# adversarial per-shard latency schedules (credits consumed by
# non-blocking done() probes; see core/experts._SimulatedAnnotation)
LATENCIES = {
    "none": None,
    "constant": 4,
    "alternating": lambda seq, j: 7 if (seq + j) % 2 else 0,
    "pseudo_random": lambda seq, j: (seq * 2654435761 + j * 40503) % 9,
}


def _pool_engine(cfg, stream, *, workers, latency=None, per_lane=True,
                 D=2, S=8):
    return batched_engine(cfg, stream, n_streams=S, max_delay=D,
                          per_lane=per_lane,
                          expert_kw={"workers": workers,
                                     "latency": latency})


# ---------------------------------------------------------------------------
# W-invariance: the acceptance contract
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("max_delay", [0, 2])
def test_worker_and_latency_invariance_bitwise(max_delay):
    """For any workers in {1, 2, 4} and any adversarial worker-latency
    schedule, the per-lane engine's predictions, levels, expert calls,
    params and optimizer state are bitwise identical: the commit
    schedule is deterministic (lanes_due) and commits block on their
    shard instead of reordering, so worker timing moves wall-clock
    only."""
    stream, cfg = make_setup(3e-7, 192, dataset="hatespeech")
    ref = _pool_engine(cfg, stream, workers=1, D=max_delay)
    m_ref = ref.run(stream)
    for workers in (2, 4):
        for name, latency in LATENCIES.items():
            eng = _pool_engine(cfg, stream, workers=workers,
                               latency=latency, D=max_delay)
            m = eng.run(stream)
            assert_run_parity(ref, m_ref, eng, m,
                              history_keys=("level", "expert_called"))
            assert eng.commit_log == ref.commit_log, (workers, name)


def test_per_tick_mode_is_worker_invariant_too():
    """per_lane=False with a pooled expert polls the whole ticket at the
    per-tick deadline — sharding the annotation must not change
    anything."""
    stream, cfg = make_setup(3e-7, 128, dataset="imdb")
    ref = _pool_engine(cfg, stream, workers=1, per_lane=False)
    m_ref = ref.run(stream)
    eng = _pool_engine(cfg, stream, workers=4, per_lane=False,
                       latency=LATENCIES["pseudo_random"])
    m = eng.run(stream)
    assert_run_parity(ref, m_ref, eng, m,
                      history_keys=("level", "expert_called"))


# ---------------------------------------------------------------------------
# workers=1, per_lane=False: the PR-3 engine, exactly
# ---------------------------------------------------------------------------
class _LegacySubmitExpert:
    """A PR-3-shaped expert: only label/label_batch/submit/poll, no
    submit_many, eager single-shard tickets."""

    def __init__(self, base):
        self.base = base
        self.cost = base.cost

    def label(self, idx, doc):
        return self.base.label(idx, doc)

    def label_batch(self, idxs, docs):
        return self.base.label_batch(idxs, docs)

    def submit(self, idxs, docs):
        return ExpertTicket(labels=self.base.label_batch(idxs, docs))

    def poll(self, ticket, block=True):
        from repro.core.experts import poll_ticket
        return poll_ticket(ticket, block)


@pytest.mark.parametrize("max_delay", [0, 2])
def test_default_mode_reproduces_pr3_engine(max_delay):
    """The default configuration (per_lane=False, workers=1) must be
    bitwise identical to the engine driven through the legacy
    single-submit expert interface — i.e. the PR-3 per-tick async
    engine, exactly."""
    stream, cfg = make_setup(3e-7, 128, dataset="hatespeech")
    legacy = BatchedCascadeEngine(
        cfg, _LegacySubmitExpert(make_expert(stream)), n_streams=8,
        max_delay=max_delay)
    m_legacy = legacy.run(stream)
    eng = _pool_engine(cfg, stream, workers=1, per_lane=False,
                       D=max_delay)
    m = eng.run(stream)
    assert_run_parity(legacy, m_legacy, eng, m,
                      history_keys=("level", "expert_called"))


def test_per_lane_s1_bitwise_parity_with_sequential():
    """per_lane=True at S=1 is the sequential reference's per-item
    update schedule — bitwise, including per-item costs and opt
    state."""
    stream, cfg = make_setup(3e-6, 300)
    seq = sequential_engine(cfg, stream)
    eng = batched_engine(cfg, stream, n_streams=1, per_lane=True)
    m_seq, m_eng = run_pair(seq, eng, stream)
    assert_run_parity(seq, m_seq, eng, m_eng, costs=True)


# ---------------------------------------------------------------------------
# the per-lane commit schedule
# ---------------------------------------------------------------------------
def test_commit_log_exactly_once_bounded_ordered():
    """Every annotated (tick, lane) commits exactly once, within the
    D-tick bound, in globally sorted (submit-tick, lane) order."""
    S, D = 8, 2
    stream, cfg = make_setup(3e-7, 256, dataset="hatespeech")
    eng = _pool_engine(cfg, stream, workers=2, D=D, S=S,
                       latency=LATENCIES["alternating"])
    eng.run(stream)
    log = eng.commit_log
    called = np.concatenate(list(eng.history["expert_called"]))
    assert len(log) == int(called.sum())            # exactly once
    keys = [(t, s) for t, s, _c in log]
    assert len(set(keys)) == len(keys)              # no duplicates
    assert keys == sorted(keys)                     # deterministic order
    ages = np.array([c - t for t, s, c in log])
    assert ages.max() <= D                          # the <= D bound
    assert ages.min() >= 0


def test_per_lane_mean_commit_age_below_per_tick():
    """At D=2 the spread schedule commits lanes at mean age ~1.5 instead
    of the per-tick drain's 2.0 — the headline latency win the
    pool_throughput benchmark measures in wall-clock too."""
    stream, cfg = make_setup(3e-7, 256, dataset="hatespeech")
    per_tick = _pool_engine(cfg, stream, workers=1, per_lane=False, D=2)
    per_tick.run(stream)
    per_lane = _pool_engine(cfg, stream, workers=2, per_lane=True, D=2)
    per_lane.run(stream)

    def mean_age(e):
        return e.commit_stats["age_sum"] / max(e.commit_stats["lanes"], 1)

    # (expert-call counts differ between the modes — per-lane is a
    # different, per-item update trajectory — but the commit-age claim
    # is about the drain schedule, not the traffic)
    assert per_lane.commit_stats["lanes"] > 0
    assert mean_age(per_lane) < mean_age(per_tick)
    # both modes honor the <= D bound; the per-tick drain commits every
    # in-window lane at exactly age D (only the stream-end flush tail,
    # covering the last < D routed ticks, lands younger)
    ages_pt = [c - t for t, _s, c in per_tick.commit_log]
    assert max(ages_pt) <= 2
    last_tick = max(t for t, _s, _c in per_tick.commit_log)
    assert all(a == 2 for (t, _s, c), a in
               zip(per_tick.commit_log, ages_pt) if t <= last_tick - 2)


def test_per_lane_composes_with_pipeline():
    """per_lane + pipeline_depth: the conservative per-lane fence keeps
    results identical to the unpipelined per-lane engine."""
    stream, cfg = make_setup(3e-6, 192)
    e0 = batched_engine(cfg, stream, n_streams=8, max_delay=2,
                        per_lane=True, expert_kw={"workers": 2})
    m0 = e0.run(stream)
    eP = batched_engine(cfg, stream, n_streams=8, max_delay=2,
                        per_lane=True, pipeline_depth=2,
                        expert_kw={"workers": 2})
    mP = eP.run(stream)
    assert_run_parity(e0, m0, eP, mP,
                      history_keys=("level", "expert_called"))


def test_lanes_due_schedule():
    """The pure commit schedule: monotone cumulative counts, nothing due
    before age 1, the spread at D=2, everything due at age D (both
    modes)."""
    assert lanes_due(8, 0, 2, True) == 0
    assert lanes_due(8, 1, 2, True) == 4
    assert lanes_due(8, 2, 2, True) == 8
    assert lanes_due(8, 1, 2, False) == 0
    assert lanes_due(8, 2, 2, False) == 8
    assert lanes_due(5, 0, 0, True) == 5            # D=0: inline
    for k in range(9):
        prev = 0
        for age in range(4):
            cur = lanes_due(k, age, 3, True)
            assert 0 <= prev <= cur <= k
            prev = cur
        assert lanes_due(k, 3, 3, True) == k


# ---------------------------------------------------------------------------
# ExpertTicket per-item completion + the lazy SimulatedExpert fix
# ---------------------------------------------------------------------------
def test_shard_bounds_pure_partition():
    """Contiguous, balanced, exhaustive, deterministic."""
    assert shard_bounds(0, 4) == []
    assert shard_bounds(3, 8) == [(0, 1), (1, 2), (2, 3)]
    for k in (1, 5, 8, 17):
        for w in (1, 2, 4, 7):
            b = shard_bounds(k, w)
            assert b[0][0] == 0 and b[-1][1] == k
            assert all(lo < hi for lo, hi in b)
            assert all(b[i][1] == b[i + 1][0] for i in range(len(b) - 1))
            sizes = [hi - lo for lo, hi in b]
            assert max(sizes) - min(sizes) <= 1
            assert b == shard_bounds(k, w)


def test_simulated_expert_ticket_is_lazy_and_latent():
    """The satellite fix: SimulatedExpert.submit must NOT compute labels
    eagerly — the ticket resolves through the real poll path, and a fake
    latency keeps it genuinely in flight for a scripted number of
    non-blocking polls."""
    stream, _ = make_setup(3e-7, 16)
    # per-shard schedule: shard 0 ready after 2 probes, shard 1 after 3
    # (done()/ready_mask probe every shard uniformly — one credit per
    # shard per whole-ticket poll)
    exp = make_expert(stream, workers=2, latency=lambda seq, j: 2 + j)
    table = stream.expert_labels("gpt-3.5-turbo")
    ticket = exp.submit_many(list(range(8)), stream.docs[:8])
    # in flight: nothing resolved yet
    assert exp.poll(ticket, block=False) is None
    mask, labels = poll_ticket_partial(ticket)
    assert not mask.any() and (labels == -1).all()
    # shard 0's credits run out first: genuine PARTIAL completion — the
    # first shard's labels are readable while the second is in flight
    mask, labels = poll_ticket_partial(ticket)
    assert mask[:4].all() and not mask[4:].any()
    np.testing.assert_array_equal(labels[:4], table[:4])
    assert (labels[4:] == -1).all()
    # one more probe drains shard 1 too
    mask, labels = poll_ticket_partial(ticket)
    assert mask.all()
    np.testing.assert_array_equal(labels, table[:8])
    # blocking poll returns the same labels (latency never changes them)
    np.testing.assert_array_equal(exp.poll(ticket), table[:8])


def test_ticket_result_slice_blocks_per_shard():
    """result_slice resolves only the shards overlapping the range;
    other shards stay in flight (per-item completion)."""

    class _Probe:
        def __init__(self, labels):
            self.labels = labels
            self.resolved = False

        def done(self):
            return self.resolved

        def result(self):
            self.resolved = True
            return self.labels

    a, b = _Probe(np.array([1, 2], np.int32)), _Probe(
        np.array([3, 4, 5], np.int32))
    ticket = ExpertTicket(shards=[(0, 2, a), (2, 5, b)])
    assert not ticket.done()
    assert ticket.item_done(0) is False
    np.testing.assert_array_equal(ticket.result_slice(0, 2), [1, 2])
    assert a.resolved and not b.resolved             # b untouched
    np.testing.assert_array_equal(ticket.ready_mask(),
                                  [True, True, False, False, False])
    np.testing.assert_array_equal(ticket.result_slice(1, 4), [2, 3, 4])
    assert b.resolved
    np.testing.assert_array_equal(ticket.result(), [1, 2, 3, 4, 5])
    assert ticket.done()


def test_ticket_legacy_forms_still_work():
    """labels= and future= constructors (the PR-3 shapes) keep their
    semantics."""
    t1 = ExpertTicket(labels=np.array([7, 8], np.int32))
    assert t1.done()
    np.testing.assert_array_equal(t1.result(), [7, 8])
    np.testing.assert_array_equal(t1.result_slice(1, 2), [8])
    with pytest.raises(ValueError):
        ExpertTicket()
    with pytest.raises(ValueError):
        ExpertTicket(labels=np.zeros(1, np.int32),
                     shards=[(0, 1, np.zeros(1, np.int32))])

    class _Fut:
        def __init__(self):
            self.ready = False

        def done(self):
            return self.ready

        def result(self):
            return np.array([4, 5, 6], np.int32)

    # future-form (length unknown until resolution): per-item queries
    # stay conservative in flight, then settle bounds once done
    t2 = ExpertTicket(future=_Fut())
    with pytest.raises(ValueError):
        t2.ready_mask()                     # in flight: length unknown
    assert t2.item_done(99) is False        # conservative, not "ready"
    t2._shards[0][2].ready = True
    np.testing.assert_array_equal(t2.ready_mask(), [True] * 3)
    with pytest.raises(IndexError):
        t2.item_done(99)                    # bounds settled: range-checked
    np.testing.assert_array_equal(poll_ticket_partial(t2)[1], [4, 5, 6])


# ---------------------------------------------------------------------------
# ModelExpert pool
# ---------------------------------------------------------------------------
def test_model_expert_pool_deterministic_labels():
    """submit_many over W workers returns exactly the per-shard
    label_batch results in order, reproducibly (shard layout is a pure
    function of (k, W), never of thread timing)."""
    stream, _ = make_setup(3e-7, 24)
    spec = TinyTFSpec(d_model=32, n_layers=1, d_ff=64, n_classes=2)
    import jax
    expert = ModelExpert(params=tinytf_init(jax.random.PRNGKey(0), spec),
                         spec=spec, workers=4)
    idxs = list(range(12))
    docs = stream.docs[:12]
    got = expert.poll(expert.submit_many(idxs, docs))
    expect = np.concatenate(
        [expert.label_batch(idxs[lo:hi], docs[lo:hi])
         for lo, hi in shard_bounds(12, 4)])
    np.testing.assert_array_equal(got, expect)
    # repeated pooled annotation is reproducible
    again = expert.poll(expert.submit_many(idxs, docs))
    np.testing.assert_array_equal(got, again)
    expert.close()
