"""Data pipeline tests: determinism, benchmark statistics, shifts."""
import numpy as np

from repro.data import BENCHMARKS, hash_bow, hash_ids, make_stream


def test_deterministic():
    s1 = make_stream("imdb", seed=3, n_samples=200)
    s2 = make_stream("imdb", seed=3, n_samples=200)
    assert np.array_equal(s1.labels, s2.labels)
    assert all(np.array_equal(a, b) for a, b in zip(s1.docs, s2.docs))
    e1 = s1.expert_labels("gpt-3.5-turbo")
    e2 = s2.expert_labels("gpt-3.5-turbo")
    assert np.array_equal(e1, e2)


def test_sizes_and_classes_match_paper():
    assert BENCHMARKS["imdb"].n_samples == 25_000
    assert BENCHMARKS["hatespeech"].n_samples == 10_703
    assert BENCHMARKS["isear"].n_samples == 7_666
    assert BENCHMARKS["fever"].n_samples == 6_512
    assert BENCHMARKS["isear"].n_classes == 7
    assert BENCHMARKS["hatespeech"].n_classes == 2


def test_hatespeech_imbalance():
    """~1:7.95 hate:noHate ratio (paper §4)."""
    s = make_stream("hatespeech", seed=0)
    frac_pos = float(np.mean(s.labels == 1))
    assert 0.09 < frac_pos < 0.14


def test_expert_accuracy_matches_table1():
    for name, spec in BENCHMARKS.items():
        s = make_stream(name, seed=0)
        for expert, acc in spec.expert_acc.items():
            got = float(np.mean(s.expert_labels(expert) == s.labels))
            assert abs(got - acc) < 0.02, (name, expert, got, acc)


def test_expert_errors_biased_to_long_inputs():
    """Paper Table 5: LLM accuracy drops with input length."""
    s = make_stream("imdb", seed=0, n_samples=8000)
    e = s.expert_labels("gpt-3.5-turbo")
    correct = (e == s.labels)
    med = np.median(s.lengths)
    acc_short = float(np.mean(correct[s.lengths <= med]))
    acc_long = float(np.mean(correct[s.lengths > med]))
    assert acc_short > acc_long


def test_length_shift_ordering():
    s = make_stream("imdb", seed=0, n_samples=500, order="length")
    assert np.all(np.diff(s.lengths) >= 0)


def test_category_shift_ordering():
    s = make_stream("imdb", seed=0, n_samples=600, order="category")
    held = s.categories == s.categories.max()
    first_held = int(np.argmax(held))
    assert not held[:first_held].any()
    assert held[first_held:].all()


def test_features_shapes():
    doc = np.arange(50)
    f = hash_bow(doc, 2048)
    assert f.shape == (2048,) and abs(float(np.linalg.norm(f)) - 1.0) < 1e-5
    ids = hash_ids(doc, 4096, 128)
    assert ids.shape == (128,)
    assert ids[:50].min() >= 1 and ids[50:].max() == 0


def test_bow_order_invariance_vs_ids_order_sensitivity():
    """The LR featurizer must be order-blind; the TF featurizer must not —
    this is the capability split the benchmarks rely on."""
    rng = np.random.default_rng(0)
    doc = rng.integers(0, 30000, 60)
    perm = doc[::-1].copy()
    assert np.allclose(hash_bow(doc, 512), hash_bow(perm, 512))
    assert not np.array_equal(hash_ids(doc, 4096, 64),
                              hash_ids(perm, 4096, 64))
