"""Model substrate tests: attention paths, SWA ring buffer, MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade gracefully: only property tests skip
    from _hypothesis_stubs import given, settings, st

from repro.configs import get_smoke_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import transformer as tf
from repro.models.attention import (
    causal_prefill_blocked, chunked_attention, swa_prefill_attention)
from repro.models.moe import capacity_for, moe_ffn_local, route


def _qkv(key, B, S, H, K, hd):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, K, hd)),
            jax.random.normal(ks[2], (B, S, K, hd)))


def _ref(q, k, v, causal=True, window=None):
    return attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=causal,
                         window=window).transpose(0, 2, 1, 3)


def test_chunked_attention_matches_naive():
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 128, 4, 2, 32)
    pos = jnp.arange(128)
    out = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref(q, k, v)),
                               atol=2e-5, rtol=2e-5)


def test_blocked_causal_prefill_matches_naive():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 256, 4, 4, 32)
    out = causal_prefill_blocked(q, k, v, chunk_q=64, chunk_kv=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref(q, k, v)),
                               atol=2e-5, rtol=2e-5)


def test_swa_banded_prefill_matches_naive():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 512, 4, 2, 32)
    out = swa_prefill_attention(q, k, v, window=64, chunk=64)
    ref = _ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([64, 128, 256]),
       w=st.sampled_from([16, 32, 64]),
       chunk=st.sampled_from([16, 32, 64]))
def test_prefill_attention_window_property(s, w, chunk):
    """Property: banded and full-mask SWA paths agree for any geometry."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, s, 2, 2, 16)
    pos = jnp.arange(s)
    full = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                             causal=True, window=w, chunk=chunk)
    ref = _ref(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


def test_swa_ring_buffer_decode_equals_full_history():
    """Ring-buffer SWA cache must reproduce windowed attention over the
    full history: decode step T with cache W == naive attention over the
    last W tokens."""
    cfg = get_smoke_config("h2o-danube-3-4b")   # window 64
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    S = 128                                      # prompt = 2x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0,
                              cfg.vocab)
    # path A: decode token S after prefilling S tokens (ring cache W=64)
    last, cache = tf.prefill(params, {"tokens": toks[:, :S]}, cfg)
    logits_dec, _ = tf.decode_step(params, cache, toks[:, S:S + 1],
                                   jnp.int32(S), cfg)
    # path B: teacher-forced full forward (banded masks, no ring buffer)
    logits_full, _ = tf.forward(
        params, {"tokens": toks, "targets": toks}, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S]),
                               rtol=6e-2, atol=6e-2)


def test_moe_router_topk_normalized():
    cfg = get_smoke_config("mixtral-8x22b")
    params_key = jax.random.PRNGKey(0)
    from repro.models.moe import init_moe
    p = init_moe(params_key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    idx, w, aux = route(x, p["router"], cfg)
    assert idx.shape == (32, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_bounded():
    """With capacity_factor -> large, gshard dispatch equals a dense
    mixture over the selected experts."""
    cfg = get_smoke_config("dbrx-132b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    from repro.models.moe import init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model),
                          jnp.float32) * 0.5
    y, aux = moe_ffn_local(x, p, cfg)
    # dense reference: run every expert, combine with routing weights
    idx, w, _ = route(x, p["router"], cfg)
    h = jnp.einsum("td,edf->tef", x, p["w_in"])
    g = jnp.einsum("td,edf->tef", x, p["w_gate"])
    out_all = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, p["w_out"])
    ref = jnp.zeros_like(x)
    for slot in range(cfg.moe.top_k):
        sel = out_all[jnp.arange(16), idx[:, slot]]
        ref = ref + w[:, slot:slot + 1] * sel
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)


def test_capacity_function():
    cfg = get_smoke_config("mixtral-8x22b")
    c = capacity_for(64, cfg)
    assert c >= 64 * cfg.moe.top_k / cfg.moe.num_experts
    assert c % 4 == 0


def test_mamba_decode_matches_forward():
    cfg = get_smoke_config("mamba2-370m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    S = 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S + 4), 0,
                              cfg.vocab)
    logits_full, _ = tf.forward(params, {"tokens": toks, "targets": toks},
                                cfg)
    last, cache = tf.prefill(params, {"tokens": toks[:, :S]}, cfg,
                             cache_len=S + 4)
    for t in range(S, S + 4):
        logits_dec, cache = tf.decode_step(params, cache, toks[:, t:t + 1],
                                           jnp.int32(t), cfg)
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_full[:, t]),
                                   rtol=6e-2, atol=6e-2)


def test_vocab_padding_masked():
    """Padded vocab columns must never win argmax."""
    cfg = get_smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab=500)   # padded to 512
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 500)
    logits, _ = tf.forward(params, {"tokens": toks, "targets": toks}, cfg)
    assert logits.shape[-1] == 512
    assert int(jnp.max(jnp.argmax(logits, -1))) < 500


# ---------------------------------------------------------------------------
# cascade students: deep MLP over hashed BoW
# ---------------------------------------------------------------------------
def test_mlp_student_forward_and_grad():
    from repro.models.students import (MLPSpec, mlp_init, mlp_loss_weighted,
                                       mlp_predict)
    spec = MLPSpec(n_features=64, hidden=32, n_layers=3, n_classes=4)
    params = mlp_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    probs = mlp_predict(params, x)
    assert probs.shape == (8, 4)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), np.ones(8),
                               rtol=1e-5)
    y = jnp.zeros((8,), jnp.int32)
    w = jnp.ones((8,), jnp.float32)
    g = jax.grad(mlp_loss_weighted)(params, x, y, w)
    # zero-init head: first gradient lands on cls_w only
    assert float(jnp.max(jnp.abs(g["cls_w"]))) > 0
    # after the head moves, the hidden chain sees gradient
    params2 = dict(params, cls_w=params["cls_w"] - 0.1 * g["cls_w"])
    g2 = jax.grad(mlp_loss_weighted)(params2, x, y, w)
    for lp in g2["layers"]:
        assert float(jnp.max(jnp.abs(lp["w"]))) > 0


def test_mlp_cascade_level_serves():
    """An 'mlp' LevelSpec runs end-to-end in the cascade (featurize ->
    predict -> defer -> online updates)."""
    import dataclasses as dc

    from repro.core import OnlineCascade, SimulatedExpert, default_cascade_config
    from repro.core.cascade import LevelSpec
    from repro.data import make_stream
    from repro.models.students import MLPSpec

    stream = make_stream("hatespeech", seed=0, n_samples=96)
    cfg = default_cascade_config(n_classes=2, mu=3e-7, seed=0)
    lvl = LevelSpec(kind="mlp", cost=120.0, cache_size=16, batch_size=8,
                    student_lr=1e-3, beta_decay=0.95,
                    calibration_factor=0.3)
    cfg = dc.replace(cfg, levels=(cfg.levels[0], lvl),
                     mlp_spec=MLPSpec(hidden=64, n_layers=2))
    cas = OnlineCascade(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"))
    m = cas.run(stream)
    assert 0 <= m["predictions"].min() and m["predictions"].max() < 2
    assert m["expert_calls"] <= 96
