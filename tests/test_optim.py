"""Optimizer correctness vs hand-computed references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade gracefully: only property tests skip
    from _hypothesis_stubs import given, settings, st

from repro.optim import adam, adamw, clip_by_global_norm, momentum, ogd_sqrt_t, sgd


def test_sgd_step():
    opt = sgd(0.1)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    s = opt.init(p)
    p2, s = opt.step(p, g, s)
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.95, 2.1])


def test_adam_matches_reference():
    opt = adam(1e-2, b1=0.9, b2=0.999, eps=1e-8)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.3])}
    # manual adam step 1
    m = 0.1 * 0.3
    v = 0.001 * 0.09
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    ref = 1.0 - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    p2, s = opt.step(p, g, s)
    np.testing.assert_allclose(float(p2["w"][0]), ref, rtol=1e-6)


def test_ogd_sqrt_t_schedule():
    """eta_t = eta0 / sqrt(t) — the paper's no-regret rate (Thm 3.1)."""
    opt = ogd_sqrt_t(1.0)
    p = {"w": jnp.array([0.0])}
    s = opt.init(p)
    g = {"w": jnp.array([1.0])}
    p, s = opt.step(p, g, s)      # t=1: step 1.0
    np.testing.assert_allclose(float(p["w"][0]), -1.0, rtol=1e-6)
    p, s = opt.step(p, g, s)      # t=2: step 1/sqrt(2)
    np.testing.assert_allclose(float(p["w"][0]), -1.0 - 2 ** -0.5,
                               rtol=1e-6)


def test_clip_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 5.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert np.isclose(total, 1.0, rtol=1e-5)


def test_adam_bf16_state_dtype():
    # lr must exceed bf16 resolution near 1.0 (~0.0078) to observe motion
    opt = adamw(0.05, state_dtype="bfloat16", weight_decay=0.0)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    s = opt.init(p)
    assert s["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4,), 0.1, jnp.bfloat16)}
    p2, s = opt.step(p, g, s)
    assert p2["w"].dtype == jnp.bfloat16
    assert float(p2["w"][0]) < 1.0


# ---------------------------------------------------------------------------
# step_k (one application standing in for k sequential steps)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt,rtol", [
    (lambda: sgd(0.1), 1e-5), (lambda: momentum(0.05), 1e-5),
    (lambda: adam(1e-2), 1e-5), (lambda: adamw(1e-2), 1e-5),
    # the sqrt-schedule's midpoint-integral closure is ~3.5% off at t=0
    (lambda: ogd_sqrt_t(0.5), 0.05),
])
def test_step_k_of_one_approximates_step(make_opt, rtol):
    """step_k with k=1 must reproduce a single step (same state counters,
    parameters equal to float tolerance — b1**k goes through a traced
    pow, so bitwise equality is not required)."""
    opt = make_opt()
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.3, 0.7])}
    s1 = opt.init(p)
    p_a, s_a = opt.step(p, g, s1)
    p_b, s_b = opt.step_k(p, g, opt.init(p), jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(p_b["w"]), np.asarray(p_a["w"]),
                               rtol=rtol)
    assert int(s_b["count"]) == int(s_a["count"]) == 1


@pytest.mark.parametrize("make_opt,rtol", [
    (lambda: sgd(0.1), 1e-6), (lambda: momentum(0.05), 1e-5),
    (lambda: ogd_sqrt_t(0.5), 0.05), (lambda: adam(1e-2), 0.35),
])
def test_step_k_tracks_k_repeated_steps(make_opt, rtol):
    """On a constant gradient, step_k(k) lands near k composed steps
    (exact for sgd and momentum; the sqrt-integral / EMA closures are
    first-order approximations for the others) and advances counters
    by k."""
    k = 6
    opt = make_opt()
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.4])}
    s = opt.init(p)
    p_seq = p
    for _ in range(k):
        p_seq, s = opt.step(p_seq, g, s)
    p_k, s_k = opt.step_k(p, g, opt.init(p), jnp.float32(k))
    assert int(s_k["count"]) == int(s["count"]) == k
    delta_seq = float(p_seq["w"][0]) - 1.0
    delta_k = float(p_k["w"][0]) - 1.0
    np.testing.assert_allclose(delta_k, delta_seq, rtol=rtol)


@settings(max_examples=20, deadline=None)
@given(lr=st.floats(1e-4, 1e-1), steps=st.integers(1, 30))
def test_momentum_converges_on_quadratic(lr, steps):
    """Property: momentum descent on 0.5*w^2 never diverges for small lr."""
    opt = momentum(lr, beta=0.9)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    for _ in range(steps):
        g = {"w": p["w"]}
        p, s = opt.step(p, g, s)
    assert abs(float(p["w"][0])) <= 1.5
