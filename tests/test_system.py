"""End-to-end behaviour tests for the paper's system (Algorithm 1 at small
scale, baselines, robustness, no-regret trend)."""
import numpy as np
import pytest

from repro.core import (
    OnlineCascade, OnlineEnsemble, SimulatedExpert, default_cascade_config,
    distill_students)
from repro.data import make_stream

N = 1000


@pytest.fixture(scope="module")
def imdb_run():
    stream = make_stream("imdb", seed=0, n_samples=N)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    cfg = default_cascade_config(n_classes=2, mu=3e-7, seed=0)
    cas = OnlineCascade(cfg, expert)
    metrics = cas.run(stream)
    return stream, expert, cas, metrics


def test_cascade_saves_cost_with_usable_accuracy(imdb_run):
    """The paper's headline: comparable accuracy at a fraction of the LLM
    calls.  Passing since the deferral-gate freeze fix (core.deferral:
    beta-floor re-exploration + every-annotation gate calibration) — the
    gates now close mid-stream instead of flapping open on the biased
    hard-case annotations; require real savings and accuracy within 15
    points of the expert."""
    stream, expert, cas, m = imdb_run
    frac_calls = m["expert_calls"] / N
    assert frac_calls < 0.85, f"no savings: {frac_calls}"
    expert_acc = float(np.mean(
        stream.expert_labels("gpt-3.5-turbo") == stream.labels))
    assert m["accuracy"] > expert_acc - 0.15


def test_accuracy_improves_over_stream(imdb_run):
    """Students learn online: accuracy on the last third is well above
    chance (the first third is DAgger-dominated)."""
    stream, _, cas, m = imdb_run
    preds = m["predictions"]
    labels = stream.labels
    third = N // 3
    acc_late = float(np.mean(preds[2 * third:] == labels[2 * third:]))
    assert acc_late > 0.6


def test_later_stream_handled_by_students(imdb_run):
    """Fig 5: over time the majority of queries shift to cheap levels."""
    stream, _, cas, m = imdb_run
    lv = np.array(cas.history["level"])
    n_levels = len(cas.levels)
    early_expert = float(np.mean(lv[:100] == n_levels))
    late_expert = float(np.mean(lv[-300:] == n_levels))
    assert early_expert > 0.9
    assert late_expert < early_expert


def test_cascade_beats_ensemble_ablation():
    """S5.1/S5.2: deferral-policy learning beats the fixed-probability
    ensemble at a matched annotation budget."""
    stream = make_stream("imdb", seed=1, n_samples=N)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    cfg = default_cascade_config(n_classes=2, mu=3e-7, seed=1)
    cas = OnlineCascade(cfg, expert)
    m_cas = cas.run(stream)

    expert2 = SimulatedExpert(stream, "gpt-3.5-turbo")
    ens = OnlineEnsemble(cfg, expert2, expert_prob_decay=0.995)
    m_ens = ens.run(stream, hard_budget=max(m_cas["expert_calls"], 1))
    # cascade must be at least as accurate (small tolerance for noise)
    assert m_cas["accuracy"] >= m_ens["accuracy"] - 0.03


def test_distillation_baseline_runs():
    stream = make_stream("fever", seed=0, n_samples=800)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    res = distill_students(stream, expert, budget_n=300, epochs=3)
    assert 0.3 < res["lr"]["accuracy"] < 1.0
    assert 0.3 < res["tinytf"]["accuracy"] < 1.0


def test_robust_to_length_shift():
    """Table 2: accuracy under length-ascending order stays within a few
    points of the default order."""
    accs = {}
    for order in ("default", "length"):
        stream = make_stream("imdb", seed=2, n_samples=N, order=order)
        expert = SimulatedExpert(stream, "gpt-3.5-turbo")
        cfg = default_cascade_config(n_classes=2, mu=2e-7, seed=2)
        cas = OnlineCascade(cfg, expert)
        accs[order] = cas.run(stream)["accuracy"]
    assert abs(accs["default"] - accs["length"]) < 0.08


def test_average_regret_decreases():
    """Thm 3.2 (empirical): average per-episode cost J/t trends down as
    the policy converges."""
    stream = make_stream("imdb", seed=3, n_samples=N)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    cfg = default_cascade_config(n_classes=2, mu=3e-7, seed=3)
    cas = OnlineCascade(cfg, expert)
    cas.run(stream)
    J = np.array(cas.history["J"])
    avg_early = float(np.mean(J[:N // 4]))
    avg_late = float(np.mean(J[-N // 4:]))
    assert avg_late < avg_early


def test_multiclass_isear():
    stream = make_stream("isear", seed=0, n_samples=800)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    cfg = default_cascade_config(n_classes=7, mu=2e-7, seed=0)
    cas = OnlineCascade(cfg, expert)
    m = cas.run(stream)
    assert m["accuracy"] > 1.0 / 7 + 0.1     # well above chance
    assert m["expert_calls"] <= 800
