"""Chaos suite: the elastic expert fleet's failure semantics.

Everything here rides ``FlakyExpert`` (core/experts.py) — scripted or
seeded per-(submit, shard) faults over a real expert whose labels are
deterministic functions of the items.  That makes the contracts sharp:

* every deferred item is committed exactly once — within its D-tick
  deadline when any retry succeeds, or as an explicitly counted
  ``dropped_annotations`` degradation after ``max_requeues`` — never
  silently, never twice, never deadlocking;
* fault TIMING never changes committed state: a run under injected
  timeouts/deaths whose annotations all eventually land is bitwise the
  fault-free run (requeues re-derive identical labels);
* the opt-in readiness-commit mode stays inside the documented
  commit-age bound while preserving commit order.
"""
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade gracefully: only property tests skip
    from _hypothesis_stubs import given, settings, st

import harness as H
from repro.core import FlakyExpert
from repro.core.experts import (ExpertShardTimeout, ExpertTicket,
                                ExpertWorkerDied, _fault_draw)

N, S = 64, 4
MU = 3e-6


def _setup(n=N, dataset="hatespeech"):
    return H.make_setup(mu=MU, n=n, dataset=dataset)


def _run(engine, stream, n_ticks):
    outs = H.run_ticks(engine, stream, 0, n_ticks)
    return H.finish_run(engine, outs)


# ---------------------------------------------------------------------------
# ticket-level failure primitives
# ---------------------------------------------------------------------------
def test_ticket_replace_splices_requeued_shard():
    t = ExpertTicket(shards=[(0, 2, np.array([1, 2], np.int32)),
                             (2, 4, np.array([3, 4], np.int32))])
    t.replace(2, 4, ExpertTicket(labels=np.array([7, 8], np.int32)))
    np.testing.assert_array_equal(t.result(), [1, 2, 7, 8])


def test_ticket_force_resolve_drops_to_sentinel():
    t = ExpertTicket(shards=[(0, 3, np.array([1, 2, 3], np.int32))])
    t.force_resolve(0, 3, np.full(3, -1, np.int32))
    np.testing.assert_array_equal(t.result(), [-1, -1, -1])


def test_flaky_timeout_shard_raises_expert_shard_timeout():
    stream, _ = _setup(8)
    ex = FlakyExpert(H.make_expert(stream, workers=2),
                     schedule=lambda seq, j: "timeout" if j == 0 else None)
    ticket = ex.submit_many(list(range(8)), [stream.docs[i]
                                             for i in range(8)])
    with pytest.raises(ExpertShardTimeout) as ei:
        ticket.result_slice(0, 8, timeout=0.01)
    assert (ei.value.lo, ei.value.hi) == (0, 4)
    assert ex.injected["timeout"] == 1


def test_flaky_dead_worker_raises_expert_worker_died():
    stream, _ = _setup(8)
    ex = FlakyExpert(H.make_expert(stream, workers=2),
                     schedule=lambda seq, j: "die" if j == 1 else None)
    ticket = ex.submit_many(list(range(8)), [stream.docs[i]
                                             for i in range(8)])
    # the dead shard reports done (its future is settled with an error)
    assert ticket.item_done(4)
    with pytest.raises(ExpertWorkerDied):
        ticket.result_slice(4, 8)


def test_fault_draws_are_replayable():
    draws = [_fault_draw(7, seq, j, "t") for seq in range(20)
             for j in range(4)]
    again = [_fault_draw(7, seq, j, "t") for seq in range(20)
             for j in range(4)]
    assert draws == again
    assert all(0.0 <= d < 1.0 for d in draws)
    assert len(set(draws)) > 50          # actually varies per cell


# ---------------------------------------------------------------------------
# kill-a-worker mid-ticket: requeue lands the SAME labels on time
# ---------------------------------------------------------------------------
def test_kill_worker_mid_ticket_requeue_restores_labels():
    """A worker dying mid-ticket requeues its shard; the retry derives
    identical labels, so the run is bitwise the fault-free one and
    nothing is dropped."""
    stream, cfg = _setup()
    n_ticks = N // S
    clean = H.batched_engine(cfg, stream, n_streams=S, max_delay=2,
                             expert_kw={"workers": 2})
    clean_outs = _run(clean, stream, n_ticks)

    # die on the first attempt of submit 3's shard 0; retries (fresh
    # submit seqs) succeed
    deaths = []

    def schedule(seq, j):
        if seq == 3 and j == 0:
            deaths.append(seq)
            return "die"
        return None

    chaos = H.flaky_engine(cfg, stream, n_streams=S, max_delay=2,
                           expert_kw={"workers": 2},
                           flaky_kw={"schedule": schedule})
    chaos_outs = _run(chaos, stream, n_ticks)

    assert chaos.expert.injected["die"] == len(deaths) == 1
    assert chaos.fault_stats["worker_deaths"] == 1
    assert chaos.fault_stats["requeues"] == 1
    assert chaos.fault_stats["dropped_annotations"] == 0
    a, b = H.collate_outputs(clean_outs), H.collate_outputs(chaos_outs)
    for key in ("predictions", "levels", "expert_called"):
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)
    H.assert_state_equal(clean.levels, chaos.levels)
    # requeues are not re-counted: annotation was costed at route time
    assert (H.expert_calls_total(clean) == H.expert_calls_total(chaos))


# ---------------------------------------------------------------------------
# timeout -> requeue exactly-once commit (property + concrete twin)
# ---------------------------------------------------------------------------
def _chaos_run_commits_exactly_once(fail_cells, max_requeues):
    """Shared body: run a chaos schedule, assert the exactly-once commit
    accounting, and return the engine (for further assertions).

    ``fail_cells`` maps a submit sequence to how many consecutive
    attempts of its shard 0 fail (requeues get fresh seqs, so attempt r
    of original submit q is approximated by failing ANY submit whose
    seq is in the scripted set — the count discipline below only needs
    "fails then eventually succeeds-or-drops").
    """
    stream, cfg = _setup()
    n_ticks = N // S
    attempts = {}

    def schedule(seq, j):
        if j != 0:
            return None
        budget = fail_cells.get(seq % 7, 0)
        seen = attempts.get(seq, 0)
        attempts[seq] = seen + 1
        return "timeout" if seen < budget else None

    eng = H.flaky_engine(cfg, stream, n_streams=S, max_delay=2,
                         expert_kw={"workers": 2},
                         flaky_kw={"schedule": schedule},
                         expert_timeout=0.01, max_requeues=max_requeues)
    outs = _run(eng, stream, n_ticks)
    col = H.collate_outputs(outs)
    # exactly-once: every item commits exactly once -> one output row
    # per stream item, and the deferred accounting balances exactly
    assert col["predictions"].shape == (N,)
    assert np.all(col["predictions"] >= 0)
    assert len(eng._pending) == 0 and len(eng._ring) == 0
    fs = eng.fault_stats
    # every timeout event either requeued or terminated in a drop —
    # no fault event vanishes without an accounted outcome
    assert fs["requeues"] <= fs["timeouts"]
    if fs["dropped_annotations"] == 0:
        assert fs["requeues"] == fs["timeouts"]
    return eng, col


def test_timeout_requeue_exactly_once_concrete():
    """Concrete twin of the property: one scripted timeout, generous
    max_requeues — no drop, bitwise the clean run."""
    stream, cfg = _setup()
    n_ticks = N // S
    clean = H.batched_engine(cfg, stream, n_streams=S, max_delay=2,
                             expert_kw={"workers": 2})
    clean_outs = _run(clean, stream, n_ticks)

    first = {}

    def schedule(seq, j):
        # first attempt of every 5th submit's shard 0 times out
        if j == 0 and seq % 5 == 0 and seq not in first:
            first[seq] = True
            return "timeout"
        return None

    eng = H.flaky_engine(cfg, stream, n_streams=S, max_delay=2,
                         expert_kw={"workers": 2},
                         flaky_kw={"schedule": schedule},
                         expert_timeout=0.01, max_requeues=3)
    outs = _run(eng, stream, n_ticks)
    assert eng.fault_stats["requeues"] == eng.fault_stats["timeouts"] > 0
    assert eng.fault_stats["dropped_annotations"] == 0
    a, b = H.collate_outputs(clean_outs), H.collate_outputs(outs)
    for key in ("predictions", "levels", "expert_called"):
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)
    H.assert_state_equal(clean.levels, eng.levels)


@settings(max_examples=15, deadline=None)
@given(fail_seqs=st.dictionaries(st.integers(0, 6), st.integers(1, 4),
                                 max_size=4),
       max_requeues=st.integers(0, 3))
def test_timeout_requeue_exactly_once_property(fail_seqs, max_requeues):
    """Property: whatever the (timeout schedule, max_requeues) draw,
    every deferred item commits exactly once — either a real label
    within its deadline or a counted drop — and the engine terminates
    with empty queues (no deadlock, no silent drop)."""
    eng, col = _chaos_run_commits_exactly_once(fail_seqs, max_requeues)
    fs = eng.fault_stats
    # drops only happen after exhausting the requeue budget
    if max_requeues >= 5:
        assert fs["dropped_annotations"] == 0
    assert fs["requeues"] <= fs["timeouts"]
    eng.close()


# ---------------------------------------------------------------------------
# max_requeues graceful degradation: never deadlocks, drops are counted
# ---------------------------------------------------------------------------
def test_max_requeues_graceful_degradation_never_deadlocks():
    """An always-failing shard exhausts its requeue budget and degrades:
    the lane commits its provisional student answer, the loss is counted
    in dropped_annotations, and the run terminates."""
    stream, cfg = _setup()
    n_ticks = N // S

    def schedule(seq, j):
        return "timeout"          # EVERY shard of EVERY submit hangs

    eng = H.flaky_engine(cfg, stream, n_streams=S, max_delay=2,
                         expert_kw={"workers": 2},
                         flaky_kw={"schedule": schedule},
                         expert_timeout=0.01, max_requeues=2)
    done = threading.Event()
    box = {}

    def drive():
        box["outs"] = _run(eng, stream, n_ticks)
        done.set()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    assert done.wait(timeout=300), "chaos run deadlocked"
    col = H.collate_outputs(box["outs"])
    assert col["predictions"].shape == (N,)
    assert np.all(col["predictions"] >= 0)   # provisional answers stand
    fs = eng.fault_stats
    assert fs["dropped_annotations"] > 0
    # every drop exhausted its requeue budget first (max_requeues=2
    # retries per shard before the terminal force-resolve)
    assert fs["requeues"] > 0
    assert fs["requeues"] < fs["timeouts"]
    assert len(eng._pending) == 0 and len(eng._ring) == 0
    # drops never update the student: expert_calls still counts routed
    # items, but the cache never saw the dropped labels — just assert
    # the engine is still servable afterwards
    eng.reset()
    assert eng.fault_stats["dropped_annotations"] == 0


def test_zero_max_requeues_drops_immediately():
    stream, cfg = _setup(16)
    eng = H.flaky_engine(cfg, stream, n_streams=S, max_delay=2,
                         expert_kw={"workers": 2},
                         flaky_kw={"schedule": lambda q, j: "die"},
                         max_requeues=0)
    outs = _run(eng, stream, 16 // S)
    col = H.collate_outputs(outs)
    assert col["predictions"].shape == (16,)
    assert eng.fault_stats["requeues"] == 0
    assert eng.fault_stats["dropped_annotations"] > 0


# ---------------------------------------------------------------------------
# deterministic default schedule is bitwise invariant to injected latency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("flaky_kw", [
    {"slow_rate": 0.5, "slow_credits": 3, "seed": 11},
    {"schedule": lambda seq, j: ("slow", 5) if seq % 3 == 0 else None},
])
def test_bitwise_invariant_to_injected_latency(flaky_kw):
    """Slow shards shift WHEN labels become observable, never what they
    are; the deterministic lanes_due commit schedule depends only on
    tick age — so the run is bitwise the fault-free one."""
    stream, cfg = _setup()
    n_ticks = N // S
    clean = H.batched_engine(cfg, stream, n_streams=S, max_delay=2,
                             per_lane=True, expert_kw={"workers": 2})
    clean_outs = _run(clean, stream, n_ticks)
    chaos = H.flaky_engine(cfg, stream, n_streams=S, max_delay=2,
                           per_lane=True, expert_kw={"workers": 2},
                           flaky_kw=flaky_kw)
    chaos_outs = _run(chaos, stream, n_ticks)
    assert chaos.expert.injected["slow"] > 0
    a, b = H.collate_outputs(clean_outs), H.collate_outputs(chaos_outs)
    for key in ("predictions", "levels", "expert_called"):
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)
    H.assert_state_equal(clean.levels, chaos.levels)
    np.testing.assert_array_equal(np.asarray(clean.expert_calls),
                                  np.asarray(chaos.expert_calls))


def test_bitwise_invariant_to_fault_timing_with_recovery():
    """Timeout-then-recover chaos (all annotations eventually land)
    commits bitwise-identical state: requeues re-derive the same
    labels, so only PERMANENT drops may ever diverge a run."""
    stream, cfg = _setup()
    n_ticks = N // S
    clean = H.batched_engine(cfg, stream, n_streams=S, max_delay=2,
                             expert_kw={"workers": 2})
    clean_outs = _run(clean, stream, n_ticks)
    seen = set()

    def schedule(seq, j):
        if j == 1 and seq % 4 == 1 and seq not in seen:
            seen.add(seq)
            return "timeout"
        return None

    chaos = H.flaky_engine(cfg, stream, n_streams=S, max_delay=2,
                           expert_kw={"workers": 2},
                           flaky_kw={"schedule": schedule},
                           expert_timeout=0.01, max_requeues=4)
    chaos_outs = _run(chaos, stream, n_ticks)
    assert chaos.fault_stats["requeues"] > 0
    assert chaos.fault_stats["dropped_annotations"] == 0
    a, b = H.collate_outputs(clean_outs), H.collate_outputs(chaos_outs)
    np.testing.assert_array_equal(a["predictions"], b["predictions"])
    H.assert_state_equal(clean.levels, chaos.levels)


# ---------------------------------------------------------------------------
# readiness commits: opt-in early drain inside the age bound
# ---------------------------------------------------------------------------
def test_readiness_commits_within_age_bound():
    """readiness_commits=True may commit a lane as soon as its
    annotation lands (age 0: ready within the submit tick) but never
    past the deterministic deadline — every commit age is in [0, D]."""
    stream, cfg = _setup()
    D = 3
    eng = H.batched_engine(cfg, stream, n_streams=S, max_delay=D,
                           expert_kw={"workers": 2},
                           readiness_commits=True)
    _run(eng, stream, N // S)
    cs = eng.commit_stats
    assert cs["lanes"] > 0
    assert 0 <= cs["age_max"] <= D
    assert cs["age_sum"] / cs["lanes"] <= D


def test_readiness_commits_beat_deadline_with_fast_expert():
    """With a zero-latency expert, readiness mode commits strictly
    earlier on average than the deterministic deadline schedule (that is
    its point), while predictions per item may differ only through the
    documented earlier-update trajectory."""
    stream, cfg = _setup()
    D = 3
    base = H.batched_engine(cfg, stream, n_streams=S, max_delay=D,
                            expert_kw={"workers": 2})
    _run(base, stream, N // S)
    eager = H.batched_engine(cfg, stream, n_streams=S, max_delay=D,
                             expert_kw={"workers": 2},
                             readiness_commits=True)
    _run(eager, stream, N // S)
    b, e = base.commit_stats, eager.commit_stats
    # earlier commits shift updates earlier, which legitimately changes
    # later routing — so deferral COUNTS may differ; the contract is the
    # age distribution: readiness commits strictly beat the deadline
    # schedule on average and never exceed its bound
    assert b["lanes"] > 0 and e["lanes"] > 0
    assert e["age_sum"] / e["lanes"] < b["age_sum"] / b["lanes"]
    assert e["age_max"] <= b["age_max"] <= D


def test_readiness_commits_hung_shard_falls_to_deadline():
    """A hung shard cannot be committed early; readiness mode falls back
    to the D-tick deadline and the requeue path — never earlier, never
    deadlocked."""
    stream, cfg = _setup()
    seen = set()

    def schedule(seq, j):
        if seq % 6 == 2 and seq not in seen:
            seen.add(seq)
            return "timeout"
        return None

    eng = H.flaky_engine(cfg, stream, n_streams=S, max_delay=3,
                         expert_kw={"workers": 2},
                         flaky_kw={"schedule": schedule},
                         expert_timeout=0.01, max_requeues=3,
                         readiness_commits=True)
    outs = _run(eng, stream, N // S)
    col = H.collate_outputs(outs)
    assert col["predictions"].shape == (N,)
    assert eng.commit_stats["age_max"] <= 3
    assert len(eng._pending) == 0


# ---------------------------------------------------------------------------
# autoscaling: deterministic tick-boundary decisions
# ---------------------------------------------------------------------------
def test_autoscale_decisions_are_deterministic():
    stream, cfg = _setup()

    def build():
        return H.batched_engine(cfg, stream, n_streams=S, max_delay=2,
                                expert_kw={"workers": "auto"},
                                autoscale=(1, 4))

    a, b = build(), build()
    _run(a, stream, N // S)
    _run(b, stream, N // S)
    assert a.fleet_log == b.fleet_log
    assert a.expert.workers == b.expert.workers
    H.assert_state_equal(a.levels, b.levels)


def test_autoscale_matches_fixed_width_bitwise():
    """Autoscaling only resizes future shard layouts; labels are
    item-deterministic, so the run is bitwise a fixed-width run."""
    stream, cfg = _setup()
    fixed = H.batched_engine(cfg, stream, n_streams=S, max_delay=2,
                             expert_kw={"workers": 2})
    fixed_outs = _run(fixed, stream, N // S)
    auto = H.batched_engine(cfg, stream, n_streams=S, max_delay=2,
                            expert_kw={"workers": "auto"},
                            autoscale=(1, 4))
    auto_outs = _run(auto, stream, N // S)
    a, b = H.collate_outputs(fixed_outs), H.collate_outputs(auto_outs)
    for key in ("predictions", "levels", "expert_called"):
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)
    H.assert_state_equal(fixed.levels, auto.levels)


# ---------------------------------------------------------------------------
# pool lifecycle: reset()/close() shut the expert pool down (leak check)
# ---------------------------------------------------------------------------
def test_model_expert_pool_closed_on_engine_reset():
    """Regression: the engine's reset()/teardown must close the expert's
    worker pool — thread count returns to baseline instead of leaking
    one pool per reset."""
    from repro.core.experts import ModelExpert
    from repro.models.students import tinytf_init, TinyTFSpec
    import jax
    spec = TinyTFSpec(vocab=64, max_len=8, d_model=16, n_heads=2,
                      n_layers=1, d_ff=32, n_classes=2)
    params = tinytf_init(jax.random.PRNGKey(0), spec)
    stream, cfg = _setup(16)
    before = threading.active_count()
    for _ in range(3):
        ex = ModelExpert(params=params, spec=spec, workers=2)
        eng = H.batched_engine(cfg, stream, n_streams=S, max_delay=2)
        eng.expert = ex
        # spin the pool up, then tear down through the engine paths
        ex.poll(ex.submit_many([0, 1],
                               [stream.docs[0], stream.docs[1]]))
        assert threading.active_count() > before
        eng.reset()
        assert ex._executor is None or ex._executor._shutdown
    # pools closed: no thread leak across 3 engine generations
    assert threading.active_count() <= before + 1


def test_engine_close_is_idempotent():
    stream, cfg = _setup(16)
    eng = H.batched_engine(cfg, stream, n_streams=S)
    eng.close()
    eng.close()
    eng.reset()


def test_model_expert_process_backend_matches_thread():
    """backend="process" spawns annotator children that produce labels
    identical to the thread pool (same params, same shard layout), and
    close() reaps them."""
    from repro.core.experts import ModelExpert
    from repro.models.students import tinytf_init, TinyTFSpec
    import jax
    stream, _ = _setup(8)
    spec = TinyTFSpec(vocab=64, max_len=8, d_model=16, n_heads=2,
                      n_layers=1, d_ff=32, n_classes=2)
    params = tinytf_init(jax.random.PRNGKey(0), spec)
    th = ModelExpert(params=params, spec=spec, workers=2,
                     backend="thread")
    pr = ModelExpert(params=params, spec=spec, workers=2,
                     backend="process")
    idxs, docs = list(range(8)), stream.docs[:8]
    try:
        a = th.poll(th.submit_many(idxs, docs))
        b = pr.poll(pr.submit_many(idxs, docs))
        np.testing.assert_array_equal(a, b)
    finally:
        pr.close()
        th.close()
    assert pr._executor is None or pr._executor._shutdown_thread
