"""Lane-sharded BatchedCascadeEngine: parity with the unsharded engine
on identical tick keys, and reuse of a compiled sharded engine across
streams.  Parity assertions live in tests/harness.py; the
8-virtual-device run executes in a subprocess so the XLA device-count
flag never leaks into this test process (same pattern as
test_sharding.py)."""
import os
import subprocess
import sys

import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as shd


# ---------------------------------------------------------------------------
# lane-sharding rules (single-device, cheap)
# ---------------------------------------------------------------------------
def test_lane_spec_rules():
    import jax
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    assert shd.lane_spec(mesh) == P(("data",))
    assert shd.lane_count(mesh) == 1
    mesh_nm = Mesh(devs.reshape(1, 1), ("model", "x"))
    assert shd.lane_spec(mesh_nm) == P()      # no batch-like axis


def test_put_lanes_places_on_mesh():
    import jax
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    x = np.arange(8, dtype=np.float32)
    y = shd.put_lanes(x, mesh)
    np.testing.assert_array_equal(np.asarray(y), x)
    z = shd.put_replicated(np.float32(3.0), mesh)
    assert float(z) == 3.0


# ---------------------------------------------------------------------------
# 8-virtual-device parity (subprocess)
# ---------------------------------------------------------------------------
SHARDED_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
sys.path.insert(0, {tests!r})
import numpy as np, jax
assert len(jax.devices()) == 8
from harness import assert_run_parity, batched_engine, make_setup
from repro.launch.mesh import make_mesh

n, S = 384, 64
stream, cfg = make_setup(3e-6, n, dataset="imdb", seed=0)
mesh = make_mesh((8, 1), ("data", "model"))

# n_streams must divide over the lane axis
try:
    batched_engine(cfg, stream, n_streams=12, mesh=mesh)
    raise SystemExit("expected ValueError for n_streams=12 on data=8")
except ValueError:
    pass

base = batched_engine(cfg, stream, n_streams=S)
m0 = base.run(stream)
# max_delay=0 explicitly: the async-capable route/commit engine must be
# bit-identical to the synchronous reference on the mesh too
shard = batched_engine(cfg, stream, n_streams=S, mesh=mesh, max_delay=0)
m1 = shard.run(stream)

# same tick keys => identical routing decisions and expert usage; final
# parameters agree to float tolerance (SPMD partitioning may
# reassociate the weighted-update reductions at the ulp level)
assert_run_parity(base, m0, shard, m1, state="allclose",
                  attrs=("params", "dparams"))
np.testing.assert_array_equal(base.expert_calls, shard.expert_calls)

# a compiled sharded engine serves a fresh stream after reset() with the
# exact same trajectory (the serving reuse path: warm once, serve many)
shard.reset()
m2 = shard.run(stream)
np.testing.assert_array_equal(m1["predictions"], m2["predictions"])
assert m1["expert_calls"] == m2["expert_calls"]

# partial final tick (n not a multiple of S) exercises the replicated
# fallback placement for non-divisible lane batches
stream2, _ = make_setup(3e-6, 100, dataset="imdb", seed=1)
shard.reset()
m3 = shard.run(stream2)
assert len(m3["predictions"]) == 100
assert int(shard.items_seen.sum()) == 100

# async bounded-delay serving on the mesh: same warmed engine (the jits
# are delay-independent), annotations land within 2 ticks, the queue
# drains at stream end, and every item is served exactly once
shard.max_delay = 2
shard.reset()
m4 = shard.run(stream)
assert len(shard._pending) == 0
assert int(shard.items_seen.sum()) == n
assert m4["expert_calls"] > 0
print("SHARDED-PARITY-OK")
"""


def test_sharded_engine_parity_8dev():
    """S=64 lanes over an 8-virtual-device (data, model) mesh: identical
    predictions, chosen levels, and expert-call counts as the unsharded
    engine; final params allclose; reset() reuse across streams."""
    here = os.path.dirname(__file__)
    src = os.path.abspath(os.path.join(here, "..", "src"))
    code = SHARDED_SNIPPET.format(src=src, tests=os.path.abspath(here))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-PARITY-OK" in proc.stdout
