"""Async expert queue (``BatchedCascadeEngine(max_delay=...)``) and the
serving-semantics bugfix batch: parity at max_delay=0, bounded-delay
update semantics, probe-route exactness under sampled actions, reorder
annotation stability, fallback costing, and bounded history.  Parity
assertions live in tests/harness.py."""
import jax
import numpy as np
import pytest

from harness import (assert_run_parity, batched_engine, make_setup,
                     run_pair, sequential_engine)
from repro.core import OnlineCascade, SimulatedExpert
from repro.data import make_stream
from repro.launch.serve import probe_route


# ---------------------------------------------------------------------------
# max_delay=0: the synchronous engine, bit for bit
# ---------------------------------------------------------------------------
def test_delay0_bitwise_parity_s1():
    """The async-capable engine at max_delay=0 must stay bit-identical to
    the sequential reference (predictions, levels, expert calls, params,
    opt state, per-item costs) — the acceptance contract for the
    route/commit split."""
    stream, cfg = make_setup(3e-6, 300)
    seq = sequential_engine(cfg, stream)
    bat = batched_engine(cfg, stream, n_streams=1, max_delay=0)
    m_seq, m_bat = run_pair(seq, bat, stream)
    assert_run_parity(seq, m_seq, bat, m_bat, costs=True)


# ---------------------------------------------------------------------------
# bounded-delay semantics
# ---------------------------------------------------------------------------
def test_bounded_delay_update_timing():
    """With max_delay=D, a tick's annotations commit exactly D ticks
    later: provisional predictions go out immediately (expert_labels
    report -1), no update lands before the delay elapses, and the queue
    never holds more than D routed ticks."""
    S, D = 8, 2
    stream, cfg = make_setup(3e-7, 64, dataset="hatespeech")
    bat = batched_engine(cfg, stream, n_streams=S, max_delay=D)
    init = [jax.tree.leaves(lvl._init_state[0]) for lvl in bat.levels]

    def params_at_init():
        return all(
            bool(np.array_equal(np.asarray(x), np.asarray(y)))
            for lvl, leaves in zip(bat.levels, init)
            for x, y in zip(jax.tree.leaves(lvl.params), leaves))

    # tick 1: beta0 == 1 -> every lane DAgger-jumps and is submitted
    out = bat.process_tick(range(S), stream.docs[:S])
    assert out["expert_called"].all()
    assert (out["expert_labels"] == -1).all()       # still in flight
    assert len(bat._pending) == 1
    assert params_at_init()                          # nothing landed yet
    # tick 2: still within the delay bound
    bat.process_tick(range(S, 2 * S), stream.docs[S:2 * S])
    assert len(bat._pending) == 2
    assert params_at_init()
    # tick 3: tick 1's annotations land (end of tick 1 + D)
    bat.process_tick(range(2 * S, 3 * S), stream.docs[2 * S:3 * S])
    assert len(bat._pending) == 2                    # bounded depth
    assert not params_at_init()                      # update applied
    assert bat._cache_n[0] > 0
    # flush drains the rest deterministically
    assert bat.flush() == 2
    assert len(bat._pending) == 0


def test_delay_bound_holds_without_further_expert_ticks():
    """The delay bound is measured in TICKS, not expert-calling ticks: a
    routed tick's annotations must commit at the end of tick t + D even
    when no later tick calls the expert (the converged regime's trickle
    annotations must not be starved)."""
    S, D = 8, 2
    # hard_budget == S: only tick 1 can call the expert; later ticks
    # route with the budget exhausted and never submit
    stream, cfg = make_setup(3e-7, 5 * S, hard_budget=S)
    bat = batched_engine(cfg, stream, n_streams=S, max_delay=D)
    out1 = bat.process_tick(range(S), stream.docs[:S])
    assert out1["expert_called"].all()
    out2 = bat.process_tick(range(S, 2 * S), stream.docs[S:2 * S])
    assert not out2["expert_called"].any()          # budget exhausted
    assert len(bat._pending) == 1                   # age 1 < D: pending
    bat.process_tick(range(2 * S, 3 * S), stream.docs[2 * S:3 * S])
    assert len(bat._pending) == 0                   # age D: committed
    assert bat._cache_n[0] > 0


def test_bounded_delay_annotations_are_delay_invariant():
    """Delay shifts when updates land, never which labels a called item
    gets: committed ring-buffer labels equal the simulated expert's
    table for the called items, same as the synchronous engine."""
    S = 8
    stream, cfg = make_setup(3e-7, S, dataset="imdb")
    bat = batched_engine(cfg, stream, n_streams=S, max_delay=3)
    out = bat.process_tick(range(S), stream.docs[:S])
    assert out["expert_called"].all()
    bat.flush()
    table = stream.expert_labels("gpt-3.5-turbo")
    got = np.asarray(bat._cache_y[0])
    size = bat.levels[0].spec.cache_size
    expect = np.zeros(size, np.int32)
    for j in range(S):
        expect[j % size] = table[j]
    np.testing.assert_array_equal(got, expect)


def test_bounded_delay_accuracy_regression():
    """1k imdb, S=16: serving with a 2-tick annotation delay must stay
    within 5 accuracy points of the synchronous engine (the provisional
    answers on deferred lanes are the only source of divergence)."""
    stream, cfg = make_setup(3e-6, 1000)
    sync = batched_engine(cfg, stream, n_streams=16, max_delay=0)
    m_sync = sync.run(stream)
    asyn = batched_engine(cfg, stream, n_streams=16, max_delay=2)
    m_async = asyn.run(stream)
    assert len(asyn._pending) == 0                   # run() flushed
    assert m_async["accuracy"] >= m_sync["accuracy"] - 0.05, (
        f"async accuracy {m_async['accuracy']:.4f} fell more than 5 points "
        f"below sync {m_sync['accuracy']:.4f}")


def test_max_delay_validated():
    stream, cfg = make_setup(3e-7, 8)
    with pytest.raises(ValueError):
        batched_engine(cfg, stream, n_streams=8, max_delay=-1)


# ---------------------------------------------------------------------------
# probe-route exactness under sampled actions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sample_actions", [False, True])
def test_probe_route_exact(sample_actions):
    """The probe must reproduce the replay pass's routing exactly —
    including the sampled-action draws when cfg.sample_actions is on
    (it previously thresholded at 0.5 and never drew u_act, degrading
    the micro-batched sequential engine to single-call fallbacks)."""
    stream, cfg = make_setup(3e-7, 120, dataset="hatespeech",
                             sample_actions=sample_actions)
    cascade = OnlineCascade(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"))
    mispredicts = 0
    for i, doc in enumerate(stream.docs):
        probe = probe_route(cascade, doc, cascade.t + 1)
        out = cascade.process(i, doc)
        mispredicts += int(probe != out["expert_called"])
    # no state changes between probe and process -> the probe is an oracle
    assert mispredicts == 0


# ---------------------------------------------------------------------------
# reorder annotation stability
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("order", ["length", "category"])
def test_reorder_annotation_stability(order):
    """The same doc must receive the same simulated-LLM annotation in
    every stream order (flip/wrong-class draws are tied to the doc's
    original index, not its stream position)."""
    base = make_stream("isear", seed=3, n_samples=400)
    shifted = base.reorder(order)
    e_base = base.expert_labels("gpt-3.5-turbo")
    e_shift = shifted.expert_labels("gpt-3.5-turbo")
    np.testing.assert_array_equal(e_base[shifted.orig_idx], e_shift)
    # and the overall teacher quality is order-invariant by construction
    assert (np.mean(e_base == base.labels)
            == np.mean(e_shift == shifted.labels))


# ---------------------------------------------------------------------------
# budget-overflow fallback costing
# ---------------------------------------------------------------------------
def test_overflow_fallback_forward_is_costed():
    """Lanes that lose the tick-granular budget race fall back to the
    last student; that forward is real compute and must show up in
    cost_units (it used to be free)."""
    S, hb = 16, 4
    stream, cfg = make_setup(3e-7, S, hard_budget=hb)
    bat = batched_engine(cfg, stream, n_streams=S)
    # tick 1: beta0 == 1 -> all S lanes jump; only hb win the budget
    out = bat.process_tick(range(S), stream.docs[:S])
    called = out["expert_called"]
    assert called.sum() == hb
    last_cost = cfg.levels[-1].cost
    # overflow lanes evaluated no cascade level (they jumped), so their
    # whole cost is the fallback forward at the last level
    np.testing.assert_allclose(out["cost_units"][~called], last_cost)
    np.testing.assert_allclose(out["cost_units"][called], cfg.expert_cost)
    assert (out["levels"][~called] == len(cfg.levels) - 1).all()


# ---------------------------------------------------------------------------
# bounded history
# ---------------------------------------------------------------------------
def test_history_limit_bounds_memory():
    S, ticks = 4, 12
    stream, cfg = make_setup(3e-7, S * ticks)
    capped = batched_engine(cfg, stream, n_streams=S, history_limit=5)
    off = batched_engine(cfg, stream, n_streams=S, history_limit=0)
    assert off.history is None
    for tk in range(ticks):
        idxs = list(range(tk * S, (tk + 1) * S))
        docs = [stream.docs[i] for i in idxs]
        capped.process_tick(idxs, docs)
        off.process_tick(idxs, docs)
    assert len(capped.history["level"]) == 5
    assert int(capped.items_seen.sum()) == S * ticks   # aggregates intact
    assert int(off.items_seen.sum()) == S * ticks

    seq = sequential_engine(cfg, stream, history_limit=3)
    for i in range(8):
        seq.process(i, stream.docs[i])
    assert len(seq.history["pred"]) == 3
    with pytest.raises(ValueError):
        OnlineCascade(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                      history_limit=-2)
