"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_architectures
from repro.models import transformer as tf
from repro.models.layers import padded_vocab
from repro.optim import adamw

ARCHS = list_architectures()
B, S = 2, 32


def _batch(cfg, key, seq=S):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, seq), 0, cfg.vocab),
        "targets": jax.random.randint(ks[1], (B, seq), 0, cfg.vocab),
    }
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(ks[2], (B, seq, cfg.d_model),
                                            jnp.float32)
    if cfg.vision_stub:
        batch["image_embeds"] = jax.random.normal(
            ks[3], (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = tf.forward(params, batch, cfg)
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    state = opt.init(params)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: tf.train_loss(p, batch, cfg), has_aux=True)(params)
        params, state = opt.step(params, grads, state)
        return loss, params, state

    loss, params, state = step(params, state, batch)
    assert np.isfinite(float(loss))
    # a second step must further decrease... at least stay finite
    loss2, params, state = step(params, state, batch)
    assert np.isfinite(float(loss2))
    assert float(loss2) < float(loss) + 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    toks = batch["tokens"]
    logits_full, _ = tf.forward(params, batch, cfg)
    pre = dict(batch)
    del pre["targets"]
    pre["tokens"] = toks[:, :S - 1]
    last, cache = tf.prefill(params, pre, cfg, cache_len=S)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, S - 2]),
                               rtol=6e-2, atol=6e-2)
    logits_dec, cache = tf.decode_step(params, cache, toks[:, S - 1:S],
                                       jnp.int32(S - 1), cfg)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=6e-2, atol=6e-2)
    assert not any(bool(jnp.any(jnp.isnan(leaf)))
                   for leaf in jax.tree.leaves(cache)
                   if jnp.issubdtype(leaf.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "mamba2-370m": (48, 1024, None, None, 0, 50280),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    }[arch]
    layers, d, heads, kv, ff, vocab = expected
    assert cfg.n_layers == layers
    assert cfg.d_model == d
    assert cfg.d_ff == ff or (cfg.moe and cfg.moe.d_ff_expert == ff)
    assert cfg.vocab == vocab
    if heads is not None:
        assert cfg.attn.n_heads == heads
        assert cfg.attn.n_kv_heads == kv
    else:
        assert cfg.attn is None and cfg.ssm is not None
        assert cfg.ssm.d_state == 128


def test_moe_configs():
    m = get_config("mixtral-8x22b").moe
    assert (m.num_experts, m.top_k) == (8, 2)
    d = get_config("dbrx-132b").moe
    assert (d.num_experts, d.top_k) == (16, 4)
    j = get_config("jamba-1.5-large-398b")
    assert (j.moe.num_experts, j.moe.top_k) == (16, 2)
    # jamba: 1:7 attention:mamba interleave
    assert j.period.count("attn") == 1 and j.period.count("mamba") == 7


def test_param_counts_roughly_match_names():
    assert 1.5e9 < get_config("internlm2-1.8b").param_count() < 2.2e9
    assert 3.5e9 < get_config("h2o-danube-3-4b").param_count() < 4.5e9
    assert 7e9 < get_config("qwen3-8b").param_count() < 9e9
    assert 3.7e11 < get_config("llama3-405b").param_count() < 4.4e11
    assert 1.2e11 < get_config("dbrx-132b").param_count() < 1.45e11
    assert 1.3e11 < get_config("mixtral-8x22b").param_count() < 1.5e11
    assert 3e8 < get_config("mamba2-370m").param_count() < 4.5e8
    assert 3.5e11 < get_config("jamba-1.5-large-398b").param_count() < 4.4e11
