"""Pipelined route passes (``BatchedCascadeEngine(pipeline_depth=...)``):
depth-0 bitwise parity with the sequential reference, exact P>0 routing
parity (predictions/levels/expert calls/params), update-tick fencing,
hard-budget fencing, composition with the async expert queue, and the
submit/resolve driver API.  Parity assertions live in tests/harness.py;
the 8-virtual-device mesh variant runs a subprocess snippet (same
pattern as tests/test_sharded.py) that imports the same harness."""
import os
import subprocess
import sys

import numpy as np
import pytest

from harness import (assert_run_parity, batched_engine, make_setup,
                     run_pair, sequential_engine)

PIPE_PARITY_KEYS = ("level", "expert_called")


def _engine(cfg, stream, S, P, D=0):
    return batched_engine(cfg, stream, n_streams=S, pipeline_depth=P,
                          max_delay=D)


# ---------------------------------------------------------------------------
# depth 0: the pre-pipeline engine, bit for bit
# ---------------------------------------------------------------------------
def test_depth0_bitwise_parity_s1():
    """pipeline_depth=0 must stay bit-identical to the sequential
    reference at S=1 — predictions, levels, per-item costs, expert
    calls, params AND optimizer state (the acceptance contract for the
    dispatch/resolve split of the route pass)."""
    stream, cfg = make_setup(3e-6, 300)
    seq = sequential_engine(cfg, stream)
    bat = _engine(cfg, stream, S=1, P=0)
    m_seq, m_bat = run_pair(seq, bat, stream)
    assert_run_parity(seq, m_seq, bat, m_bat, costs=True)


# ---------------------------------------------------------------------------
# P > 0: identical routing on identical tick keys
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [1, 2])
def test_pipelined_parity_learning_regime(depth):
    """P in {1, 2} must produce identical predictions, chosen levels,
    expert-call decisions and (bitwise) parameters in the LEARNING
    regime, where in-flight speculation goes stale on every committing
    tick — the refetch path must restore exactness, not approximate
    it."""
    stream, cfg = make_setup(3e-6, 256)
    e0 = _engine(cfg, stream, S=8, P=0)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=depth)
    mP = eP.run(stream)
    assert_run_parity(e0, m0, eP, mP, history_keys=PIPE_PARITY_KEYS)
    # the learning regime actually exercised the staleness machinery
    assert eP.pipeline_stats["refetches"] > 0
    assert eP.pipeline_stats["submitted"] == eP.pipeline_stats["resolved"]


def test_update_tick_fencing_with_async_delay():
    """Composition with max_delay=2: commits are knowable D ticks ahead,
    so the pipeline fences PROACTIVELY (update_fences) instead of
    wasting speculated forwards (refetches == 0) — and the results stay
    identical to the unpipelined async engine."""
    stream, cfg = make_setup(3e-6, 256)
    e0 = _engine(cfg, stream, S=8, P=0, D=2)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=2, D=2)
    mP = eP.run(stream)
    assert_run_parity(e0, m0, eP, mP, history_keys=PIPE_PARITY_KEYS)
    assert eP.pipeline_stats["update_fences"] > 0
    assert eP.pipeline_stats["refetches"] == 0


def test_hard_budget_fences_speculation():
    """Near a hard budget the jump gate's budget bit cannot be proven
    stable; the engine must drain the ring inside that window and still
    match the unpipelined engine's calls exactly."""
    stream, cfg = make_setup(3e-6, 256, hard_budget=25)
    e0 = _engine(cfg, stream, S=8, P=0)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=2)
    mP = eP.run(stream)
    assert_run_parity(e0, m0, eP, mP, history_keys=PIPE_PARITY_KEYS)
    assert m0["expert_calls"] <= 25
    assert eP.pipeline_stats["budget_fences"] > 0


def test_converged_regime_speculates_freely():
    """The single-exit converged regime (no expert traffic, no updates)
    is where the pipeline pays: every tick must speculate successfully —
    zero refetches, zero fences — with identical predictions."""
    stream, cfg = make_setup(3e-6, 256, hard_budget=0)
    e0 = _engine(cfg, stream, S=8, P=0)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=2)
    mP = eP.run(stream)
    np.testing.assert_array_equal(m0["predictions"], mP["predictions"])
    st = eP.pipeline_stats
    assert st["refetches"] == 0
    assert st["update_fences"] == 0 and st["budget_fences"] == 0
    assert st["submitted"] == st["resolved"] == len(stream) // 8


# ---------------------------------------------------------------------------
# driver API
# ---------------------------------------------------------------------------
def test_submit_resolve_api_fifo_and_latency_bound():
    """submit_tick holds at most P ticks in flight, resolves oldest
    first, and every output maps back to its submission via
    "indices"."""
    S, P, ticks = 4, 2, 6
    stream, cfg = make_setup(3e-7, S * ticks, hard_budget=0)
    eng = _engine(cfg, stream, S=S, P=P)
    seen = []
    for tk in range(ticks):
        idxs = list(range(tk * S, (tk + 1) * S))
        outs = eng.submit_tick(idxs, [stream.docs[i] for i in idxs])
        assert len(eng._ring) <= P
        seen += outs
        # a tick resolves at most P submissions after its own
        if tk + 1 > P:
            assert len(seen) == tk + 1 - P
    seen += eng.drain()
    assert [o["tick"] for o in seen] == list(range(1, ticks + 1))
    got = np.concatenate([o["indices"] for o in seen])
    np.testing.assert_array_equal(got, np.arange(S * ticks))


def test_process_tick_rejects_inflight_mixing():
    S = 4
    stream, cfg = make_setup(3e-7, 2 * S, hard_budget=0)
    eng = _engine(cfg, stream, S=S, P=2)
    eng.submit_tick(list(range(S)), stream.docs[:S])
    with pytest.raises(RuntimeError):
        eng.process_tick(list(range(S, 2 * S)), stream.docs[S:2 * S])
    eng.drain()
    out = eng.process_tick(list(range(S, 2 * S)), stream.docs[S:2 * S])
    assert out["predictions"].shape == (S,)


def test_flush_rejects_inflight_ticks():
    """flush() while ticks are in flight would commit annotations out of
    FIFO tick order (stale in-flight forwards would then refetch against
    params the unpipelined engine never saw at those ticks) — it must
    refuse until the ring is drained."""
    S = 4
    stream, cfg = make_setup(3e-6, 2 * S)
    eng = _engine(cfg, stream, S=S, P=2, D=2)
    eng.submit_tick(list(range(S)), stream.docs[:S])
    with pytest.raises(RuntimeError):
        eng.flush()
    eng.drain()
    assert eng.flush() >= 0
    assert len(eng._pending) == 0


def test_reset_clears_pipeline_and_reproduces():
    """reset() discards in-flight dispatches and restores the exact
    initial trajectory (warm-engine reuse across streams)."""
    stream, cfg = make_setup(3e-6, 128)
    eng = _engine(cfg, stream, S=8, P=2)
    m1 = eng.run(stream)
    # leave a tick in flight, then reset mid-stream
    eng.reset()
    eng.submit_tick(list(range(8)), stream.docs[:8])
    assert len(eng._ring) == 1
    eng.reset()
    assert len(eng._ring) == 0
    m2 = eng.run(stream)
    np.testing.assert_array_equal(m1["predictions"], m2["predictions"])
    assert m1["expert_calls"] == m2["expert_calls"]


def test_pipeline_depth_validated():
    stream, cfg = make_setup(3e-7, 8)
    with pytest.raises(ValueError):
        batched_engine(cfg, stream, n_streams=8, pipeline_depth=-1)


# ---------------------------------------------------------------------------
# 8-virtual-device mesh parity (subprocess)
# ---------------------------------------------------------------------------
PIPELINED_MESH_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
sys.path.insert(0, {tests!r})
import numpy as np, jax
assert len(jax.devices()) == 8
from harness import assert_run_parity, batched_engine, make_setup
from repro.launch.mesh import make_mesh

n, S = 256, 32
stream, cfg = make_setup(3e-6, n, dataset="imdb", seed=0)
mesh = make_mesh((8, 1), ("data", "model"))

base = batched_engine(cfg, stream, n_streams=S)
m0 = base.run(stream)
pipe = batched_engine(cfg, stream, n_streams=S, mesh=mesh,
                      pipeline_depth=2)
m1 = pipe.run(stream)

# same tick keys => identical routing under pipelining on the mesh too;
# params agree to float tolerance (SPMD may reassociate reductions)
assert_run_parity(base, m0, pipe, m1, state="allclose",
                  attrs=("params", "dparams"))
assert len(pipe._ring) == 0 and len(pipe._pending) == 0

# warm reuse: the pipelined mesh engine reproduces itself after reset()
pipe.reset()
m2 = pipe.run(stream)
np.testing.assert_array_equal(m1["predictions"], m2["predictions"])

# composition: mesh + pipeline + bounded annotation delay must match the
# unsharded unpipelined engine AT THE SAME DELAY (provisional answers on
# deferred lanes are delay semantics, not pipeline semantics)
baseD = batched_engine(cfg, stream, n_streams=S, max_delay=2)
mD0 = baseD.run(stream)
pipeD = batched_engine(cfg, stream, n_streams=S, mesh=mesh,
                       pipeline_depth=2, max_delay=2)
mD1 = pipeD.run(stream)
assert_run_parity(baseD, mD0, pipeD, mD1, state=None,
                  history_keys=("level", "expert_called"))
print("PIPELINED-MESH-OK")
"""


def test_pipelined_mesh_parity_8dev():
    """S=32 lanes over an 8-virtual-device mesh with pipeline_depth=2 +
    max_delay=2: identical predictions/levels/expert calls as the
    unsharded unpipelined engine on the same tick keys."""
    here = os.path.dirname(__file__)
    src = os.path.abspath(os.path.join(here, "..", "src"))
    code = PIPELINED_MESH_SNIPPET.format(src=src,
                                         tests=os.path.abspath(here))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PIPELINED-MESH-OK" in proc.stdout
