"""Pipelined route passes (``BatchedCascadeEngine(pipeline_depth=...)``):
depth-0 bitwise parity with the sequential reference, exact P>0 routing
parity (predictions/levels/expert calls/params), update-tick fencing,
hard-budget fencing, composition with the async expert queue, and the
submit/resolve driver API.  The 8-virtual-device mesh variant lives in a
subprocess snippet (same pattern as tests/test_sharded.py)."""
import os
import subprocess
import sys
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.core import (BatchedCascadeEngine, OnlineCascade, SimulatedExpert,
                        default_cascade_config)
from repro.data import make_stream


def _setup(mu, n, dataset="imdb", seed=0, **cfg_kw):
    stream = make_stream(dataset, seed=seed, n_samples=n)
    cfg = default_cascade_config(n_classes=stream.spec.n_classes, mu=mu,
                                 seed=seed)
    if cfg_kw:
        cfg = replace(cfg, **cfg_kw)
    return stream, cfg


def _engine(cfg, stream, S, P, D=0):
    return BatchedCascadeEngine(
        cfg, SimulatedExpert(stream, "gpt-3.5-turbo"), n_streams=S,
        pipeline_depth=P, max_delay=D)


def _state(e):
    return [np.asarray(x) for lvl in e.levels
            for attr in ("params", "opt_state", "dparams", "dopt_state")
            for x in jax.tree.leaves(getattr(lvl, attr))]


def _assert_identical(e_ref, m_ref, e_new, m_new, *, bitwise_state=True):
    np.testing.assert_array_equal(m_ref["predictions"], m_new["predictions"])
    for a, b in zip(e_ref.history["level"], e_new.history["level"]):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(e_ref.history["expert_called"],
                    e_new.history["expert_called"]):
        np.testing.assert_array_equal(a, b)
    assert m_ref["expert_calls"] == m_new["expert_calls"]
    if bitwise_state:
        for a, b in zip(_state(e_ref), _state(e_new)):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# depth 0: the pre-pipeline engine, bit for bit
# ---------------------------------------------------------------------------
def test_depth0_bitwise_parity_s1():
    """pipeline_depth=0 must stay bit-identical to the sequential
    reference at S=1 — predictions, levels, per-item costs, expert
    calls, params AND optimizer state (the acceptance contract for the
    dispatch/resolve split of the route pass)."""
    stream, cfg = _setup(3e-6, 300)
    seq = OnlineCascade(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"))
    bat = _engine(cfg, stream, S=1, P=0)
    m_seq = seq.run(stream)
    m_bat = bat.run(stream)
    np.testing.assert_array_equal(m_seq["predictions"], m_bat["predictions"])
    np.testing.assert_array_equal(np.asarray(seq.history["level"]),
                                  np.concatenate(bat.history["level"]))
    np.testing.assert_allclose(np.asarray(seq.history["cost"], np.float64),
                               np.concatenate(bat.history["cost"]))
    assert m_seq["expert_calls"] == m_bat["expert_calls"]
    for ls, lb in zip(seq.levels, bat.levels):
        for attr in ("params", "opt_state", "dparams", "dopt_state"):
            for a, b in zip(jax.tree.leaves(getattr(ls, attr)),
                            jax.tree.leaves(getattr(lb, attr))):
                assert bool(jax.numpy.array_equal(a, b)), attr


# ---------------------------------------------------------------------------
# P > 0: identical routing on identical tick keys
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [1, 2])
def test_pipelined_parity_learning_regime(depth):
    """P in {1, 2} must produce identical predictions, chosen levels,
    expert-call decisions and (bitwise) parameters in the LEARNING
    regime, where in-flight speculation goes stale on every committing
    tick — the refetch path must restore exactness, not approximate
    it."""
    stream, cfg = _setup(3e-6, 256)
    e0 = _engine(cfg, stream, S=8, P=0)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=depth)
    mP = eP.run(stream)
    _assert_identical(e0, m0, eP, mP)
    # the learning regime actually exercised the staleness machinery
    assert eP.pipeline_stats["refetches"] > 0
    assert eP.pipeline_stats["submitted"] == eP.pipeline_stats["resolved"]


def test_update_tick_fencing_with_async_delay():
    """Composition with max_delay=2: commits are knowable D ticks ahead,
    so the pipeline fences PROACTIVELY (update_fences) instead of
    wasting speculated forwards (refetches == 0) — and the results stay
    identical to the unpipelined async engine."""
    stream, cfg = _setup(3e-6, 256)
    e0 = _engine(cfg, stream, S=8, P=0, D=2)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=2, D=2)
    mP = eP.run(stream)
    _assert_identical(e0, m0, eP, mP)
    assert eP.pipeline_stats["update_fences"] > 0
    assert eP.pipeline_stats["refetches"] == 0


def test_hard_budget_fences_speculation():
    """Near a hard budget the jump gate's budget bit cannot be proven
    stable; the engine must drain the ring inside that window and still
    match the unpipelined engine's calls exactly."""
    stream, cfg = _setup(3e-6, 256, hard_budget=25)
    e0 = _engine(cfg, stream, S=8, P=0)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=2)
    mP = eP.run(stream)
    _assert_identical(e0, m0, eP, mP)
    assert m0["expert_calls"] <= 25
    assert eP.pipeline_stats["budget_fences"] > 0


def test_converged_regime_speculates_freely():
    """The single-exit converged regime (no expert traffic, no updates)
    is where the pipeline pays: every tick must speculate successfully —
    zero refetches, zero fences — with identical predictions."""
    stream, cfg = _setup(3e-6, 256, hard_budget=0)
    e0 = _engine(cfg, stream, S=8, P=0)
    m0 = e0.run(stream)
    eP = _engine(cfg, stream, S=8, P=2)
    mP = eP.run(stream)
    np.testing.assert_array_equal(m0["predictions"], mP["predictions"])
    st = eP.pipeline_stats
    assert st["refetches"] == 0
    assert st["update_fences"] == 0 and st["budget_fences"] == 0
    assert st["submitted"] == st["resolved"] == len(stream) // 8


# ---------------------------------------------------------------------------
# driver API
# ---------------------------------------------------------------------------
def test_submit_resolve_api_fifo_and_latency_bound():
    """submit_tick holds at most P ticks in flight, resolves oldest
    first, and every output maps back to its submission via
    "indices"."""
    S, P, ticks = 4, 2, 6
    stream, cfg = _setup(3e-7, S * ticks, hard_budget=0)
    eng = _engine(cfg, stream, S=S, P=P)
    seen = []
    for tk in range(ticks):
        idxs = list(range(tk * S, (tk + 1) * S))
        outs = eng.submit_tick(idxs, [stream.docs[i] for i in idxs])
        assert len(eng._ring) <= P
        seen += outs
        # a tick resolves at most P submissions after its own
        if tk + 1 > P:
            assert len(seen) == tk + 1 - P
    seen += eng.drain()
    assert [o["tick"] for o in seen] == list(range(1, ticks + 1))
    got = np.concatenate([o["indices"] for o in seen])
    np.testing.assert_array_equal(got, np.arange(S * ticks))


def test_process_tick_rejects_inflight_mixing():
    S = 4
    stream, cfg = _setup(3e-7, 2 * S, hard_budget=0)
    eng = _engine(cfg, stream, S=S, P=2)
    eng.submit_tick(list(range(S)), stream.docs[:S])
    with pytest.raises(RuntimeError):
        eng.process_tick(list(range(S, 2 * S)), stream.docs[S:2 * S])
    eng.drain()
    out = eng.process_tick(list(range(S, 2 * S)), stream.docs[S:2 * S])
    assert out["predictions"].shape == (S,)


def test_flush_rejects_inflight_ticks():
    """flush() while ticks are in flight would commit annotations out of
    FIFO tick order (stale in-flight forwards would then refetch against
    params the unpipelined engine never saw at those ticks) — it must
    refuse until the ring is drained."""
    S = 4
    stream, cfg = _setup(3e-6, 2 * S)
    eng = _engine(cfg, stream, S=S, P=2, D=2)
    eng.submit_tick(list(range(S)), stream.docs[:S])
    with pytest.raises(RuntimeError):
        eng.flush()
    eng.drain()
    assert eng.flush() >= 0
    assert len(eng._pending) == 0


def test_reset_clears_pipeline_and_reproduces():
    """reset() discards in-flight dispatches and restores the exact
    initial trajectory (warm-engine reuse across streams)."""
    stream, cfg = _setup(3e-6, 128)
    eng = _engine(cfg, stream, S=8, P=2)
    m1 = eng.run(stream)
    # leave a tick in flight, then reset mid-stream
    eng.reset()
    eng.submit_tick(list(range(8)), stream.docs[:8])
    assert len(eng._ring) == 1
    eng.reset()
    assert len(eng._ring) == 0
    m2 = eng.run(stream)
    np.testing.assert_array_equal(m1["predictions"], m2["predictions"])
    assert m1["expert_calls"] == m2["expert_calls"]


def test_pipeline_depth_validated():
    stream, cfg = _setup(3e-7, 8)
    with pytest.raises(ValueError):
        BatchedCascadeEngine(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                             n_streams=8, pipeline_depth=-1)


# ---------------------------------------------------------------------------
# 8-virtual-device mesh parity (subprocess)
# ---------------------------------------------------------------------------
PIPELINED_MESH_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import (BatchedCascadeEngine, SimulatedExpert,
                        default_cascade_config)
from repro.data import make_stream
from repro.launch.mesh import make_mesh

n, S = 256, 32
stream = make_stream("imdb", seed=0, n_samples=n)
cfg = default_cascade_config(n_classes=2, mu=3e-6, seed=0)
mesh = make_mesh((8, 1), ("data", "model"))

base = BatchedCascadeEngine(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                            n_streams=S)
m0 = base.run(stream)
pipe = BatchedCascadeEngine(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                            n_streams=S, mesh=mesh, pipeline_depth=2)
m1 = pipe.run(stream)

# same tick keys => identical routing under pipelining on the mesh too
np.testing.assert_array_equal(m0["predictions"], m1["predictions"])
for a, b in zip(base.history["level"], pipe.history["level"]):
    np.testing.assert_array_equal(a, b)
assert m0["expert_calls"] == m1["expert_calls"]
assert len(pipe._ring) == 0 and len(pipe._pending) == 0

# params agree to float tolerance (SPMD may reassociate reductions)
for ls, lb in zip(base.levels, pipe.levels):
    for attr in ("params", "dparams"):
        for a, b in zip(jax.tree.leaves(getattr(ls, attr)),
                        jax.tree.leaves(getattr(lb, attr))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)

# warm reuse: the pipelined mesh engine reproduces itself after reset()
pipe.reset()
m2 = pipe.run(stream)
np.testing.assert_array_equal(m1["predictions"], m2["predictions"])

# composition: mesh + pipeline + bounded annotation delay must match the
# unsharded unpipelined engine AT THE SAME DELAY (provisional answers on
# deferred lanes are delay semantics, not pipeline semantics)
baseD = BatchedCascadeEngine(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                             n_streams=S, max_delay=2)
mD0 = baseD.run(stream)
pipeD = BatchedCascadeEngine(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                             n_streams=S, mesh=mesh, pipeline_depth=2,
                             max_delay=2)
mD1 = pipeD.run(stream)
np.testing.assert_array_equal(mD0["predictions"], mD1["predictions"])
for a, b in zip(baseD.history["expert_called"],
                pipeD.history["expert_called"]):
    np.testing.assert_array_equal(a, b)
assert mD0["expert_calls"] == mD1["expert_calls"]
print("PIPELINED-MESH-OK")
"""


def test_pipelined_mesh_parity_8dev():
    """S=32 lanes over an 8-virtual-device mesh with pipeline_depth=2 +
    max_delay=2: identical predictions/levels/expert calls as the
    unsharded unpipelined engine on the same tick keys."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = PIPELINED_MESH_SNIPPET.format(src=src)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PIPELINED-MESH-OK" in proc.stdout
