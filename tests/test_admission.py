"""Stream-lifecycle suite for the continuous-batching front-end.

Three layers, mirroring the module's contract (core/admission.py):

* **lifecycle conservation** — property-based (hypothesis, with
  concrete smoke twins per tests/_hypothesis_stubs.py) over a fake
  engine that records exactly what the front-end submits: every
  admitted stream retires exactly once, no lane serves two streams in
  one tick, occupancy never exceeds the lane budget, admission is FCFS
  and deterministic in the schedule alone;
* **parity pins** — the all-at-t=0 lockstep schedule through the
  front-end is bitwise the classic fixed-S run (predictions, levels,
  expert calls, costs, params/opt state) including under D>0 and P>0;
  a staggered-arrival run in the frozen regime (hard_budget=0)
  reproduces each stream's dedicated-lane sequential reference
  trajectory; a staggered LEARNING run is bitwise invariant to the
  execution axes (pipeline depth, expert workers) and its admission
  log is invariant even to the semantic delay axis;
* **recycled-lane hygiene** — reset()-then-rerun is bitwise, and a
  recycled engine serving schedule B equals a fresh engine serving
  schedule B (no stale ring/cache/commit-log leakage from retired
  streams).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stubs import given, settings, st

from dataclasses import replace

from harness import (assert_run_parity, assert_state_equal,
                     batched_engine, frontend_engine, run_frontend,
                     run_frontend_pair, sequential_stream_reference,
                     state_leaves)
from repro.core import (CascadeConfig, CascadeFrontEnd, LevelSpec,
                        serve_requests)
from repro.data import (Request, burst_requests, lockstep_requests,
                        make_stream, poisson_requests)
from repro.models.students import MLPSpec

N, S = 96, 8
_CACHE = {}


def _stream_cfg():
    """The matrix suite's cheap two-level cascade (LR + small MLP)."""
    if "setup" not in _CACHE:
        stream = make_stream("hatespeech", seed=0, n_samples=N)
        levels = (
            LevelSpec(kind="lr", cost=1.0, cache_size=8, batch_size=8,
                      student_lr=0.5, beta_decay=0.9,
                      calibration_factor=0.4),
            LevelSpec(kind="mlp", cost=50.0, cache_size=16, batch_size=8,
                      student_lr=1e-3, beta_decay=0.9,
                      calibration_factor=0.3),
        )
        cfg = CascadeConfig(
            levels=levels, n_classes=stream.spec.n_classes,
            expert_cost=1.0e6, mu=3e-6, n_features=512,
            mlp_spec=MLPSpec(n_features=512, hidden=64, n_layers=2),
            seed=0)
        _CACHE["setup"] = (stream, cfg)
    return _CACHE["setup"]


def _staggered():
    """The shared staggered schedule (seeded, ~20 requests over N)."""
    return poisson_requests(N, rate=0.7, mean_len=5, seed=3)


# ---------------------------------------------------------------------------
# lifecycle conservation properties (fake engine: pure admission logic)
# ---------------------------------------------------------------------------
class _FakeStream:
    def __init__(self, n):
        self.docs = list(range(n))

    def __len__(self):
        return len(self.docs)


class _FakeEngine:
    """Records exactly the tick surface the front-end drives (the
    documented engine contract: n_streams, t, pipeline_depth,
    process_tick(indices, docs, lanes=, stream_ids=, stream_ticks=),
    commit_log, drain, flush)."""

    def __init__(self, n_streams):
        self.n_streams = n_streams
        self.pipeline_depth = 0
        self.t = 0
        self.commit_log = None
        self.ticks = []           # (t, lanes, stream_ids, stream_ticks)

    def process_tick(self, indices, docs, *, lanes=None, stream_ids=None,
                     stream_ticks=None):
        self.t += 1
        k = len(indices)
        self.ticks.append((self.t, list(lanes), list(stream_ids),
                           list(stream_ticks)))
        return {"tick": self.t,
                "indices": np.asarray(indices, np.int64),
                "lanes": np.asarray(lanes, np.int64),
                "predictions": np.zeros(k, np.int64),
                "levels": np.zeros(k, np.int64),
                "expert_called": np.zeros(k, bool),
                "cost_units": np.zeros(k),
                "expert_labels": np.full(k, -1, np.int32)}

    def drain(self):
        return []

    def flush(self):
        return 0


def _schedule_requests(schedule):
    """[(arrival_gap, length)] -> contiguous-partition Requests."""
    reqs, start, arrival = [], 0, 0
    for rid, (gap, length) in enumerate(schedule):
        arrival += gap
        reqs.append(Request(rid=rid, arrival=arrival,
                            items=tuple(range(start, start + length))))
        start += length
    return reqs


def _check_lifecycle(schedule, budget, policy, queue_limit):
    """The conservation properties, on one (schedule, policy) instance."""
    reqs = _schedule_requests(schedule)
    total = sum(len(r.items) for r in reqs)
    eng = _FakeEngine(budget)
    fe = CascadeFrontEnd(eng, _FakeStream(total), admission=policy,
                         queue_limit=queue_limit)
    fe.serve(reqs)

    # -- per-tick invariants, straight from what the engine was handed
    seen_ticks = {}
    for t, lanes, sids, sticks in eng.ticks:
        assert len(lanes) <= budget, "occupancy exceeded the lane budget"
        assert lanes == sorted(set(lanes)), \
            "a lane served two streams in one tick (or order broke)"
        assert len(set(sids)) == len(sids)
        for sid, tick in zip(sids, sticks):
            seen_ticks.setdefault(sid, []).append(tick)
    for rid, ticks in seen_ticks.items():
        assert ticks == list(range(1, len(ticks) + 1)), \
            "a stream's local ticks must be 1..n in order"

    # -- conservation: every admitted stream retires exactly once
    shed = {r.rid for r in reqs if fe.records[r.rid].shed}
    assert not shed or policy == "shed", "queue policy must never shed"
    admitted_rids = [rid for rid, _, _ in fe.admission_log]
    assert sorted(admitted_rids) == sorted(
        r.rid for r in reqs if r.rid not in shed)
    assert len(set(admitted_rids)) == len(admitted_rids)
    for r in reqs:
        rec = fe.records[r.rid]
        if rec.shed:
            assert rec.admit == -1 and rec.items_done == 0
            continue
        assert rec.items_done == rec.n_items == len(
            seen_ticks.get(r.rid, []))
        assert 0 < max(r.arrival, 1) <= rec.admit <= rec.done < rec.retired
    assert sum(fe.records[r.rid].items_done for r in reqs) == \
        total - sum(len(r.items) for r in reqs if r.rid in shed)

    # -- FCFS: lanes are granted in offer order (arrival, then rid)
    offer_order = [r.rid for r in
                   sorted(reqs, key=lambda r: (max(r.arrival, 1), r.rid))
                   if r.rid not in shed]
    assert admitted_rids == offer_order

    # -- determinism: the same schedule replays to the same log
    eng2 = _FakeEngine(budget)
    fe2 = CascadeFrontEnd(eng2, _FakeStream(total), admission=policy,
                          queue_limit=queue_limit)
    fe2.serve(reqs)
    assert fe2.admission_log == fe.admission_log
    assert eng2.ticks == eng.ticks


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 5)),
                min_size=1, max_size=12),
       st.integers(1, 4), st.sampled_from(["queue", "shed"]),
       st.integers(0, 2))
@settings(max_examples=40, deadline=None)
def test_lifecycle_properties(schedule, budget, policy, queue_limit):
    """Conservation/occupancy/FCFS/determinism over random schedules."""
    _check_lifecycle(schedule, budget, policy, queue_limit)


def test_lifecycle_smoke_underload():
    """Concrete twin: staggered arrivals under capacity, queue policy."""
    _check_lifecycle([(0, 3), (1, 2), (2, 4), (0, 1)], 2, "queue", 0)


def test_lifecycle_smoke_overload_shed():
    """Concrete twin: a burst beyond lanes+queue must shed the rest."""
    _check_lifecycle([(0, 4)] * 6, 2, "shed", 1)
    reqs = _schedule_requests([(0, 4)] * 6)
    eng = _FakeEngine(2)
    fe = CascadeFrontEnd(eng, _FakeStream(24), admission="shed",
                         queue_limit=1)
    fe.serve(reqs)
    # 2 lanes + 1 queue slot: exactly 3 of the 6 simultaneous arrivals
    # survive the first wave, and each later retirement frees no slot
    # for requests already dropped (shed is final)
    assert fe.stats["shed"] == 3 and fe.stats["admitted"] == 3


# ---------------------------------------------------------------------------
# parity pin 1: all-at-t=0 through the front-end == the lockstep run
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("max_delay,depth",
                         [(0, 0), (2, 0), (0, 2), (2, 2)],
                         ids=["D0-P0", "D2-P0", "D0-P2", "D2-P2"])
def test_lockstep_schedule_bitwise(max_delay, depth):
    """The stride-S all-at-t=0 schedule is bitwise the classic run —
    predictions, levels, expert calls, per-item costs, params and
    optimizer state — composed with the async queue and the route
    pipeline."""
    stream, cfg = _stream_cfg()
    ref = batched_engine(cfg, stream, n_streams=S, max_delay=max_delay,
                         pipeline_depth=depth)
    eng = frontend_engine(cfg, stream, S, max_delay=max_delay,
                          pipeline_depth=depth)
    m_ref, fe, m_fe = run_frontend_pair(
        ref, eng, stream, lockstep_requests(len(stream), S))
    assert m_fe["answered"] == m_fe["requests"] == S
    assert_run_parity(ref, m_ref, eng, m_fe,
                      history_keys=("level", "expert_called"),
                      costs=True)
    # lane recycling left nothing in flight
    assert not eng._pending and not eng._ring


# ---------------------------------------------------------------------------
# parity pin 2: staggered arrivals reproduce each stream's dedicated-
# lane sequential reference (frozen regime: the trajectories decouple)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("max_delay,depth,per_lane",
                         [(0, 0, False), (2, 2, False), (2, 0, True)],
                         ids=["D0-P0", "D2-P2", "D2-lane"])
def test_staggered_matches_sequential_reference(max_delay, depth,
                                                per_lane):
    """With hard_budget=0 (no jumps, expert calls or updates) every
    dynamically-admitted stream must produce, item for item, the
    predictions and levels of a fresh sequential cascade keyed as that
    stream — whatever lane, global tick, co-occupants, delay or
    pipeline depth served it."""
    stream, cfg = _stream_cfg()
    cfg0 = replace(cfg, hard_budget=0)
    reqs = _staggered()
    eng = frontend_engine(cfg0, stream, 4, max_delay=max_delay,
                          pipeline_depth=depth, per_lane=per_lane)
    fe, m = run_frontend(eng, stream, reqs)
    assert m["answered"] == len(reqs)
    for r in reqs:
        preds, levels = sequential_stream_reference(cfg0, stream, r)
        rec = fe.records[r.rid]
        assert rec.predictions == preds, f"stream {r.rid} preds diverge"
        assert rec.levels == levels, f"stream {r.rid} levels diverge"


def test_staggered_invariant_to_execution_knobs():
    """Learning regime: a staggered run is bitwise invariant to the
    pure execution axes — pipeline depth and expert workers — and the
    admission log is invariant even across the (semantic) delay axis."""
    stream, cfg = _stream_cfg()
    reqs = _staggered()
    base = frontend_engine(cfg, stream, 4)
    fe0, m0 = run_frontend(base, stream, reqs)
    for kw in ({"pipeline_depth": 2}, {"expert_kw": {"workers": 2}}):
        eng = frontend_engine(cfg, stream, 4, **kw)
        fe, m = run_frontend(eng, stream, reqs)
        np.testing.assert_array_equal(m0["predictions"],
                                      m["predictions"])
        assert fe.admission_log == fe0.admission_log
        assert_state_equal(base.levels, eng.levels)
        for rid, rec in fe0.records.items():
            other = fe.records[rid]
            assert (rec.admit, rec.done, rec.retired, rec.lane) == \
                (other.admit, other.done, other.retired, other.lane)
            assert rec.predictions == other.predictions
    # the delay axis changes update timing (a documented semantic axis)
    # but admission/retirement timing is schedule-driven and identical
    eng_d = frontend_engine(cfg, stream, 4, max_delay=2)
    fe_d, _ = run_frontend(eng_d, stream, reqs)
    assert fe_d.admission_log == fe0.admission_log


# ---------------------------------------------------------------------------
# recycled-lane hygiene: reset() and commit-log attribution
# ---------------------------------------------------------------------------
def test_recycle_then_rerun_bitwise():
    """A front-end run that recycled lanes many times, reset, and rerun
    must be bitwise the first run — stale ring/cache/commit-log state
    from retired streams must not leak into the next occupancy."""
    stream, cfg = _stream_cfg()
    reqs = _staggered()
    eng = frontend_engine(cfg, stream, 4, max_delay=2, pipeline_depth=2)
    fe_a, m_a = run_frontend(eng, stream, reqs)
    leaves_a = [leaf.copy() for leaf in state_leaves(eng.levels)]
    commits_a = {rid: list(r.commit_ticks)
                 for rid, r in fe_a.records.items()}
    eng.reset()
    assert eng.commit_log == [] and not eng._pending and not eng._ring
    fe_b, m_b = run_frontend(eng, stream, reqs)
    np.testing.assert_array_equal(m_a["predictions"], m_b["predictions"])
    assert fe_b.admission_log == fe_a.admission_log
    for a, b in zip(leaves_a, state_leaves(eng.levels)):
        np.testing.assert_array_equal(a, b)
    assert commits_a == {rid: list(r.commit_ticks)
                         for rid, r in fe_b.records.items()}


def test_recycled_engine_equals_fresh_engine():
    """Serving schedule A, resetting, then serving schedule B equals a
    fresh engine serving schedule B (the recycled-lane reset audit)."""
    stream, cfg = _stream_cfg()
    reqs_a = burst_requests(N, burst=5, every=3, mean_len=4, seed=7)
    reqs_b = _staggered()
    eng = frontend_engine(cfg, stream, 4, max_delay=2)
    run_frontend(eng, stream, reqs_a)
    eng.reset()
    fe1, m1 = run_frontend(eng, stream, reqs_b)
    fresh = frontend_engine(cfg, stream, 4, max_delay=2)
    fe2, m2 = run_frontend(fresh, stream, reqs_b)
    np.testing.assert_array_equal(m1["predictions"], m2["predictions"])
    assert_state_equal(eng.levels, fresh.levels)
    assert eng.commit_log == fresh.commit_log
    assert {r: rec.commit_ticks for r, rec in fe1.records.items()} == \
        {r: rec.commit_ticks for r, rec in fe2.records.items()}


# ---------------------------------------------------------------------------
# engine-surface contracts the front-end rests on
# ---------------------------------------------------------------------------
def test_commit_log_decoupled_from_history_limit():
    """commit_log=True/False overrides the legacy history coupling (the
    front-end needs the log while serving with history_limit=0)."""
    stream, cfg = _stream_cfg()
    legacy_on = batched_engine(cfg, stream, n_streams=2)
    legacy_off = batched_engine(cfg, stream, n_streams=2,
                                history_limit=0)
    forced_on = batched_engine(cfg, stream, n_streams=2, history_limit=0,
                               commit_log=True)
    forced_off = batched_engine(cfg, stream, n_streams=2,
                                commit_log=False)
    assert legacy_on.commit_log == [] and forced_on.commit_log == []
    assert legacy_off.commit_log is None
    assert forced_off.commit_log is None and forced_off.history is not None


def test_commit_attribution_and_delay_bound():
    """Every expert call of every stream gets exactly one commit tick in
    its record, and every commit lands within the D-tick bound of its
    submit tick — through admit/serve/retire/recycle."""
    stream, cfg = _stream_cfg()
    eng = frontend_engine(cfg, stream, 4, max_delay=2)
    fe = serve_requests(eng, stream, _staggered())
    assert not eng._pending and not eng._ring
    for sub_t, lane, commit_t in eng.commit_log:
        assert 0 <= commit_t - sub_t <= 2
        assert 0 <= lane < 4
    for rec in fe.records.values():
        assert len(rec.commit_ticks) == rec.expert_calls


def test_empty_tick_advances_commit_deadlines():
    """An idle (empty) tick still moves the clock: a pending annotation
    routed before an idle gap commits on schedule during the gap."""
    stream, cfg = _stream_cfg()
    eng = batched_engine(cfg, stream, n_streams=2, max_delay=2)
    # tick 1: both lanes defer (beta0=1 jumps everything on tick 1)
    eng.process_tick([0, 1], [stream.docs[0], stream.docs[1]])
    assert len(eng._pending) == 1
    before = [leaf.copy() for leaf in state_leaves(eng.levels)]
    eng.process_tick([], [])          # idle tick, age 1: not yet due
    assert len(eng._pending) == 1
    eng.process_tick([], [])          # idle tick, age 2 == D: commits
    assert len(eng._pending) == 0
    after = state_leaves(eng.levels)
    assert any(not np.array_equal(a, b) for a, b in zip(before, after))
    assert eng.commit_log == [(1, 0, 3), (1, 1, 3)]


def test_occupancy_kwargs_validation():
    """Malformed occupancy arguments fail loudly at dispatch."""
    stream, cfg = _stream_cfg()
    eng = batched_engine(cfg, stream, n_streams=4)
    docs = [stream.docs[0], stream.docs[1]]
    with pytest.raises(ValueError, match="strictly increasing"):
        eng.process_tick([0, 1], docs, lanes=[1, 0])
    with pytest.raises(ValueError, match="strictly increasing"):
        eng.process_tick([0, 1], docs, lanes=[2, 9])
    with pytest.raises(ValueError, match="one entry per tick position"):
        eng.process_tick([0, 1], docs, lanes=[0])
    with pytest.raises(ValueError, match="stream_ids"):
        eng.process_tick([0, 1], docs, stream_ids=[5])
    with pytest.raises(ValueError, match="stream_ticks"):
        eng.process_tick([0, 1], docs, stream_ticks=[1])
    with pytest.raises(ValueError, match="admission"):
        CascadeFrontEnd(eng, stream, admission="drop-all")
