"""Unit + property tests for the OCL core (Algorithm 1, MDP, deferral)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade gracefully: only property tests skip
    from _hypothesis_stubs import given, settings, st

from repro.core import (
    OnlineCascade, SimulatedExpert, default_cascade_config, episode_cost)
from repro.core.deferral import (
    DeferralSpec, deferral_init, deferral_prob)
from repro.data import make_stream


# ---------------------------------------------------------------------------
# MDP cost (Eq. 1)
# ---------------------------------------------------------------------------
def test_episode_cost_no_defer():
    """If level 1 never defers, cost = its prediction loss."""
    f = jnp.array([0.0, 0.0, 0.0])
    losses = jnp.array([0.7, 0.1, 0.0])
    costs = jnp.array([10.0, 100.0, 0.0])
    j, reach = episode_cost(f, losses, costs, mu=1.0)
    assert np.isclose(float(j), 0.7)
    np.testing.assert_allclose(np.asarray(reach), [1.0, 0.0, 0.0])


def test_episode_cost_always_defer():
    """Full deferral pays every defer penalty plus the expert's loss."""
    f = jnp.array([1.0, 1.0, 0.0])
    losses = jnp.array([0.7, 0.5, 0.05])
    costs = jnp.array([10.0, 100.0, 0.0])
    j, reach = episode_cost(f, losses, costs, mu=0.01)
    # level1: 0.01*10 ; level2: 0.01*100 ; level3: loss 0.05
    assert np.isclose(float(j), 0.1 + 1.0 + 0.05)
    np.testing.assert_allclose(np.asarray(reach), [1.0, 1.0, 1.0])


@settings(max_examples=30, deadline=None)
@given(
    f1=st.floats(0.0, 1.0), f2=st.floats(0.0, 1.0),
    l1=st.floats(0.0, 5.0), l2=st.floats(0.0, 5.0), l3=st.floats(0.0, 5.0),
    mu=st.floats(1e-4, 1.0),
)
def test_episode_cost_properties(f1, f2, l1, l2, l3, mu):
    """J is within [0, sum of all possible penalties]; reach is a
    decreasing survival probability."""
    f = jnp.array([f1, f2, 0.0])
    losses = jnp.array([l1, l2, l3])
    costs = jnp.array([10.0, 100.0, 0.0])
    j, reach = episode_cost(f, losses, costs, mu)
    r = np.asarray(reach)
    assert r[0] == 1.0 and r[1] <= r[0] + 1e-6 and r[2] <= r[1] + 1e-6
    upper = mu * 110.0 + l1 + l2 + l3
    assert -1e-6 <= float(j) <= upper + 1e-5


# ---------------------------------------------------------------------------
# Deferral MLP (Eq. 5)
# ---------------------------------------------------------------------------
def test_deferral_starts_open():
    spec = DeferralSpec(n_classes=2)
    params = deferral_init(jax.random.PRNGKey(0), spec)
    probs = jnp.array([[0.9, 0.1], [0.5, 0.5]])
    p = deferral_prob(params, probs)
    assert bool(jnp.all(p > 0.5)), "gates must start open (paper §1)"


def test_deferral_permutation_robust():
    """Features are sorted probabilities: class order must not matter."""
    spec = DeferralSpec(n_classes=3)
    params = deferral_init(jax.random.PRNGKey(1), spec)
    p1 = deferral_prob(params, jnp.array([[0.7, 0.2, 0.1]]))
    p2 = deferral_prob(params, jnp.array([[0.1, 0.7, 0.2]]))
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)


# ---------------------------------------------------------------------------
# Algorithm 1 driver
# ---------------------------------------------------------------------------
def _run(mu, n=400, hard_budget=None, dataset="imdb", seed=0):
    stream = make_stream(dataset, seed=seed, n_samples=n)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    cfg = default_cascade_config(n_classes=stream.spec.n_classes, mu=mu,
                                 seed=seed)
    if hard_budget is not None:
        from dataclasses import replace
        cfg = replace(cfg, hard_budget=hard_budget)
    cas = OnlineCascade(cfg, expert)
    metrics = cas.run(stream)
    return cas, metrics, stream


def test_cascade_outputs_valid_labels():
    cas, m, stream = _run(mu=3e-7, n=300)
    preds = m["predictions"]
    assert preds.min() >= 0 and preds.max() < stream.spec.n_classes


def test_cascade_initially_defers_everything():
    """First queries go to the expert (beta=1 + open gates)."""
    cas, m, _ = _run(mu=3e-7, n=60)
    assert all(cas.history["expert_called"][:10])


def test_hard_budget_respected():
    cas, m, _ = _run(mu=1e-7, n=400, hard_budget=50)
    assert m["expert_calls"] <= 50


def test_beta_decays():
    cas, m, _ = _run(mu=3e-7, n=200)
    for lvl in cas.levels:
        assert lvl.beta < 1e-2


def test_mu_controls_budget_monotonically():
    """Larger mu (costlier deferral) => fewer expert calls (paper §3:
    'the user can change the cost weighting factor mu ... for adjusting
    cost budgets')."""
    _, m_hi, _ = _run(mu=1e-6, n=500)
    _, m_lo, _ = _run(mu=1e-8, n=500)
    assert m_hi["expert_calls"] <= m_lo["expert_calls"]


def test_cache_fifo():
    from repro.core.cascade import _Level
    cfg = default_cascade_config(n_classes=2)
    lvl = _Level(cfg.levels[0], cfg, jax.random.PRNGKey(0))
    for i in range(20):
        lvl.cache_add(np.full((cfg.n_features,), i, np.float32), i % 2)
    assert lvl.cache_n == lvl.spec.cache_size
    # oldest entries were evicted: cache holds items 12..19
    vals = sorted(set(float(x[0]) for x in lvl.cache_x))
    assert min(vals) >= 20 - lvl.spec.cache_size


def test_students_learn_from_expert_only():
    """The cascade never touches ground-truth labels: accuracy vs the
    EXPERT's labels must exceed accuracy expected by chance."""
    cas, m, stream = _run(mu=1e-7, n=600)
    preds = m["predictions"]
    exp_labels = stream.expert_labels("gpt-3.5-turbo")
    agree = float(np.mean(preds == exp_labels))
    assert agree > 0.8


def test_cost_accounting_consistent():
    cas, m, stream = _run(mu=3e-7, n=300)
    # total cost >= expert_calls * expert cost
    assert m["total_cost_units"] >= m["expert_calls"] * cas.cfg.expert_cost
    assert sum(cas.level_counts) == len(stream)
