import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess; never set it globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
