import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess; never set it globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# CASCADE_SANITIZE=determinism,locks,retrace runs the whole suite under
# the named runtime sanitizers (the CI sanitizer job does this for the
# matrix smoke); a no-op when the variable is unset.
from repro.analysis import sanitize as _san  # noqa: E402

_san.enable_from_env()
