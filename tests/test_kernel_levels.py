"""Kernel-backed cascade levels: kernel-vs-ref parity + engine contracts.

Three layers of contract, per ISSUE 6 / docs/MODELS.md:

1. **Ops-vs-ref at level shapes** — the Pallas kernels (CPU interpret
   mode) match their jnp oracles at exactly the shapes the new levels
   run: short causal sequences, decode readout over odd-length masked
   tails, SSD at the student chunking.
2. **Path parity** — a level's kernel-path logits (what the route pass
   serves) match its reference-path logits (what the loss
   differentiates) within the documented tolerance, including pad-tail
   items.
3. **Engine contracts** — the lr -> tinytf_flash -> ssm ladder passes
   the same harness parity contracts as every other level kind: S=1
   bitwise vs the sequential reference, pipeline/pool execution axes
   change nothing, mesh cells match at the SPMD float tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import (MESH_ATOL, MESH_RTOL, assert_run_parity,
                     batched_engine, run_pair, sequential_engine)
from repro.core import CascadeConfig, LevelSpec
from repro.data import make_stream
from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.kernel_students import (
    TINY_SSM_CI, TINY_TF_CI, ssm_student_init, ssm_student_logits,
    tinytf_flash_init, tinytf_flash_logits)

# CI-sized specs: interpret-mode Pallas is slow, so the engine tests run
# the smallest shapes the kernels' block constraints allow.
TINY_TF = TINY_TF_CI
TINY_SSM = TINY_SSM_CI

_CACHE = {}


def _stream_cfg(n=48):
    if "setup" not in _CACHE:
        stream = make_stream("hatespeech", seed=0, n_samples=n)
        levels = (
            LevelSpec(kind="lr", cost=1.0, cache_size=8, batch_size=8,
                      student_lr=0.5, beta_decay=0.9,
                      calibration_factor=0.4),
            LevelSpec(kind="tinytf_flash", cost=50.0, cache_size=8,
                      batch_size=4, student_lr=1e-3, beta_decay=0.9,
                      calibration_factor=0.3),
            LevelSpec(kind="ssm", cost=200.0, cache_size=8, batch_size=4,
                      student_lr=7e-4, beta_decay=0.9,
                      calibration_factor=0.4),
        )
        cfg = CascadeConfig(
            levels=levels, n_classes=stream.spec.n_classes,
            expert_cost=1.0e6, mu=3e-6, n_features=512,
            tf_flash_spec=TINY_TF, ssm_spec=TINY_SSM, seed=0)
        _CACHE["setup"] = (stream, cfg)
    return _CACHE["setup"]


def _tokens_with_tails(lengths, max_len, vocab, seed=0):
    """(B, max_len) int32 batch with the given valid lengths (pads at
    the end) — the masked-tail shapes the levels actually see."""
    toks = np.zeros((len(lengths), max_len), np.int32)
    rng = np.random.default_rng(seed)
    for i, n in enumerate(lengths):
        toks[i, :n] = rng.integers(1, vocab, n)
    return jnp.asarray(toks)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# 1. ops vs ref at the level shapes (odd-length / masked tails included)
# ---------------------------------------------------------------------------
def test_flash_attention_at_level_shape():
    B, S, H, hd = 4, TINY_TF.max_len, TINY_TF.n_heads, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (_rand(ks[i], (B, S, H, hd)) for i in range(3))
    out = flash_attention(q, k, v, causal=True, block_q=TINY_TF.block_q,
                          block_kv=TINY_TF.block_kv)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3),
                        causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("nvalid", [1, 7, 17, 31, 32])
def test_decode_readout_at_level_shape(nvalid):
    """The readout's pos mask: odd valid lengths, incl. the full and
    nearly-full tails."""
    B, W, H, hd = 2, TINY_TF.max_len, TINY_TF.n_heads, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, 1, H, hd))
    k = _rand(ks[1], (B, W, H, hd))
    v = _rand(ks[2], (B, W, H, hd))
    pos = jnp.where(jnp.arange(W) < nvalid, jnp.arange(W), -1)
    out = decode_attention(q, k, v, pos, block_kv=TINY_TF.block_kv)
    ref = decode_attention_ref(
        q[:, 0].reshape(B, H, 1, hd), k, v,
        jnp.broadcast_to(pos[None], (B, W))).reshape(B, 1, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # the masked tail must be inert: scrambling empty slots is a no-op
    if nvalid < W:
        out2 = decode_attention(q, k.at[:, nvalid:].set(77.0),
                                v.at[:, nvalid:].set(-77.0), pos,
                                block_kv=TINY_TF.block_kv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                                   atol=1e-6)


def test_ssd_scan_at_level_shape():
    s = TINY_SSM
    Bsz, S = 2, s.max_len
    H = s.expand * s.d_model // s.head_dim
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = _rand(ks[0], (Bsz, S, H, s.head_dim))
    dt = jax.nn.softplus(_rand(ks[1], (Bsz, S, H)))
    adt = -0.4 * dt
    B = _rand(ks[2], (Bsz, S, s.d_state))
    C = _rand(ks[3], (Bsz, S, s.d_state))
    out = ssd_scan(x, adt, dt, B, C, chunk=s.chunk)
    ref = ssd_scan_ref(x, adt, dt, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# 2. kernel path vs reference path, whole-level logits
# ---------------------------------------------------------------------------
def _randomized(params, key, d, n_classes):
    """Students init their heads at zero; parity on logits needs a
    non-degenerate head."""
    params = dict(params)
    params["cls_w"] = jax.random.normal(key, (d, n_classes)) * 0.1
    return params


def test_tinytf_flash_paths_agree():
    key = jax.random.PRNGKey(3)
    params = _randomized(tinytf_flash_init(key, TINY_TF),
                         jax.random.fold_in(key, 1), TINY_TF.d_model,
                         TINY_TF.n_classes)
    toks = _tokens_with_tails([32, 17, 7, 1], TINY_TF.max_len,
                              TINY_TF.vocab)
    kernel = tinytf_flash_logits(params, toks, TINY_TF, use_kernels=True)
    ref = tinytf_flash_logits(params, toks, TINY_TF, use_kernels=False)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_ssm_paths_agree():
    key = jax.random.PRNGKey(4)
    params = _randomized(ssm_student_init(key, TINY_SSM),
                         jax.random.fold_in(key, 1), TINY_SSM.d_model,
                         TINY_SSM.n_classes)
    toks = _tokens_with_tails([32, 19, 5, 1], TINY_SSM.max_len,
                              TINY_SSM.vocab)
    kernel = ssm_student_logits(params, toks, TINY_SSM, use_kernels=True)
    ref = ssm_student_logits(params, toks, TINY_SSM, use_kernels=False)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_tinytf_flash_pad_independence():
    """Causality + pos-masked readout: logits of an item must not
    depend on how much pad tail follows it (same doc, same buffer)."""
    key = jax.random.PRNGKey(5)
    params = _randomized(tinytf_flash_init(key, TINY_TF),
                         jax.random.fold_in(key, 1), TINY_TF.d_model,
                         TINY_TF.n_classes)
    toks = _tokens_with_tails([11], TINY_TF.max_len, TINY_TF.vocab, seed=7)
    # a second batch whose OTHER row differs: row 0's logits must match
    toks2 = jnp.concatenate(
        [toks, _tokens_with_tails([29], TINY_TF.max_len, TINY_TF.vocab,
                                  seed=8)])
    a = tinytf_flash_logits(params, toks, TINY_TF, use_kernels=True)[0]
    b = tinytf_flash_logits(params, toks2, TINY_TF, use_kernels=True)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# 3. engine contracts for the kernel ladder
# ---------------------------------------------------------------------------
def test_s1_bitwise_parity_kernel_ladder():
    """S=1 batched == sequential reference, bitwise state, on the full
    lr -> tinytf_flash -> ssm ladder."""
    stream, cfg = _stream_cfg()
    ref = sequential_engine(cfg, stream)
    new = batched_engine(cfg, stream, n_streams=1)
    m_ref, m_new = run_pair(ref, new, stream)
    assert_run_parity(ref, m_ref, new, m_new,
                      history_keys=("level", "expert_called"), costs=True)


def _d2_reference():
    if "d2ref" not in _CACHE:
        stream, cfg = _stream_cfg()
        eng = batched_engine(cfg, stream, n_streams=8, max_delay=2)
        _CACHE["d2ref"] = (eng, eng.run(stream))
    return _CACHE["d2ref"]


def test_pipeline_composition_kernel_ladder():
    """pipeline_depth is a pure execution axis for kernel levels too."""
    stream, cfg = _stream_cfg()
    ref, m_ref = _d2_reference()
    new = batched_engine(cfg, stream, n_streams=8, max_delay=2,
                         pipeline_depth=2)
    m_new = new.run(stream)
    assert_run_parity(ref, m_ref, new, m_new,
                      history_keys=("level", "expert_called"), costs=True)


def test_pool_composition_kernel_ladder():
    """Per-lane commits on the kernel ladder are bitwise invariant to
    the expert pool's worker count."""
    stream, cfg = _stream_cfg()
    r1 = batched_engine(cfg, stream, n_streams=8, max_delay=2,
                        per_lane=True, expert_kw={"workers": 1})
    r2 = batched_engine(cfg, stream, n_streams=8, max_delay=2,
                        per_lane=True, expert_kw={"workers": 2})
    m1, m2 = run_pair(r1, r2, stream)
    assert_run_parity(r1, m1, r2, m2,
                      history_keys=("level", "expert_called"), costs=True)


@pytest.mark.multidevice
def test_mesh_composition_kernel_ladder():
    """Lane sharding the kernel ladder matches the unmeshed engine at
    the documented SPMD float tolerance."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices (multi-device CI job: "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.launch.mesh import make_mesh
    stream, cfg = _stream_cfg()
    ref = batched_engine(cfg, stream, n_streams=8)
    new = batched_engine(cfg, stream, n_streams=8,
                         mesh=make_mesh((8, 1), ("data", "model")))
    m_ref, m_new = run_pair(ref, new, stream)
    assert_run_parity(ref, m_ref, new, m_new, state="allclose",
                      attrs=("params", "dparams"),
                      history_keys=("level", "expert_called"),
                      rtol=MESH_RTOL, atol=MESH_ATOL)
