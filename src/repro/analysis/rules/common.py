"""Shared AST plumbing for the cascade-lint checkers.

The checkers care about *qualified* call targets (``np.random.default_rng``
must resolve to ``numpy.random.default_rng`` however numpy was imported),
and about which function a node sits in.  Both are resolved here once so
individual rules stay declarative.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


def import_table(tree: ast.AST) -> Dict[str, str]:
    """Map local names to fully qualified dotted paths.

    ``import numpy as np``                 -> ``{"np": "numpy"}``
    ``from jax import random as jr``       -> ``{"jr": "jax.random"}``
    ``from numpy.random import default_rng`` ->
    ``{"default_rng": "numpy.random.default_rng"}``
    """
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                table[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0])
                if alias.asname:
                    table[alias.asname] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for alias in node.names:
                table[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    return table


def qualified_name(node: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """Dotted path of a Name/Attribute chain with import aliases resolved.

    Returns None for anything that is not a plain ``a.b.c`` chain
    (subscripts, call results, ...).
    """
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = imports.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def call_name(call: ast.Call, imports: Dict[str, str]) -> Optional[str]:
    """Qualified name of a call's target, or None if unresolvable."""
    return qualified_name(call.func, imports)


def walk_with_function_stack(
        tree: ast.AST) -> Iterator[Tuple[ast.AST, List[FuncNode]]]:
    """Yield ``(node, enclosing-function-stack)`` pairs, outermost first."""
    def visit(node: ast.AST, stack: List[FuncNode]):
        yield node, stack
        child_stack = stack
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            child_stack = stack + [node]
        for child in ast.iter_child_nodes(node):
            yield from visit(child, child_stack)
    yield from visit(tree, [])


def param_names(fn: FuncNode) -> Set[str]:
    """All parameter names of a def/lambda (incl. *args/**kwargs/kw-only)."""
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def positional_param_names(fn: ast.FunctionDef) -> List[str]:
    """Ordered positional (non-kw-only) parameter names, ``self`` dropped."""
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    return names


def required_positional_names(fn: ast.FunctionDef) -> List[str]:
    """Positional parameters WITHOUT defaults (the tensor signature —
    trailing defaulted positionals are config knobs)."""
    names = positional_param_names(fn)
    n_defaults = len(fn.args.defaults)
    return names[:len(names) - n_defaults] if n_defaults else names


def root_name(node: ast.AST) -> Optional[str]:
    """The base variable of an attribute/subscript chain (``a`` of
    ``a.b[0].c``), or None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def is_builtin_call(call: ast.Call, name: str,
                    imports: Dict[str, str]) -> bool:
    """True when ``call`` targets the builtin ``name`` (not shadowed by an
    import; local shadowing is rare enough to accept)."""
    return (isinstance(call.func, ast.Name) and call.func.id == name
            and name not in imports)


def self_attribute(node: ast.AST) -> Optional[str]:
    """``X`` when ``node`` is exactly ``self.X``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def assigned_self_attrs(stmt: ast.stmt) -> Iterator[ast.Attribute]:
    """Yield ``self.X`` attribute nodes written by an assignment stmt."""
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        for node in ast.walk(t):
            if isinstance(node, ast.Attribute) and \
                    self_attribute(node) is not None:
                yield node


def string_value(node: ast.AST) -> Optional[str]:
    """The value of a string constant node, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
