"""CAS006 — docs drift contract (migrated from the CI shell greps).

PR 4 made the README + docs/ a CI-guarded surface with an ad-hoc inline
python step in the workflow; this rule owns that contract now, so it runs
locally, supports suppressions/baselining like every other check, and is
testable:

* ``README.md`` names every ``benchmarks/*.py`` and ``examples/*.py``
  file (token match — ``throughput.py`` inside ``batched_throughput.py``
  does not count for a new ``throughput.py``);
* the documentation surface exists and is linked from the README:
  ``docs/ARCHITECTURE.md``, ``docs/MODELS.md``, ``docs/ANALYSIS.md``.
"""
from __future__ import annotations

import re
from typing import Iterator

from repro.analysis.engine import Finding, RepoContext, Rule

REQUIRED_DOCS = ("docs/ARCHITECTURE.md", "docs/MODELS.md",
                 "docs/ANALYSIS.md")
NAMED_DIRS = ("benchmarks", "examples")


class DocsContractRule(Rule):
    """README/docs stay in lockstep with the runnable surface."""

    id = "CAS006"
    title = "docs contract (README names every benchmark/example)"

    def check_repo(self, repo: RepoContext) -> Iterator[Finding]:
        """Check README coverage and the docs/ surface."""
        readme_path = repo.root / "README.md"
        if not readme_path.is_file():
            if any(m.rel.startswith(NAMED_DIRS) for m in repo.modules):
                yield Finding(self.id, "README.md", 1, 0,
                              "README.md is missing")
            return
        readme = readme_path.read_text(encoding="utf-8")
        for d in NAMED_DIRS:
            base = repo.root / d
            if not base.is_dir():
                continue
            for p in sorted(base.glob("*.py")):
                if not re.search(r"(?<![\w-])" + re.escape(p.name), readme):
                    yield Finding(
                        self.id, f"{d}/{p.name}", 1, 0,
                        f"README.md does not mention {d}/{p.name} — every "
                        "benchmark/example must be indexed")
        for doc in REQUIRED_DOCS:
            if not (repo.root / doc).is_file():
                yield Finding(self.id, doc, 1, 0, f"{doc} is missing")
            elif doc not in readme:
                yield Finding(self.id, "README.md", 1, 0,
                              f"README.md does not link {doc}")
