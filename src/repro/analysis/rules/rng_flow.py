"""CAS007 — interprocedural tick-RNG dataflow.

CAS001 polices where generators may be *constructed*; this rule follows
the keys after construction.  The per-tick discipline (core/rng.py)
hands every tick a :class:`TickRngs` of purpose-separated generators —
``jump``, ``action``, ``cache[i]`` — and the parity contract depends on
each (lane, tick, level, purpose) generator being consumed by exactly
one draw site and never outliving its tick:

* **key reuse** — two draw sites consuming the same purpose of one
  ``tick_rngs`` binding (directly via a ``Generator`` draw method, or by
  passing the purpose into a function that draws from it) would make the
  second site's values depend on whether the first executed, desyncing
  any engine that pre-draws from one that draws lazily;
* **key escape** — storing a tick's generator (or any purpose of it) on
  ``self`` caches live generator *state* across ticks, so a later tick's
  draws depend on serving history instead of ``(seed, stream, t)``.

The rule builds a call summary across every scanned ``src/repro/core/``
module: a function that draws from one of its parameters (transitively,
to a fixpoint) is a *consumer* at that parameter position, and a
function that assigns a parameter to ``self.<attr>`` is a *store*.
Passing a purpose to a consumer counts as the purpose's one draw site;
passing it to a store is an escape.  Calls to classes (dataclass records
like ``_InFlightTick`` that carry a tick's own generators between the
pipeline stages of the same tick) are exempt — that is transport within
the tick, not caching across ticks.

Known limit: purposes are keyed by their source text relative to the
binding (``r.jump``, ``r.cache[i]``), so reuse hidden behind re-aliasing
through containers is not tracked — CAS001 confines constructions
tightly enough that the binding-rooted form covers the real engines.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import Finding, ModuleContext, RepoContext, Rule
from repro.analysis.rules.common import import_table, root_name

#: modules the dataflow is tracked in (the tick-key universe)
CORE_PREFIX = "src/repro/core/"

#: numpy Generator draw methods — a call to one consumes the key
DRAW_METHODS = {
    "random", "integers", "choice", "normal", "uniform", "permutation",
    "standard_normal", "shuffle", "permuted", "bytes",
}


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:           # pragma: no cover - unparse is total on 3.9+
        return "<expr>"


def _purpose_of(node: ast.AST, bindings: Set[str]) -> Optional[str]:
    """The purpose key of an expression rooted at a tick_rngs binding.

    ``r.jump`` -> ``"r.jump"``; ``r.cache[i]`` -> ``"r.cache[i]"``; the
    bare binding ``r`` -> ``"r"`` (the whole key bundle).  None when the
    expression is not rooted at a binding.
    """
    root = root_name(node)
    if root in bindings:
        return _unparse(node)
    return None


class _FnInfo:
    """Per-function summary used to propagate consumption across calls."""

    def __init__(self, name: str, node: ast.AST, rel: str,
                 params: List[str]):
        self.name = name
        self.node = node
        self.rel = rel
        self.params = params              # positional names, self dropped
        self.consumes: Set[int] = set()   # param positions drawn from
        self.stores: Set[int] = set()     # param positions put on self


def _positional(fn) -> List[str]:
    names = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    return names


def _class_names(modules) -> Set[str]:
    names: Set[str] = set()
    for mod in modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                names.add(node.name)
    return names


def _callee_name(call: ast.Call) -> Optional[str]:
    """Last dotted component of the call target (method-call friendly)."""
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


class RngFlowRule(Rule):
    """Every per-tick RNG purpose: one consumer, no caching on self."""

    id = "CAS007"
    title = "tick-RNG dataflow (one consumer per purpose, no escapes)"

    def check_repo(self, repo: RepoContext) -> Iterator[Finding]:
        """Summaries over core/, then per-function reuse/escape checks."""
        core = [m for m in repo.modules if m.rel.startswith(CORE_PREFIX)
                or "/core/" in m.rel]
        if not core:
            return
        classes = _class_names(repo.modules)
        summaries = self._build_summaries(core)
        for mod in core:
            for fn in ast.walk(mod.tree):
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield from self._check_function(mod, fn, summaries,
                                                    classes)

    # -- pass 1: which params does each core function draw from / store --
    def _build_summaries(self, core) -> Dict[str, _FnInfo]:
        infos: Dict[str, _FnInfo] = {}
        for mod in core:
            for fn in ast.walk(mod.tree):
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # last definition wins on name collisions — fine for
                    # the summary, which only answers "does a function of
                    # this name touch its k-th argument"
                    infos[fn.name] = _FnInfo(fn.name, fn, mod.rel,
                                             _positional(fn))
        changed = True
        while changed:
            changed = False
            for info in infos.values():
                params = set(info.params)
                for node in ast.walk(info.node):
                    if isinstance(node, ast.Call):
                        callee = _callee_name(node)
                        # direct draw: param.random(...) etc.
                        if (isinstance(node.func, ast.Attribute)
                                and node.func.attr in DRAW_METHODS):
                            r = root_name(node.func.value)
                            if r in params:
                                pos = info.params.index(r)
                                if pos not in info.consumes:
                                    info.consumes.add(pos)
                                    changed = True
                        # transitive: param passed to a consuming callee
                        sub = infos.get(callee or "")
                        if sub is not None:
                            for ai, arg in enumerate(node.args):
                                r = root_name(arg)
                                if r in params and ai in sub.consumes:
                                    pos = info.params.index(r)
                                    if pos not in info.consumes:
                                        info.consumes.add(pos)
                                        changed = True
                    elif isinstance(node, ast.Assign):
                        for tgt in node.targets:
                            if (isinstance(tgt, ast.Attribute)
                                    and isinstance(tgt.value, ast.Name)
                                    and tgt.value.id == "self"):
                                r = root_name(node.value)
                                if r in params:
                                    pos = info.params.index(r)
                                    if pos not in info.stores:
                                        info.stores.add(pos)
                                        changed = True
        return infos

    # -- pass 2: per tick_rngs binding, reuse + escape ---------------------
    def _check_function(self, mod: ModuleContext, fn,
                        summaries: Dict[str, _FnInfo],
                        classes: Set[str]) -> Iterator[Finding]:
        imports = import_table(mod.tree)
        bindings: Set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and isinstance(node.value,
                                                            ast.Call)):
                callee = _callee_name(node.value)
                if callee == "tick_rngs":
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            bindings.add(tgt.id)
        if not bindings:
            return
        # one walk, collecting draw sites keyed by (binding-rooted
        # purpose) and flagging escapes as they appear.  Nested defs are
        # NOT excluded: a closure drawing from the enclosing binding is
        # still one site of this function's tick.
        sites: Dict[str, List[Tuple[int, int, str]]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = _callee_name(node)
                # direct draw on a purpose: r.jump.random(...)
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr in DRAW_METHODS):
                    purpose = _purpose_of(node.func.value, bindings)
                    if purpose is not None:
                        sites.setdefault(purpose, []).append(
                            (node.lineno, node.col_offset, "draw"))
                    continue
                if callee in classes or (callee or "")[:1].isupper():
                    continue        # record/dataclass transport, not a draw
                sub = summaries.get(callee or "")
                for ai, arg in enumerate(node.args):
                    purpose = _purpose_of(arg, bindings)
                    if purpose is None:
                        continue
                    if sub is not None and ai in sub.stores:
                        yield Finding(
                            self.id, mod.rel, arg.lineno, arg.col_offset,
                            f"tick-RNG purpose '{purpose}' escapes into "
                            f"cached state via {callee}() (stores its "
                            f"argument on self) — per-tick keys must die "
                            "with their tick; derive later draws from "
                            "tick_rngs(seed, stream, t)")
                    if sub is None or ai in sub.consumes:
                        # unknown callees are assumed to consume: a
                        # missed duplicate is worse than a spurious one
                        sites.setdefault(purpose, []).append(
                            (arg.lineno, arg.col_offset,
                             f"passed to {callee or '<call>'}()"))
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        purpose = _purpose_of(node.value, bindings)
                        if purpose is not None:
                            yield Finding(
                                self.id, mod.rel, node.lineno,
                                node.col_offset,
                                f"tick-RNG purpose '{purpose}' escapes "
                                f"into cached state (self.{tgt.attr}) — "
                                "per-tick generators must not outlive "
                                "their tick; re-derive from "
                                "tick_rngs(seed, stream, t) instead")
        del imports     # reserved for qualified resolution extensions
        for purpose, uses in sorted(sites.items()):
            if len(uses) <= 1:
                continue
            first = uses[0]
            for line, col, how in uses[1:]:
                yield Finding(
                    self.id, mod.rel, line, col,
                    f"tick-RNG purpose '{purpose}' consumed again "
                    f"({how}; first drawn at line {first[0]}) — each "
                    "(lane, tick, level, purpose) key has exactly one "
                    "consumer; split another purpose from the tick's "
                    "SeedSequence instead of re-drawing")
