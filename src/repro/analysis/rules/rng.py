"""CAS001 — RNG discipline.

The bitwise parity story of every engine (batched, sharded, async,
pipelined, per-lane) rests on the pre-split per-tick RNG rule of
``core/rng.py``: all Algorithm-1 randomness flows through
``tick_rngs``/``sample_cache_indices``, derived from
``SeedSequence((seed, stream_id, t))``.  A single ad-hoc generator inside
an engine silently desyncs the reference and the batched path.

Enforced here:

* **Everywhere scanned** — RNG-source construction with no seed argument
  (``np.random.default_rng()``, ``random.Random()``) is nondeterministic
  by definition: flagged.
* **``src/repro/core/``** — even *seeded* construction is confined to
  whitelisted modules (``rng.py`` is the discipline itself;
  ``distill.py`` is the offline baseline) and to init/offline-training
  contexts (``__init__``/``__post_init__``/``reset``/``train_*``/
  ``*_init``), where randomness is consumed before the stream starts.
  Anything reachable per tick must take its generators from
  ``tick_rngs``.
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from repro.analysis.engine import Finding, ModuleContext, Rule
from repro.analysis.rules.common import (
    FuncNode, call_name, import_table, walk_with_function_stack)

RNG_CONSTRUCTORS = {
    "numpy.random.default_rng",
    "numpy.random.Generator",
    "numpy.random.RandomState",
    "numpy.random.SeedSequence",
    "jax.random.PRNGKey",
    "jax.random.key",
    "random.Random",
    "random.SystemRandom",
}

#: modules whose *job* is constructing generators (seeded) in core/
CORE_WHITELIST = {
    "src/repro/core/rng.py",       # the tick-RNG discipline itself
    "src/repro/core/distill.py",   # offline distillation baseline
}

CORE_PREFIX = "src/repro/core/"

#: function contexts where seeded construction is pre-stream, not per-tick
_ALLOWED_FUNCS = {"__init__", "__post_init__", "reset"}
_ALLOWED_PREFIXES = ("train_",)
_ALLOWED_SUFFIXES = ("_init",)


def _allowed_context(stack: List[FuncNode]) -> bool:
    for fn in stack:
        name = getattr(fn, "name", None)
        if name is None:
            continue
        if name in _ALLOWED_FUNCS:
            return True
        if name.startswith(_ALLOWED_PREFIXES) or \
                name.endswith(_ALLOWED_SUFFIXES):
            return True
    return False


class RngDisciplineRule(Rule):
    """All engine randomness flows through ``core/rng.py`` tick keys."""

    id = "CAS001"
    title = "RNG discipline (tick_rngs / sample_cache_indices)"

    def check_module(self, ctx: ModuleContext) -> Iterator[Finding]:
        """Flag unseeded RNG construction, and any construction on the
        per-tick paths of ``src/repro/core/``."""
        imports = import_table(ctx.tree)
        in_core = (ctx.rel.startswith(CORE_PREFIX)
                   and ctx.rel not in CORE_WHITELIST)
        for node, stack in walk_with_function_stack(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node, imports)
            if name not in RNG_CONSTRUCTORS:
                continue
            if not node.args and not node.keywords:
                yield Finding(
                    self.id, ctx.rel, node.lineno, node.col_offset,
                    f"unseeded RNG construction {name}() — every generator "
                    "must derive from an explicit seed (core engines: from "
                    "core.rng.tick_rngs)")
            elif in_core and not _allowed_context(stack):
                yield Finding(
                    self.id, ctx.rel, node.lineno, node.col_offset,
                    f"direct {name}(...) on a core/ serving path — per-tick "
                    "randomness must flow through core.rng.tick_rngs / "
                    "sample_cache_indices (whitelist: core/rng.py, "
                    "core/distill.py; init/offline-training contexts are "
                    "exempt)")
