"""CAS005 — the §8 kernel/level contract, machine-checked.

docs/ARCHITECTURE.md §8 and docs/MODELS.md promise, for every Pallas
kernel package ``src/repro/kernels/<name>/``:

* ``kernel.py``'s public entry points are consumed by ``ops.py`` (the
  jitted public wrapper that pads shapes and picks interpret mode);
* every public op in ``ops.py`` has a **signature-matching** pure-jnp
  twin in ``ref.py`` (same ordered positional parameters — the parity
  tests call both with the same tensors);
* every public op is exported through the package ``__init__.__all__``.

And for the cascade's level zoo: every ``LevelSpec(kind=...)`` string
constructed anywhere in ``src/repro`` must have an analytic FLOP model
in ``metrics/costs.py`` (``<kind>_flops`` or ``<kind>_student_flops``) —
the deferral penalties c_i are only honest if each level's cost is
derived, not guessed.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Set

from repro.analysis.engine import Finding, RepoContext, Rule
from repro.analysis.rules.common import required_positional_names, string_value

KERNELS_DIR = "src/repro/kernels"
COSTS_PATH = "src/repro/metrics/costs.py"

#: level kinds costed under another kind's FLOP model on purpose
KIND_ALIASES = {"tinytf_large": "tinytf"}


def _parse(path: Path) -> Optional[ast.Module]:
    try:
        return ast.parse(path.read_text(encoding="utf-8"), str(path))
    except (OSError, SyntaxError):
        return None


def _public_defs(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef) and not n.name.startswith("_")}


def _all_exports(tree: ast.Module) -> Optional[Set[str]]:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    vals = {string_value(e)
                            for e in getattr(node.value, "elts", [])}
                    return {v for v in vals if v}
    return None


def _names_used(tree: ast.Module) -> Set[str]:
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                used.add(alias.asname or alias.name.split(".")[-1])
    return used


class KernelContractRule(Rule):
    """kernel.py/ref.py/ops.py/__init__ stay a closed, parity-testable
    contract, and every level kind keeps a FLOP model."""

    id = "CAS005"
    title = "kernel/level contract (ops twins, __all__, FLOP models)"

    def check_repo(self, repo: RepoContext) -> Iterator[Finding]:
        """Structural sweep over kernels/ + the LevelSpec-kind cost map."""
        yield from self._check_kernels(repo.root)
        yield from self._check_level_kinds(repo)

    # -- kernels/<name>/ packages -----------------------------------------
    def _check_kernels(self, root: Path) -> Iterator[Finding]:
        kdir = root / KERNELS_DIR
        if not kdir.is_dir():
            return
        for pkg in sorted(p for p in kdir.iterdir() if p.is_dir()):
            kernel_py = pkg / "kernel.py"
            if not kernel_py.is_file():
                continue
            rel = f"{KERNELS_DIR}/{pkg.name}"
            ktree = _parse(kernel_py)
            ops_py, ref_py, init_py = (pkg / "ops.py", pkg / "ref.py",
                                       pkg / "__init__.py")
            for req in (ops_py, ref_py, init_py):
                if not req.is_file():
                    yield Finding(self.id, f"{rel}/kernel.py", 1, 0,
                                  f"kernel package is missing {req.name} "
                                  "(§8 contract: kernel/ref/ops triple)")
            otree = _parse(ops_py) if ops_py.is_file() else None
            rtree = _parse(ref_py) if ref_py.is_file() else None
            itree = _parse(init_py) if init_py.is_file() else None
            if ktree is not None and otree is not None:
                used = _names_used(otree)
                for name, node in _public_defs(ktree).items():
                    if name not in used:
                        yield Finding(
                            self.id, f"{rel}/kernel.py", node.lineno, 0,
                            f"public kernel entry {name}() is not consumed "
                            "by ops.py — dead kernel or missing wrapper")
            if otree is None:
                continue
            ref_defs = _public_defs(rtree) if rtree is not None else {}
            ref_sigs = {tuple(required_positional_names(fn)): n
                        for n, fn in ref_defs.items()}
            exports = _all_exports(itree) if itree is not None else None
            for name, node in _public_defs(otree).items():
                sig = tuple(required_positional_names(node))
                if rtree is not None and sig not in ref_sigs:
                    yield Finding(
                        self.id, f"{rel}/ops.py", node.lineno, 0,
                        f"public op {name}({', '.join(sig)}) has no "
                        "signature-matching ref.py twin — the parity "
                        "tests need a pure-jnp oracle with the same "
                        "positional parameters")
                if exports is not None and name not in exports:
                    yield Finding(
                        self.id, f"{rel}/ops.py", node.lineno, 0,
                        f"public op {name}() is not exported in "
                        "__init__.__all__")

    # -- LevelSpec kinds vs metrics/costs.py -------------------------------
    def _check_level_kinds(self, repo: RepoContext) -> Iterator[Finding]:
        costs_path = repo.root / COSTS_PATH
        ctree = _parse(costs_path)
        if ctree is None:
            return      # no cost model in this tree (fixture repos)
        cost_fns = set(_public_defs(ctree))
        kinds: List = []       # (kind, rel, lineno)
        for mod in repo.modules:
            if not mod.rel.startswith("src/repro/"):
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                fname = node.func.attr if isinstance(node.func, ast.Attribute) \
                    else getattr(node.func, "id", "")
                if fname != "LevelSpec":
                    continue
                for kw in node.keywords:
                    if kw.arg == "kind":
                        kind = string_value(kw.value)
                        if kind:
                            kinds.append((kind, mod.rel, node.lineno))
        seen: Set[str] = set()
        for kind, rel, lineno in kinds:
            if kind in seen:
                continue
            seen.add(kind)
            base = KIND_ALIASES.get(kind, kind)
            if f"{base}_flops" not in cost_fns and \
                    f"{base}_student_flops" not in cost_fns:
                yield Finding(
                    self.id, rel, lineno, 0,
                    f"LevelSpec kind '{kind}' has no FLOP model in "
                    f"metrics/costs.py (expected {base}_flops or "
                    f"{base}_student_flops) — deferral penalties must be "
                    "derived from analytic costs")
