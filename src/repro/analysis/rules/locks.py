"""CAS004 — lock discipline (a static race detector for the expert pool).

``core/experts.py`` shares mutable state between the engine thread and W
pool workers (PR 5).  The convention machine-checked here: an attribute
whose initializing assignment carries a ``# guarded-by: <lock>`` comment

    self._shards = ...   # guarded-by: _lock

may only be touched inside a ``with self.<lock>:`` block, in every method
of the class except the constructor family (``__init__``,
``__post_init__``, ``__del__`` — no concurrent aliases can exist yet/
anymore).  The lock itself must be created in the constructor.  This
catches the classic pool bug — a new method reading ``self._shards``
bare while a worker resolves a shard — at lint time instead of as a
once-a-month flaky parity failure.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Set

from repro.analysis.engine import Finding, ModuleContext, Rule
from repro.analysis.rules.common import self_attribute

_GUARD_RE = re.compile(r"#\s*guarded-by:\s*(\w+)")

#: methods where the object is not yet / no longer shared
_EXEMPT_METHODS = {"__init__", "__post_init__", "__del__", "__new__"}


def _guard_comment(lines: List[str], lineno: int) -> str:
    """The lock name annotated on a 1-based source line, or ''."""
    if 1 <= lineno <= len(lines):
        m = _GUARD_RE.search(lines[lineno - 1])
        if m:
            return m.group(1)
    return ""


class LockDisciplineRule(Rule):
    """``# guarded-by:`` attributes only under ``with self.<lock>:``."""

    id = "CAS004"
    title = "lock discipline (guarded-by annotations)"

    def check_module(self, ctx: ModuleContext) -> Iterator[Finding]:
        """Check every class that declares guarded attributes."""
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(node, ctx)

    def _check_class(self, cls: ast.ClassDef,
                     ctx: ModuleContext) -> Iterator[Finding]:
        guarded: Dict[str, str] = {}    # attr -> lock attr
        for node in ast.walk(cls):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                lock = _guard_comment(ctx.lines, node.lineno)
                if not lock:
                    continue
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    attr = self_attribute(t)
                    if attr is None and isinstance(t, ast.Name):
                        attr = t.id      # class-level declaration
                    if attr is not None:
                        guarded[attr] = lock
        if not guarded:
            return
        for stmt in cls.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name not in _EXEMPT_METHODS:
                yield from self._check_method(stmt, guarded, ctx, cls.name)

    def _check_method(self, method: ast.FunctionDef,
                      guarded: Dict[str, str], ctx: ModuleContext,
                      cls_name: str) -> Iterator[Finding]:
        locks = set(guarded.values())

        # exhaustive walker tracking which guard locks are held lexically
        def walk(node: ast.AST, held: Set[str]) -> Iterator[Finding]:
            if isinstance(node, ast.With):
                inner = set(held)
                for item in node.items:
                    a = self_attribute(item.context_expr)
                    if a in locks:
                        inner.add(a)
                for item in node.items:
                    yield from walk(item, held)
                for child in node.body:
                    yield from walk(child, inner)
                return
            a = self_attribute(node)
            if a is not None and a in guarded and guarded[a] not in held:
                yield Finding(
                    self.id, ctx.rel, node.lineno, node.col_offset,
                    f"{cls_name}.{method.name} touches self.{a} outside "
                    f"'with self.{guarded[a]}:' (declared guarded-by "
                    f"{guarded[a]})")
            for child in ast.iter_child_nodes(node):
                yield from walk(child, held)

        for stmt in method.body:
            yield from walk(stmt, set())
