"""CAS002 — determinism hazards.

The repo's reproducibility rule (learned twice in the seed code, fixed in
PR 1): anything that feeds a seed, an ordering, or a printed result must
be a deterministic function of the run configuration.  Python breaks this
in well-camouflaged ways:

* builtin ``hash()`` on strings is salted per process (PYTHONHASHSEED) —
  the PR-1 bug: ``default_rng(hash(f"{seed}:{name}"))`` gave every run a
  different corpus.  Use ``zlib.crc32`` on the encoded string.
* ``id()`` values change run to run — ordering by them (sort keys) makes
  output order an allocator artifact.
* ``time.time()`` / ``os.urandom()`` / ``uuid.uuid4()`` in a seed position
  makes the seed itself nondeterministic (timing *measurements* are fine).
* the legacy ``np.random.*`` module-level samplers share one hidden global
  generator across every caller — unseedable in any composable way.
* iterating a ``set`` literal/constructor feeds PYTHONHASHSEED-dependent
  order into whatever consumes the loop (wrap in ``sorted()``).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional

from repro.analysis.engine import Finding, ModuleContext, Rule
from repro.analysis.rules.common import (
    call_name, import_table, is_builtin_call)

#: legacy global-state samplers of the pre-Generator numpy API
LEGACY_NP_RANDOM = {
    "seed", "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "beta", "binomial", "poisson", "standard_normal", "bytes", "get_state",
    "set_state",
}

#: wall-clock / entropy sources that must never feed a seed
NONDET_SOURCES = {
    "time.time", "time.time_ns", "time.monotonic", "time.perf_counter",
    "os.urandom", "uuid.uuid4", "datetime.datetime.now",
    "datetime.datetime.utcnow", "secrets.token_bytes", "secrets.randbits",
}

#: call targets whose arguments are seed positions
SEED_SINKS = {
    "numpy.random.default_rng", "numpy.random.SeedSequence",
    "numpy.random.RandomState", "numpy.random.seed",
    "jax.random.PRNGKey", "jax.random.key", "random.seed", "random.Random",
}

_ORDERING_CALLS = {"sorted", "min", "max"}


def _contains_nondet_source(node: ast.AST,
                            imports: Dict[str, str]) -> Optional[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub, imports)
            if name in NONDET_SOURCES:
                return name
    return None


def _contains_id_call(node: ast.AST, imports: Dict[str, str]) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and is_builtin_call(sub, "id", imports):
            return True
        if isinstance(sub, ast.Name) and sub.id == "id" and \
                "id" not in imports:
            return True
    return False


def _set_expr(node: ast.AST, imports: Dict[str, str]) -> bool:
    if isinstance(node, ast.Set):
        return True
    if isinstance(node, ast.Call):
        return (is_builtin_call(node, "set", imports)
                or is_builtin_call(node, "frozenset", imports))
    return False


class DeterminismRule(Rule):
    """No salted hashes, id() ordering, wall-clock seeds, global numpy
    RNG, or raw-set iteration order."""

    id = "CAS002"
    title = "determinism hazards (hash()/id()/time-seeds/np.random.*/sets)"

    def check_module(self, ctx: ModuleContext) -> Iterator[Finding]:
        """Flag the five hazard classes documented in the module docstring."""
        imports = import_table(ctx.tree)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(node, ctx, imports)
            elif isinstance(node, ast.Assign):
                yield from self._check_seed_assign(node, ctx, imports)
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if _set_expr(it, imports):
                    line = getattr(node, "lineno", it.lineno)
                    col = getattr(node, "col_offset", it.col_offset)
                    yield Finding(
                        self.id, ctx.rel, line, col,
                        "iteration over a set is PYTHONHASHSEED-ordered — "
                        "wrap it in sorted() before it feeds results")

    def _check_call(self, node: ast.Call, ctx: ModuleContext,
                    imports: Dict[str, str]) -> Iterator[Finding]:
        if is_builtin_call(node, "hash", imports):
            yield Finding(
                self.id, ctx.rel, node.lineno, node.col_offset,
                "builtin hash() is salted per process (the PR-1 seeding "
                "bug) — use zlib.crc32(s.encode()) for stable hashing")
            return
        name = call_name(node, imports)
        if name is not None and name.startswith("numpy.random."):
            tail = name.rsplit(".", 1)[1]
            if tail in LEGACY_NP_RANDOM:
                yield Finding(
                    self.id, ctx.rel, node.lineno, node.col_offset,
                    f"legacy global-state sampler {name}() — construct a "
                    "seeded np.random.default_rng(seed) (engines: tick_rngs)")
                return
        if name in SEED_SINKS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                src = _contains_nondet_source(arg, imports)
                if src is not None:
                    yield Finding(
                        self.id, ctx.rel, node.lineno, node.col_offset,
                        f"{src}() feeds a seed position of {name}() — seeds "
                        "must be deterministic functions of the run config")
        if name in _ORDERING_CALLS or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "sort"):
            for kw in node.keywords:
                if kw.arg == "key" and _contains_id_call(kw.value, imports):
                    yield Finding(
                        self.id, ctx.rel, node.lineno, node.col_offset,
                        "ordering by id() is allocator-dependent — sort by "
                        "a stable key")

    def _check_seed_assign(self, node: ast.Assign, ctx: ModuleContext,
                           imports: Dict[str, str]) -> Iterator[Finding]:
        seedish = any(isinstance(t, ast.Name) and "seed" in t.id.lower()
                      for t in node.targets)
        if not seedish:
            return
        src = _contains_nondet_source(node.value, imports)
        if src is not None:
            yield Finding(
                self.id, ctx.rel, node.lineno, node.col_offset,
                f"{src}() assigned to a seed variable — seeds must be "
                "deterministic functions of the run config")
