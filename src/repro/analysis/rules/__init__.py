"""The cascade-lint rule registry (CAS001–CAS008)."""
from repro.analysis.rules.determinism import DeterminismRule
from repro.analysis.rules.docs_contract import DocsContractRule
from repro.analysis.rules.jit_purity import JitPurityRule
from repro.analysis.rules.kernel_contract import KernelContractRule
from repro.analysis.rules.locks import LockDisciplineRule
from repro.analysis.rules.rng import RngDisciplineRule
from repro.analysis.rules.rng_flow import RngFlowRule
from repro.analysis.rules.sharding_contract import ShardingContractRule

#: registration order == report order for equal positions
ALL_RULES = (
    RngDisciplineRule,
    DeterminismRule,
    JitPurityRule,
    LockDisciplineRule,
    KernelContractRule,
    DocsContractRule,
    RngFlowRule,
    ShardingContractRule,
)

__all__ = [
    "ALL_RULES",
    "RngDisciplineRule", "DeterminismRule", "JitPurityRule",
    "LockDisciplineRule", "KernelContractRule", "DocsContractRule",
    "RngFlowRule", "ShardingContractRule",
]
