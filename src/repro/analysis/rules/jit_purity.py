"""CAS003 — jit purity.

Functions staged by ``jax.jit`` / ``shard_map`` / the repo's
``sharding.jit_*`` factories execute as traced computations: Python side
effects run once at trace time and silently disappear from later calls,
host syncs (``.item()``, ``float()`` on a tracer) either throw under jit
or serialize the device pipeline, and a buffer passed at a
``donate_argnums`` position is dead the moment the call returns.

Three checks, all within one module (cross-module staging is out of
static reach and stays the parity suite's job):

1. a jit-reached function must not mutate ``self``/enclosing state
   (``self.x = ...``, ``global``/``nonlocal``);
2. it must not call ``.item()`` or ``float()/int()/bool()`` on values
   rooted at its own parameters (tracers);
3. after a call to a locally-defined donating jitted callable, the
   variables passed at donated positions must not be read again before
   reassignment.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set

from repro.analysis.engine import Finding, ModuleContext, Rule
from repro.analysis.rules.common import (
    FuncNode, call_name, import_table, param_names, root_name,
    self_attribute)

#: call targets that stage their first positional argument
_STAGING_CALLS = {"jax.jit", "jax.experimental.shard_map.shard_map",
                  "shard_map"}
#: repo convention: sharding factories named jit_* stage their first arg
_STAGING_NAME_RE = re.compile(r"(^|\.)jit_\w+$")

_CAST_BUILTINS = {"float", "int", "bool"}


def _is_partial_of_jit(call: ast.Call, imports: Dict[str, str]) -> bool:
    name = call_name(call, imports)
    if name not in ("functools.partial", "partial"):
        return False
    return bool(call.args) and call_name_or_qual(call.args[0], imports) \
        in _STAGING_CALLS


def call_name_or_qual(node: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """Qualified name of a bare Name/Attribute expression (not a call)."""
    from repro.analysis.rules.common import qualified_name
    return qualified_name(node, imports)


def _staging_call(call: ast.Call, imports: Dict[str, str]) -> bool:
    name = call_name(call, imports)
    if name in _STAGING_CALLS:
        return True
    if name is not None and _STAGING_NAME_RE.search(name):
        return True
    # functools.partial(jax.jit, ...)(fn) — the outer call stages fn
    if isinstance(call.func, ast.Call) and \
            _is_partial_of_jit(call.func, imports):
        return True
    return False


def _jit_decorated(fn: ast.AST, imports: Dict[str, str]) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        if call_name_or_qual(dec, imports) in _STAGING_CALLS:
            return True
        if isinstance(dec, ast.Call):
            if call_name(dec, imports) in _STAGING_CALLS:
                return True
            if _is_partial_of_jit(dec, imports):
                return True
    return False


def _static_params(call: Optional[ast.Call], fn: FuncNode) -> Set[str]:
    """Parameter names marked static in a jit call/decorator (they are
    concrete Python values, not tracers — host casts on them are fine)."""
    if call is None:
        return set()
    static: Set[str] = set()
    ordered = [p.arg for p in fn.args.posonlyargs + fn.args.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, str):
                    static.add(sub.value)
        elif kw.arg == "static_argnums":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, int) and \
                        sub.value < len(ordered):
                    static.add(ordered[sub.value])
    # kw-only params of a jitted fn are necessarily static-like configs
    static.update(p.arg for p in fn.args.kwonlyargs)
    return static


def _donated_positions(call: ast.Call) -> List[int]:
    """Literal ``donate_argnums`` positions of a jax.jit call, if any."""
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)):
            return [e.value for e in v.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int)]
    return []


class JitPurityRule(Rule):
    """Jit-staged functions stay pure; donated buffers die at the call."""

    id = "CAS003"
    title = "jit purity (no self-mutation / host syncs / donated reads)"

    def check_module(self, ctx: ModuleContext) -> Iterator[Finding]:
        """Resolve the module's jit-reached functions, then check each."""
        imports = import_table(ctx.tree)
        defs_by_name: Dict[str, FuncNode] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(node.name, node)

        jitted: List[tuple] = []    # (fn node, static param names)
        donating: Dict[str, List[int]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and _jit_decorated(node, imports):
                dec_call = next(
                    (d for d in node.decorator_list
                     if isinstance(d, ast.Call)), None)
                jitted.append((node, _static_params(dec_call, node)))
            if not isinstance(node, ast.Call):
                continue
            if _staging_call(node, imports) and node.args:
                target = node.args[0]
                if isinstance(target, ast.Lambda):
                    jitted.append((target, _static_params(node, target)))
                elif isinstance(target, ast.Name) and \
                        target.id in defs_by_name:
                    fn = defs_by_name[target.id]
                    jitted.append((fn, _static_params(node, fn)))
        # assignments binding a donating jax.jit(...) to a local name
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    call_name(node.value, imports) in _STAGING_CALLS:
                pos = _donated_positions(node.value)
                if pos:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            donating[t.id] = pos

        seen: Set[int] = set()
        for fn, static in jitted:
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            yield from self._check_staged(fn, ctx, static)
        if donating:
            for node in ast.walk(ctx.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield from self._check_donation(node.body, donating, ctx)
            if isinstance(ctx.tree, ast.Module):
                yield from self._check_donation(ctx.tree.body, donating, ctx)

    # -- staged-function purity ----------------------------------------
    def _check_staged(self, fn: FuncNode, ctx: ModuleContext,
                      static: Set[str]) -> Iterator[Finding]:
        params = param_names(fn) - static
        label = getattr(fn, "name", "<lambda>")
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in targets:
                        for sub in ast.walk(t):
                            attr = self_attribute(sub)
                            if attr is not None and \
                                    isinstance(sub.ctx, ast.Store):
                                yield Finding(
                                    self.id, ctx.rel, sub.lineno,
                                    sub.col_offset,
                                    f"jit-staged {label}() mutates "
                                    f"self.{attr} — the write happens once "
                                    "at trace time, not per call")
                elif isinstance(node, (ast.Global, ast.Nonlocal)):
                    kind = "global" if isinstance(node, ast.Global) \
                        else "nonlocal"
                    yield Finding(
                        self.id, ctx.rel, node.lineno, node.col_offset,
                        f"jit-staged {label}() rebinds {kind} "
                        f"{', '.join(node.names)} — trace-time side effect")
                elif isinstance(node, ast.Call):
                    if isinstance(node.func, ast.Attribute) and \
                            node.func.attr == "item" and not node.args:
                        yield Finding(
                            self.id, ctx.rel, node.lineno, node.col_offset,
                            f"jit-staged {label}() calls .item() — host "
                            "sync on a tracer")
                    elif isinstance(node.func, ast.Name) and \
                            node.func.id in _CAST_BUILTINS and \
                            len(node.args) == 1 and \
                            root_name(node.args[0]) in params:
                        yield Finding(
                            self.id, ctx.rel, node.lineno, node.col_offset,
                            f"jit-staged {label}() calls "
                            f"{node.func.id}() on tracer argument "
                            f"'{root_name(node.args[0])}' — host sync")

    # -- donated-buffer reads --------------------------------------------
    def _check_donation(self, body: Sequence[ast.stmt],
                        donating: Dict[str, List[int]],
                        ctx: ModuleContext) -> Iterator[Finding]:
        dead: Dict[str, str] = {}   # var -> jitted callee that consumed it
        for stmt in body:
            # reads first (the donating call's own args are not yet dead)
            if dead:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Name) and \
                            isinstance(node.ctx, ast.Load) and \
                            node.id in dead:
                        yield Finding(
                            self.id, ctx.rel, node.lineno, node.col_offset,
                            f"read of '{node.id}' after it was donated to "
                            f"{dead[node.id]}(...) — donated buffers are "
                            "invalidated by the call")
                        dead.pop(node.id, None)
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id in donating:
                    for pos in donating[node.func.id]:
                        if pos < len(node.args) and \
                                isinstance(node.args[pos], ast.Name):
                            dead[node.args[pos].id] = node.func.id
            # reassignment revives the name
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Name):
                            dead.pop(sub.id, None)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                for sub in ast.walk(stmt.target):
                    if isinstance(sub, ast.Name):
                        dead.pop(sub.id, None)
