"""CAS008 — sharding-spec consistency between core/ and sharding/.

The batched engine's mesh placement contract lives in
``sharding/specs.py`` (lane-major dims shard via ``lane_spec``/
``put_lanes``, shared cascade state replicates via ``put_replicated``,
and the ``jit_*`` factories carry the ``donate_argnums`` annotations),
while the arrays it governs live in ``core/batched.py``.  The per-file
rules cannot see across that boundary; this rule checks three
cross-module invariants:

1. **spec-surface integrity** — every name a ``core/`` module imports
   from ``repro.sharding`` must exist in ``sharding/specs.py`` and be
   exported through ``sharding/__init__.__all__``.  A renamed or
   un-exported helper otherwise only fails at engine import time (or
   silently resolves to a stale re-export).
2. **explicit placement** — engine state reaches devices only through
   the spec helpers: a bare single-argument ``jax.device_put(x)`` in
   ``core/`` picks the default device with no lane/replication rule and
   desyncs from the mesh'd path; use ``put_lanes``/``put_replicated``
   (or pass an explicit sharding).
3. **donation deadness across function boundaries** — for every
   ``self.<attr> = jit_*factory*(...)`` whose factory body (in
   ``sharding/specs.py``) jits with ``donate_argnums``, any
   ``self``-rooted buffer passed at a donated position of a
   ``self.<attr>(...)`` call site must be reassigned later in the same
   function.  CAS003 checks donated *locals* against a literal
   ``donate_argnums`` in the same file; here the donation annotation
   lives in another module, so the per-file rule is blind to it — this
   is exactly how a stale ``self._cache_x`` read after the scatter
   donated it would slip through.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from repro.analysis.engine import Finding, ModuleContext, RepoContext, Rule
from repro.analysis.rules.common import (
    call_name, import_table, string_value)

CORE_MARKER = "/core/"
SPECS_PATH = "src/repro/sharding/specs.py"
INIT_PATH = "src/repro/sharding/__init__.py"
PKG = "repro.sharding"


def _public_defs(tree: ast.Module) -> Set[str]:
    return {n.name for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.ClassDef))
            and not n.name.startswith("_")}


def _module_constants(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            out.add(node.target.id)
    return out


def _all_exports(tree: ast.Module) -> Optional[Set[str]]:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    vals = {string_value(e)
                            for e in getattr(node.value, "elts", [])}
                    return {v for v in vals if v}
    return None


def _donating_factories(tree: ast.Module) -> Dict[str, Set[int]]:
    """Factory defs in specs.py whose bodies jit with donate_argnums.

    ``jit_route_pass`` -> {2}, ``jit_cache_scatter`` -> {0, 1}.  The
    donation may be conditional (mesh-gated); callers must satisfy
    deadness unconditionally, so positions are collected from every
    branch.
    """
    out: Dict[str, Set[int]] = {}
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        donated: Set[int] = set()
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            for kw in sub.keywords:
                if kw.arg != "donate_argnums":
                    continue
                for e in ast.walk(kw.value):
                    if isinstance(e, ast.Constant) and \
                            isinstance(e.value, int):
                        donated.add(e.value)
        if donated:
            out[node.name] = donated
    return out


def _self_attr_chain(node: ast.AST) -> Optional[str]:
    """``X`` when ``node`` is rooted at ``self.X`` (through subscripts /
    a wrapping ``tuple()``/``list()`` copy — the copy shares buffers, so
    donation still kills the original)."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("tuple", "list") and node.args:
        node = node.args[0]
    while isinstance(node, (ast.Subscript,)):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class ShardingContractRule(Rule):
    """core/ and sharding/specs.py agree on surface, placement, donation."""

    id = "CAS008"
    title = "sharding-spec consistency (surface, placement, donation)"

    def check_repo(self, repo: RepoContext) -> Iterator[Finding]:
        """Resolve the spec surface once, then sweep core/ modules."""
        specs = repo.module(SPECS_PATH)
        init = repo.module(INIT_PATH)
        if specs is None and init is None:
            # look outside the scanned set (narrowed runs still check)
            for rel in (SPECS_PATH, INIT_PATH):
                path = repo.root / rel
                if path.is_file():
                    from repro.analysis.engine import load_module
                    ctx, _ = load_module(repo.root, path)
                    if ctx is not None:
                        if rel == SPECS_PATH:
                            specs = ctx
                        else:
                            init = ctx
        if specs is None:
            return          # no sharding package in this tree (fixtures)
        surface = _public_defs(specs.tree) | _module_constants(specs.tree)
        exports = _all_exports(init.tree) if init is not None else None
        factories = _donating_factories(specs.tree)
        for mod in repo.modules:
            if CORE_MARKER not in f"/{mod.rel}":
                continue
            yield from self._check_imports(mod, surface, exports)
            yield from self._check_bare_device_put(mod)
            yield from self._check_donation_deadness(mod, factories)

    # -- 1. spec-surface integrity ----------------------------------------
    def _check_imports(self, mod: ModuleContext, surface: Set[str],
                       exports: Optional[Set[str]]) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ImportFrom) or node.level:
                continue
            if node.module != PKG and not (
                    node.module or "").startswith(PKG + "."):
                continue
            for alias in node.names:
                name = alias.name
                if node.module == PKG and exports is not None \
                        and name not in exports:
                    yield Finding(
                        self.id, mod.rel, node.lineno, node.col_offset,
                        f"'{name}' is imported from {PKG} but not "
                        "exported in sharding/__init__.__all__ — add it "
                        "to the package surface or import from "
                        f"{PKG}.specs directly")
                if name not in surface:
                    yield Finding(
                        self.id, mod.rel, node.lineno, node.col_offset,
                        f"'{name}' is imported from {node.module} but "
                        "sharding/specs.py defines no such helper — the "
                        "engine/spec surface drifted")

    # -- 2. explicit placement --------------------------------------------
    def _check_bare_device_put(self, mod: ModuleContext
                               ) -> Iterator[Finding]:
        imports = import_table(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = call_name(node, imports)
            if qn != "jax.device_put":
                continue
            if len(node.args) >= 2 or any(
                    kw.arg in ("device", "sharding") or kw.arg is None
                    for kw in node.keywords):
                continue
            yield Finding(
                self.id, mod.rel, node.lineno, node.col_offset,
                "bare jax.device_put(x) in core/ places engine state "
                "with no lane/replication rule — use put_lanes / "
                "put_replicated (sharding/specs.py) or pass an explicit "
                "sharding")

    # -- 3. donation deadness across function boundaries ------------------
    def _check_donation_deadness(self, mod: ModuleContext,
                                 factories: Dict[str, Set[int]]
                                 ) -> Iterator[Finding]:
        if not factories:
            return
        # which self attrs hold a donating jitted callable (assignments
        # may sit inside list comprehensions — the pipelined per-level
        # route passes)
        donating_attrs: Dict[str, Set[int]] = {}
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign):
                continue
            attr = None
            for t in node.targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    attr = t.attr
            if attr is None:
                continue
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Call):
                    fname = sub.func.attr if isinstance(
                        sub.func, ast.Attribute) else getattr(
                        sub.func, "id", "")
                    if fname in factories:
                        donating_attrs[attr] = factories[fname]
        if not donating_attrs:
            return
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            yield from self._check_call_sites(mod, fn, donating_attrs)

    def _check_call_sites(self, mod: ModuleContext, fn,
                          donating_attrs: Dict[str, Set[int]]
                          ) -> Iterator[Finding]:
        body = list(ast.walk(fn))
        for node in body:
            if not isinstance(node, ast.Call):
                continue
            target = node.func
            while isinstance(target, ast.Subscript):
                target = target.value
            attr = _self_attr_chain(target)
            if attr not in donating_attrs:
                continue
            for pos in donating_attrs[attr]:
                if pos >= len(node.args):
                    continue
                donated = _self_attr_chain(node.args[pos])
                if donated is None:
                    continue        # transient value: dies on its own
                if not self._reassigned_after(fn, node.lineno, donated):
                    yield Finding(
                        self.id, mod.rel, node.args[pos].lineno,
                        node.args[pos].col_offset,
                        f"self.{donated} is passed at donated position "
                        f"{pos} of self.{attr}(...) (donate_argnums in "
                        "sharding/specs.py) but never reassigned in this "
                        "function — the attribute keeps pointing at a "
                        "dead buffer; rebind it from the call's outputs")

    @staticmethod
    def _reassigned_after(fn, lineno: int, attr: str) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and node.lineno > lineno:
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"
                            and t.attr == attr):
                        return True
        return False
