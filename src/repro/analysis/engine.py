"""cascade-lint rule engine: findings, suppressions, baseline, runner.

The engine is deliberately tiny: a rule is a class with an ``id`` and one
or both of ``check_module`` (AST of one file) / ``check_repo`` (whole-tree
structural contracts).  Everything repo-specific lives in
``repro.analysis.rules``; this module only knows how to walk files, parse
them, apply suppressions, and diff findings against a committed baseline.

Suppression syntax (checked per physical line).  Every suppression must
carry a trailing justification — free text after the rule ids saying WHY
the waiver is sound; a bare ``disable=CASxxx`` still suppresses but is
itself reported as a CAS000 finding (non-suppressible), so it fails
``--strict``::

    x = hash(s)          # cascade-lint: disable=CAS002 -- demo input, not a seed
    # cascade-lint: disable-next-line=CAS001,CAS002 (fixture exercises the bug)
    rng = np.random.default_rng()
    # cascade-lint: disable-file=CAS003 tracing helper, runs pre-jit
    # (disable-file must sit in the first 20 lines of the file)

Baseline format (one fingerprint per line, ``--write-baseline`` emits it)::

    CAS002 src/repro/data/streams.py a1b2c3d4  # hash() in seed position

Fingerprints hash (rule, path, message) — NOT the line number — so
findings don't churn when unrelated edits move code.  The baseline is a
ratchet: it may only shrink.  (crc32, not ``hash()``: rule CAS002 applies
to this tool too.)
"""
from __future__ import annotations

import ast
import re
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

#: ids are a strict comma list; everything after them is the (required)
#: justification text — see the module docstring's suppression syntax
_SUPPRESS_RE = re.compile(
    r"#\s*cascade-lint:\s*disable(?P<kind>-file|-next-line)?="
    r"(?P<ids>[A-Z0-9]+(?:\s*,\s*[A-Z0-9]+)*)(?P<just>.*)$")

#: directories never scanned, wherever they appear
_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache", "build",
              "dist"}


@dataclass(frozen=True)
class Finding:
    """One rule violation at a file position."""

    rule: str          # "CAS001" ... "CAS006" (or "CAS000" for parse errors)
    path: str          # repo-relative posix path
    line: int          # 1-based
    col: int           # 0-based
    message: str
    severity: str = "error"   # "error" | "warning"

    def render(self) -> str:
        """``path:line:col: RULE message`` — the CLI output line."""
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def fingerprint(finding: Finding) -> str:
    """Line-number-free identity of a finding, for the baseline ratchet."""
    raw = f"{finding.rule}:{finding.path}:{finding.message}".encode()
    return f"{zlib.crc32(raw) & 0xFFFFFFFF:08x}"


@dataclass
class ModuleContext:
    """One parsed file as the per-module rules see it."""

    root: Path         # repo root (absolute)
    path: Path         # absolute file path
    rel: str           # posix path relative to root
    source: str
    lines: List[str]
    tree: ast.AST


@dataclass
class RepoContext:
    """Whole-tree view for structural rules (kernel/docs contracts)."""

    root: Path
    modules: List[ModuleContext] = field(default_factory=list)

    def module(self, rel: str) -> Optional[ModuleContext]:
        """The scanned module at repo-relative path ``rel``, if any."""
        for m in self.modules:
            if m.rel == rel:
                return m
        return None


class Rule:
    """Base checker: subclasses set ``id``/``title`` and override one hook."""

    id: str = "CAS000"
    title: str = ""

    def check_module(self, ctx: ModuleContext) -> Iterator[Finding]:
        """Per-file findings (default: none)."""
        return iter(())

    def check_repo(self, repo: RepoContext) -> Iterator[Finding]:
        """Whole-tree findings (default: none)."""
        return iter(())


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def _suppressions(lines: Sequence[str]) -> Tuple[
        Set[str], Dict[int, Set[str]], List[Tuple[int, str]]]:
    """Parse ``cascade-lint:`` comments ->
    (file-wide ids, per-line ids, unjustified suppression lines).

    Per-line ids are keyed by the 1-based line a finding must sit on for
    the suppression to apply (``disable-next-line`` keys the line below
    the comment).  A suppression with no trailing justification text
    still suppresses (the waiver the author intended stays effective)
    but is returned in the third slot so the runner can report it — the
    policy is "every waiver says why", enforced as a CAS000 finding.
    """
    file_ids: Set[str] = set()
    line_ids: Dict[int, Set[str]] = {}
    bare: List[Tuple[int, str]] = []
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        ids = {s.strip() for s in m.group("ids").split(",") if s.strip()}
        if not m.group("just").strip():
            bare.append((i, ", ".join(sorted(ids))))
        kind = m.group("kind")
        if kind == "-file":
            if i <= 20:      # file-wide pragmas must sit near the top
                file_ids |= ids
        elif kind == "-next-line":
            line_ids.setdefault(i + 1, set()).update(ids)
        else:
            line_ids.setdefault(i, set()).update(ids)
    return file_ids, line_ids, bare


def _is_suppressed(finding: Finding, file_ids: Set[str],
                   line_ids: Dict[int, Set[str]],
                   bare: Sequence[Tuple[int, str]]) -> bool:
    del bare      # justification policy is enforced by the runner
    if finding.rule in file_ids:
        return True
    return finding.rule in line_ids.get(finding.line, set())


# ---------------------------------------------------------------------------
# file walking / parsing
# ---------------------------------------------------------------------------
def iter_py_files(root: Path, paths: Sequence[str]) -> Iterator[Path]:
    """Yield ``*.py`` files under each path (sorted, skip-list applied)."""
    for p in paths:
        base = (root / p) if not Path(p).is_absolute() else Path(p)
        if base.is_file() and base.suffix == ".py":
            yield base
            continue
        if not base.is_dir():
            continue
        for f in sorted(base.rglob("*.py")):
            if any(part in _SKIP_DIRS or part.startswith(".")
                   for part in f.relative_to(base).parts[:-1]):
                continue
            yield f


def load_module(root: Path, path: Path) -> Tuple[Optional[ModuleContext],
                                                 Optional[Finding]]:
    """Parse one file; on a syntax error return a CAS000 finding instead."""
    rel = path.relative_to(root).as_posix() if path.is_relative_to(root) \
        else path.as_posix()
    source = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return None, Finding("CAS000", rel, e.lineno or 1, e.offset or 0,
                             f"syntax error: {e.msg}")
    return ModuleContext(root=root, path=path, rel=rel, source=source,
                         lines=source.splitlines(), tree=tree), None


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def load_baseline(path: Path) -> Set[str]:
    """Read committed fingerprints; a missing file is an empty baseline."""
    if not path.is_file():
        return set()
    prints: Set[str] = set()
    for line in path.read_text(encoding="utf-8").splitlines():
        line = line.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        if len(parts) >= 3:
            prints.add(parts[2])
    return prints


def render_baseline(findings: Iterable[Finding]) -> str:
    """Serialize findings as a baseline file (``--write-baseline``)."""
    header = ("# cascade-lint baseline — a ratchet, not a waiver list.\n"
              "# Lines may only be REMOVED (fix the finding); new code must\n"
              "# be clean.  Regenerate with:  python -m repro.analysis "
              "--write-baseline\n")
    rows = [f"{f.rule} {f.path} {fingerprint(f)}  # {f.message}"
            for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))]
    return header + "".join(r + "\n" for r in rows)


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------
DEFAULT_PATHS = ("src", "benchmarks", "examples")


@dataclass
class AnalysisResult:
    """Everything one run produced (pre-baseline)."""

    findings: List[Finding]
    suppressed: int
    files: int


def run_analysis(root: Path, paths: Optional[Sequence[str]] = None,
                 rules: Optional[Sequence[Rule]] = None) -> AnalysisResult:
    """Run ``rules`` over ``paths`` under ``root``; suppressions applied.

    ``rules`` defaults to the full registry (``repro.analysis.rules``);
    ``paths`` defaults to ``src benchmarks examples``.
    """
    if rules is None:
        from repro.analysis.rules import ALL_RULES
        rules = [cls() for cls in ALL_RULES]
    paths = list(paths) if paths else list(DEFAULT_PATHS)

    repo = RepoContext(root=root)
    findings: List[Finding] = []
    unjustified: List[Finding] = []
    suppressed = 0
    suppression_maps: Dict[str, Tuple[Set[str], Dict[int, Set[str]],
                                      List[Tuple[int, str]]]] = {}

    for f in iter_py_files(root, paths):
        ctx, err = load_module(root, f)
        if err is not None:
            findings.append(err)
            continue
        repo.modules.append(ctx)
        maps = _suppressions(ctx.lines)
        suppression_maps[ctx.rel] = maps
        for line, ids in maps[2]:
            # the suppression stays effective, but the missing "why" is
            # a finding of its own — and is itself non-suppressible, so
            # the justification policy cannot be waived recursively
            unjustified.append(Finding(
                "CAS000", ctx.rel, line, 0,
                f"suppression of {ids} has no justification — append "
                "why the waiver is sound "
                "(# cascade-lint: disable=ID <reason>)"))
        for rule in rules:
            findings.extend(rule.check_module(ctx))

    for rule in rules:
        findings.extend(rule.check_repo(repo))

    kept: List[Finding] = []
    for fd in findings:
        maps = suppression_maps.get(fd.path)
        if maps is None:
            # repo-rule finding against an unscanned file: look it up
            target = root / fd.path
            if target.is_file() and target.suffix == ".py":
                try:
                    text = target.read_text(encoding="utf-8").splitlines()
                    maps = _suppressions(text)
                    suppression_maps[fd.path] = maps
                except OSError:
                    maps = None
        if maps is not None and _is_suppressed(fd, *maps):
            suppressed += 1
            continue
        kept.append(fd)
    kept.extend(unjustified)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return AnalysisResult(findings=kept, suppressed=suppressed,
                          files=len(repo.modules))
