"""cascade-san: runtime sanitizers for the cascade engines.

Static rules (``repro.analysis.rules``) check what the source *says*;
the three sanitizers here check what the engines actually *do* at
runtime.  All are zero-cost when off: the engines' hook sites guard on
one attribute read (``determinism_on()`` / ``trace_probe`` returning the
function unchanged), and nothing below imports jax or numpy at module
import time (the CI ``analysis`` job runs ``repro.analysis`` on bare
CPU pins with no deps installed).

Determinism sanitizer
---------------------
``enable({"determinism"})`` makes every engine tick append one record to
a per-engine :class:`Trace`: crc32 digests of each ``STATE_ATTRS`` entry
per level, the tick's routing decisions (chosen level / expert-called /
prediction per lane), per-lane digests of the consumed tick-RNG draws,
and the ring-buffer fill/ptr mirrors.  :func:`diff_traces` takes two
traces — e.g. ``workers=1`` vs ``workers=4``, ``pipeline_depth=0`` vs
``2``, mesh on vs off — and reports the FIRST divergence at
(tick, lane, level, attr) granularity instead of "params mismatch
somewhere".  ``tests/harness.py`` runs every parity test under this
sanitizer and attaches the first divergence to any parity failure.

Lock sanitizer
--------------
``enable({"locks"})`` instruments the ``# guarded-by:`` annotations of
``core/experts.py`` (the same annotations cascade-lint CAS004 checks
statically): any read/write of an annotated attribute without the
declared lock held raises :class:`LockSanitizerError` at the access, and
lock acquisitions are tracked in a per-thread held-stack so an
inconsistent acquisition order across the expert pool's locks raises
:class:`LockOrderError` (cycle detection over the order graph).

Retrace sanitizer
-----------------
``enable({"retrace"})`` makes the engines wrap every function they jit
with a trace-counting probe *before* staging (``trace_probe``): the
wrapped body only executes when XLA retraces, so ``retrace_report()``
counts compiles per compiled step function and ``retrace_check(limit)``
flags unexpected recompilation (the engines' bucketing bounds route-pass
shapes at O(log S); a shape leak shows up as an unbounded count).

Enable via code (``enable``/``disable``), via ``serve.py
--sanitize=determinism,locks,retrace``, or via the environment
(``CASCADE_SANITIZE=determinism,locks`` — ``enable_from_env`` is called
by tests/conftest.py, which is how the CI sanitizer job runs the matrix
smoke).  See docs/ANALYSIS.md "Sanitizers".
"""
from __future__ import annotations

import ast
import contextlib
import json
import os
import re
import sys
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Set, Tuple

MODES = ("determinism", "locks", "retrace")

ENV_VAR = "CASCADE_SANITIZE"

_active: Set[str] = set()
_state_lock = threading.Lock()


class SanitizerError(RuntimeError):
    """Base class for sanitizer-detected invariant violations."""


class LockSanitizerError(SanitizerError):
    """A ``# guarded-by:`` attribute was touched without its lock held."""


class LockOrderError(SanitizerError):
    """Two locks were acquired in inconsistent order (deadlock hazard)."""


# ---------------------------------------------------------------------------
# mode switchboard
# ---------------------------------------------------------------------------
def enable(modes: Iterable[str]) -> None:
    """Turn on the given sanitizer modes (subset of :data:`MODES`)."""
    modes = set(modes)
    bad = modes - set(MODES)
    if bad:
        raise ValueError(f"unknown sanitize mode(s) {sorted(bad)}; "
                         f"choose from {MODES}")
    with _state_lock:
        _active.update(modes)
    if "locks" in modes:
        instrument_locks()


def disable(modes: Optional[Iterable[str]] = None) -> None:
    """Turn off the given modes (all when ``modes`` is None)."""
    modes = set(MODES) if modes is None else set(modes)
    with _state_lock:
        _active.difference_update(modes)
    if "locks" in modes:
        uninstrument_locks()


def active_modes() -> Set[str]:
    """The currently enabled sanitizer modes."""
    return set(_active)


def enable_from_env(var: str = ENV_VAR) -> Set[str]:
    """Enable the comma-separated modes named in ``$CASCADE_SANITIZE``.

    A no-op when the variable is unset/empty; returns the enabled set.
    tests/conftest.py calls this, which is how the CI sanitizer job runs
    the whole matrix smoke under ``--sanitize``.
    """
    raw = os.environ.get(var, "")
    modes = {m.strip() for m in raw.split(",") if m.strip()}
    if modes:
        enable(modes)
    return modes


# ---------------------------------------------------------------------------
# determinism sanitizer: per-tick trace + first-divergence differ
# ---------------------------------------------------------------------------
def determinism_on() -> bool:
    """Fast engine-side guard: is the determinism tracer recording?"""
    return "determinism" in _active


def retrace_on() -> bool:
    """Fast engine-side guard: is the retrace counter installed?"""
    return "retrace" in _active


class Trace:
    """One engine run's per-tick records (the determinism trace).

    Each record is a plain dict (JSON-serializable)::

        {"t":     tick number,
         "level": [chosen level per lane]      (nlev = went to expert),
         "called": [0/1 expert-called per lane],
         "pred":  [emitted prediction per lane],
         "rng":   [crc32 of lane's consumed (jump, action) draws],
         "cache_n": [ring fill per level], "cache_ptr": [ptr per level],
         "state": {"<level>.<attr>": crc32 of the state tree's leaves}}

    Traces from runs with identical tick shapes (same S, same stream)
    are comparable tick-by-tick with :func:`diff_traces` — the
    sequential engine records one entry per item (a 1-lane tick), so it
    aligns with a batched ``n_streams=1`` trace exactly.
    """

    def __init__(self) -> None:
        self.ticks: List[dict] = []

    def __len__(self) -> int:
        return len(self.ticks)

    def append(self, rec: dict) -> None:
        """Append one tick record."""
        self.ticks.append(rec)

    def save(self, path: str) -> None:
        """Write the trace as JSON-lines (one tick record per line)."""
        with open(path, "w", encoding="utf-8") as fh:
            for rec in self.ticks:
                fh.write(json.dumps(rec) + "\n")

    @staticmethod
    def load(path: str) -> "Trace":
        """Read a trace written by :meth:`save`."""
        tr = Trace()
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if line:
                    tr.append(json.loads(line))
        return tr


def trace_of(engine) -> Optional[Trace]:
    """The trace recorded on ``engine`` (None when never recorded)."""
    return getattr(engine, "_san_trace", None)


def concat_traces(a: Optional[Trace], b: Optional[Trace]
                  ) -> Optional[Trace]:
    """Join two trace segments end-to-end (checkpoint/restore runs).

    A run interrupted by ``save_state``/``restore_state`` records its
    trace in two pieces — the pre-checkpoint engine's and the resumed
    engine's.  Concatenating them yields a trace comparable tick-by-tick
    (via :func:`diff_traces`) with an uninterrupted run's, which is how
    tests/test_faults.py pins resume parity at trace granularity.  The
    segments must abut: ``b``'s first tick must follow ``a``'s last
    (docs/ANALYSIS.md "Tracing across restore")."""
    if a is None or b is None:
        return b if a is None else a
    if a.ticks and b.ticks:
        last, first = a.ticks[-1].get("t"), b.ticks[0].get("t")
        if last is not None and first is not None and first != last + 1:
            raise ValueError(
                f"trace segments do not abut: first ends at tick {last}, "
                f"second starts at tick {first}")
    out = Trace()
    out.ticks = list(a.ticks) + list(b.ticks)
    return out


def drop_trace(engine) -> None:
    """Discard ``engine``'s recorded trace (engines call this from
    ``reset()`` so a reused engine starts a fresh, comparable trace)."""
    if getattr(engine, "_san_trace", None) is not None:
        engine._san_trace = None


def _crc(arr) -> int:
    """crc32 of an array's raw bytes (C-order), numpy imported lazily."""
    import numpy as np
    a = np.ascontiguousarray(np.asarray(arr))
    return zlib.crc32(a.tobytes()) & 0xFFFFFFFF


def lane_rng_digests(u_jump, u_act) -> List[int]:
    """Per-lane crc32 of the consumed tick-RNG draws.

    ``u_jump``/``u_act`` are the raw (nlev, S) jump/action draws; lane
    s's digest covers its column of both (jump as float64, action as
    float32 — the dtypes the engines consume them at), so a lane whose
    key stream diverged is named directly by the differ.
    """
    import numpy as np
    uj = np.asarray(u_jump, np.float64).reshape(len(u_jump), -1)
    ua = np.asarray(u_act, np.float32).reshape(len(u_act), -1)
    out = []
    for s in range(uj.shape[1]):
        crc = zlib.crc32(np.ascontiguousarray(uj[:, s]).tobytes())
        crc = zlib.crc32(np.ascontiguousarray(ua[:, s]).tobytes(), crc)
        out.append(crc & 0xFFFFFFFF)
    return out


def state_digests(levels, attrs: Optional[Tuple[str, ...]] = None
                  ) -> Dict[str, int]:
    """crc32 per ``"<level>.<attr>"`` over the state tree's leaf bytes.

    ``attrs`` defaults to the engines' canonical ``STATE_ATTRS``
    (params, opt_state, dparams, dopt_state).  Bitwise-equal state trees
    digest identically; any leaf-level difference changes the digest, so
    the differ can name exactly which (level, attr) moved first.
    """
    import jax
    import numpy as np
    if attrs is None:
        from repro.core.cascade import STATE_ATTRS
        attrs = STATE_ATTRS
    out: Dict[str, int] = {}
    for li, lvl in enumerate(levels):
        for attr in attrs:
            crc = 0
            for leaf in jax.tree.leaves(getattr(lvl, attr)):
                a = np.ascontiguousarray(np.asarray(leaf))
                crc = zlib.crc32(a.tobytes(), crc)
            out[f"{li}.{attr}"] = crc & 0xFFFFFFFF
    return out


def record_tick(engine, *, t: int, level, called, pred, u_jump, u_act,
                cache_n, cache_ptr, levels) -> None:
    """Append one tick record to ``engine``'s trace (engine hook).

    Called by ``OnlineCascade.process`` and
    ``BatchedCascadeEngine._route_resolve`` at the end of every tick,
    only when :func:`determinism_on`.  All digesting happens here so the
    engines stay free of sanitizer logic beyond the one guarded call.
    """
    import numpy as np
    tr = getattr(engine, "_san_trace", None)
    if tr is None:
        tr = Trace()
        engine._san_trace = tr
    tr.append({
        "t": int(t),
        "level": [int(x) for x in np.atleast_1d(level)],
        "called": [int(bool(x)) for x in np.atleast_1d(called)],
        "pred": [int(x) for x in np.atleast_1d(pred)],
        "rng": lane_rng_digests(u_jump, u_act),
        "cache_n": [int(x) for x in cache_n],
        "cache_ptr": [int(x) for x in cache_ptr],
        "state": state_digests(levels),
    })


@contextlib.contextmanager
def determinism_trace():
    """Context manager: record determinism traces for a ``with`` block.

    Enables the determinism sanitizer (restoring its prior off state on
    exit — an enable that predates the block stays on) and yields; read
    each engine's recorded trace with :func:`trace_of` after its run.
    ``tests/harness.py run_pair`` wraps both engines' runs in this,
    which is what gives every parity test a pinpoint first-divergence
    report on failure.
    """
    was_on = determinism_on()
    enable({"determinism"})
    try:
        yield
    finally:
        if not was_on:
            disable({"determinism"})


@dataclass
class Divergence:
    """The first point two determinism traces disagree.

    ``tick`` is the engine tick number (record field ``t``); ``index``
    its position in the trace.  ``lane``/``level``/``attr`` are set when
    the diverging field has that granularity (routing arrays name the
    lane, cache mirrors the level, state digests the (level, attr)
    pair).  ``a``/``b`` are the two observed values.
    """

    tick: int
    index: int
    field: str
    lane: Optional[int] = None
    level: Optional[int] = None
    attr: Optional[str] = None
    a: Any = None
    b: Any = None

    def describe(self) -> str:
        """Human-readable one-liner naming the divergence point."""
        where = f"tick {self.tick}"
        if self.lane is not None:
            where += f", lane {self.lane}"
        if self.level is not None:
            where += f", level {self.level}"
        if self.attr is not None:
            where += f", attr {self.attr!r}"
        return (f"first divergence at {where}: field {self.field!r} "
                f"({self.a!r} vs {self.b!r})")


#: trace record fields compared per lane (divergence names the lane)
_LANE_FIELDS = ("rng", "level", "called", "pred")
#: trace record fields compared per level (divergence names the level)
_LEVEL_FIELDS = ("cache_n", "cache_ptr")
#: canonical state-attr comparison order: parameters before their
#: optimizer/deferral shadows, so an injected params corruption is named
#: "params", not a same-tick downstream echo
_ATTR_ORDER = ("params", "opt_state", "dparams", "dopt_state")


def _state_key_order(key: str) -> Tuple[int, int, str]:
    li, _, attr = key.partition(".")
    rank = _ATTR_ORDER.index(attr) if attr in _ATTR_ORDER \
        else len(_ATTR_ORDER)
    return (int(li) if li.isdigit() else -1, rank, attr)


def diff_traces(a, b) -> Optional[Divergence]:
    """First divergence between two traces, or None when identical.

    ``a``/``b`` are :class:`Trace` objects (or raw record lists).
    Records are compared in order: tick number, per-lane consumed-RNG
    digests, routing decisions (chosen level, expert-called,
    prediction — per lane), ring-buffer mirrors (per level), then the
    per-(level, attr) state digests.  A length mismatch diverges at the
    first missing record.
    """
    ra = a.ticks if isinstance(a, Trace) else list(a)
    rb = b.ticks if isinstance(b, Trace) else list(b)
    for i, (x, y) in enumerate(zip(ra, rb)):
        if x.get("t") != y.get("t"):
            return Divergence(tick=int(x.get("t", i)), index=i, field="t",
                              a=x.get("t"), b=y.get("t"))
        t = int(x.get("t", i))
        for f in _LANE_FIELDS:
            xs, ys = x.get(f, []), y.get(f, [])
            if len(xs) != len(ys):
                return Divergence(tick=t, index=i, field=f,
                                  a=len(xs), b=len(ys))
            for lane, (xa, yb) in enumerate(zip(xs, ys)):
                if xa != yb:
                    return Divergence(tick=t, index=i, field=f, lane=lane,
                                      a=xa, b=yb)
        for f in _LEVEL_FIELDS:
            xs, ys = x.get(f, []), y.get(f, [])
            if len(xs) != len(ys):
                return Divergence(tick=t, index=i, field=f,
                                  a=len(xs), b=len(ys))
            for li, (xa, yb) in enumerate(zip(xs, ys)):
                if xa != yb:
                    return Divergence(tick=t, index=i, field=f, level=li,
                                      a=xa, b=yb)
        sx, sy = x.get("state", {}), y.get("state", {})
        for key in sorted(set(sx) | set(sy), key=_state_key_order):
            if sx.get(key) != sy.get(key):
                li, _, attr = key.partition(".")
                return Divergence(tick=t, index=i, field="state",
                                  level=int(li), attr=attr,
                                  a=sx.get(key), b=sy.get(key))
    if len(ra) != len(rb):
        i = min(len(ra), len(rb))
        longer = ra if len(ra) > len(rb) else rb
        return Divergence(tick=int(longer[i].get("t", i)), index=i,
                          field="length", a=len(ra), b=len(rb))
    return None


# ---------------------------------------------------------------------------
# lock sanitizer: runtime guarded-by enforcement + lock-order cycles
# ---------------------------------------------------------------------------
#: same annotation syntax as cascade-lint CAS004 (rules/locks.py)
_GUARD_RE = re.compile(r"#\s*guarded-by:\s*(\w+)")

#: constructor family — the object is not yet / no longer shared
_EXEMPT_METHODS = {"__init__", "__post_init__", "__del__", "__new__"}

_lock_patches: List[Tuple[type, str, Any]] = []
_held = threading.local()                 # per-thread stack of held locks
_order_edges: Dict[str, Set[str]] = {}    # lock key -> keys acquired under
_order_violations: List[str] = []


def _in_constructor(obj) -> bool:
    """True when a constructor-family frame of ``obj`` is on the stack."""
    frame = sys._getframe(2)
    for _ in range(32):
        if frame is None:
            return False
        if (frame.f_code.co_name in _EXEMPT_METHODS
                and frame.f_locals.get("self") is obj):
            return True
        frame = frame.f_back
    return False


def _lock_is_owned(lock) -> bool:
    owned = getattr(lock, "_is_owned", None)
    if owned is None:
        return True          # cannot introspect: stay permissive
    return bool(owned())


class _GuardedAttr:
    """Data descriptor enforcing ``# guarded-by:`` at attribute access.

    Installed over the annotated attribute on the class (wrapping the
    original slot descriptor when the class uses ``__slots__``, or the
    instance ``__dict__`` under the same name otherwise, so pre-existing
    instances keep working and uninstrumenting restores them cleanly).
    """

    _MISSING = object()

    def __init__(self, name: str, lock_name: str, cls_name: str,
                 slot=None, default=_MISSING):
        self._name = name
        self._lock_name = lock_name
        self._cls_name = cls_name
        self._slot = slot
        self._default = default

    def _check(self, obj, op: str) -> None:
        lock = getattr(obj, self._lock_name, None)
        if lock is None:
            return                    # lock not created yet (constructor)
        if _lock_is_owned(lock):
            return
        if _in_constructor(obj):
            return
        raise LockSanitizerError(
            f"{self._cls_name}.{self._name} {op} without holding "
            f"self.{self._lock_name} (declared '# guarded-by: "
            f"{self._lock_name}')")

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        self._check(obj, "read")
        if self._slot is not None:
            return self._slot.__get__(obj, objtype)
        val = obj.__dict__.get(self._name, self._default)
        if val is self._MISSING:
            raise AttributeError(self._name)
        return val

    def __set__(self, obj, value):
        self._check(obj, "write")
        if self._slot is not None:
            self._slot.__set__(obj, value)
        else:
            obj.__dict__[self._name] = value


class _TrackedLock:
    """Thin per-access proxy over a real RLock that records ordering."""

    __slots__ = ("_real", "_key")

    def __init__(self, real, key: str):
        self._real = real
        self._key = key

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        """Acquire the real lock, recording the acquisition order."""
        _note_acquire(self._key, self._real)
        if timeout == -1:
            ok = self._real.acquire(blocking)
        else:
            ok = self._real.acquire(blocking, timeout)
        if not ok:
            _note_release(self._real)
        return ok

    def release(self) -> None:
        """Release the real lock and pop it from the held stack."""
        self._real.release()
        _note_release(self._real)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def _is_owned(self) -> bool:
        return _lock_is_owned(self._real)


class _LockAttr:
    """Data descriptor wrapping a lock attribute in a tracking proxy."""

    def __init__(self, name: str, cls_name: str, slot=None):
        self._name = name
        self._key = f"{cls_name}.{name}"
        self._slot = slot

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        if self._slot is not None:
            real = self._slot.__get__(obj, objtype)
        else:
            real = obj.__dict__.get(self._name)
        if real is None:
            return real
        return _TrackedLock(real, self._key)

    def __set__(self, obj, value):
        if self._slot is not None:
            self._slot.__set__(obj, value)
        else:
            obj.__dict__[self._name] = value


def _held_stack() -> List[Tuple[str, int]]:
    stack = getattr(_held, "stack", None)
    if stack is None:
        stack = _held.stack = []
    return stack


def _note_acquire(key: str, real) -> None:
    stack = _held_stack()
    rid = id(real)
    if any(r == rid for _, r in stack):
        stack.append((key, rid))       # re-entrant: no new edge
        return
    cycle = None
    with _state_lock:
        for held_key, _ in stack:
            if held_key != key:
                _order_edges.setdefault(held_key, set()).add(key)
        if _find_cycle():
            cycle = " -> ".join(sorted(_order_edges))
            msg = (f"lock order cycle involving {key} while holding "
                   f"{[k for k, _ in stack]} (order graph: {cycle})")
            _order_violations.append(msg)
    stack.append((key, rid))
    if cycle is not None:
        raise LockOrderError(_order_violations[-1])


def _note_release(real) -> None:
    stack = _held_stack()
    rid = id(real)
    for i in range(len(stack) - 1, -1, -1):
        if stack[i][1] == rid:
            del stack[i]
            return


def _find_cycle() -> bool:
    """DFS cycle check over the acquisition-order graph (keys)."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {k: WHITE for k in _order_edges}

    def visit(u: str) -> bool:
        color[u] = GRAY
        for v in _order_edges.get(u, ()):
            c = color.get(v, WHITE)
            if c == GRAY:
                return True
            if c == WHITE and visit(v):
                return True
        color[u] = BLACK
        return False

    return any(color[k] == WHITE and visit(k) for k in list(color))


def lock_order_violations() -> List[str]:
    """Every lock-order cycle observed since instrumentation."""
    return list(_order_violations)


def _guarded_attrs_from_source(source: str) -> Dict[str, Dict[str, str]]:
    """Parse ``# guarded-by:`` annotations -> {class: {attr: lock}}.

    The same convention cascade-lint CAS004 checks statically; the lock
    sanitizer instruments whatever the annotations declare, so the
    static and dynamic checkers can never drift apart.
    """
    tree = ast.parse(source)
    lines = source.splitlines()
    out: Dict[str, Dict[str, str]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        guarded: Dict[str, str] = {}
        for sub in ast.walk(node):
            if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                ln = sub.lineno
                m = _GUARD_RE.search(lines[ln - 1]) if ln <= len(lines) \
                    else None
                if not m:
                    continue
                targets = sub.targets if isinstance(sub, ast.Assign) \
                    else [sub.target]
                for tgt in targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        guarded[tgt.attr] = m.group(1)
                    elif isinstance(tgt, ast.Name):
                        guarded[tgt.id] = m.group(1)
        if guarded:
            out[node.name] = guarded
    return out


def instrument_locks(module=None) -> List[str]:
    """Install runtime guarded-by enforcement on ``module``'s classes.

    ``module`` defaults to ``repro.core.experts`` (imported lazily — the
    static-analysis surface stays importable without jax).  Idempotent;
    returns the list of instrumented ``Class.attr`` names.  Undo with
    :func:`uninstrument_locks`.
    """
    if _lock_patches:
        return [f"{cls.__name__}.{name}" for cls, name, _ in _lock_patches]
    if module is None:
        import repro.core.experts as module
    import inspect
    source = inspect.getsource(module)
    per_class = _guarded_attrs_from_source(source)
    installed: List[str] = []
    for cls_name, guarded in per_class.items():
        cls = getattr(module, cls_name, None)
        if cls is None:
            continue
        lock_names = set(guarded.values())
        for attr, lock_name in guarded.items():
            orig = inspect.getattr_static(cls, attr, _GuardedAttr._MISSING)
            slot = orig if hasattr(orig, "__set__") and hasattr(
                orig, "__get__") and not isinstance(
                orig, (_GuardedAttr, _LockAttr)) else None
            default = (_GuardedAttr._MISSING if slot is not None
                       or orig is _GuardedAttr._MISSING else orig)
            setattr(cls, attr, _GuardedAttr(attr, lock_name, cls_name,
                                            slot=slot, default=default))
            _lock_patches.append((cls, attr, orig))
            installed.append(f"{cls_name}.{attr}")
        for lock_name in lock_names:
            orig = inspect.getattr_static(cls, lock_name,
                                          _GuardedAttr._MISSING)
            slot = orig if hasattr(orig, "__set__") and hasattr(
                orig, "__get__") and not isinstance(
                orig, (_GuardedAttr, _LockAttr)) else None
            setattr(cls, lock_name, _LockAttr(lock_name, cls_name,
                                              slot=slot))
            _lock_patches.append((cls, lock_name, orig))
            installed.append(f"{cls_name}.{lock_name}")
    return installed


def uninstrument_locks() -> None:
    """Restore every class patched by :func:`instrument_locks`."""
    while _lock_patches:
        cls, name, orig = _lock_patches.pop()
        if orig is _GuardedAttr._MISSING:
            try:
                delattr(cls, name)
            except AttributeError:
                pass
        else:
            setattr(cls, name, orig)
    with _state_lock:
        _order_edges.clear()
        del _order_violations[:]


def tracked_rlock(key: str):
    """A standalone order-tracked RLock (for tests and ad-hoc use)."""
    import threading as _threading
    return _TrackedLock(_threading.RLock(), key)


# ---------------------------------------------------------------------------
# retrace sanitizer: count jit recompiles per compiled step function
# ---------------------------------------------------------------------------
_retrace_counts: Dict[str, int] = {}


def trace_probe(name: str, fn: Callable) -> Callable:
    """Wrap ``fn`` so each XLA *trace* of it bumps a named counter.

    The engines call this on every function they are about to
    ``jax.jit`` — the wrapper's Python body only runs at trace time, so
    its call count IS the compile count.  Returns ``fn`` unchanged when
    the retrace sanitizer is off (zero cost: no wrapper in the compiled
    path, no counter).
    """
    if not retrace_on():
        return fn

    def traced(*args, **kwargs):
        with _state_lock:
            _retrace_counts[name] = _retrace_counts.get(name, 0) + 1
        return fn(*args, **kwargs)

    return traced


def retrace_report() -> Dict[str, int]:
    """Compile counts per probed step function (name -> traces)."""
    with _state_lock:
        return dict(_retrace_counts)


def reset_retrace() -> None:
    """Zero the compile counters (call before the run being measured)."""
    with _state_lock:
        _retrace_counts.clear()


def retrace_check(limit: int) -> Dict[str, int]:
    """Step functions that compiled more than ``limit`` times.

    The engines bound compiled shapes by bucketing gathered lane subsets
    (O(log S) shapes per route pass), so a count past a generous limit
    means a shape or dtype is leaking into the traced signature and
    every tick is recompiling.  Returns the offenders (empty = clean).
    """
    with _state_lock:
        return {k: v for k, v in _retrace_counts.items() if v > limit}
