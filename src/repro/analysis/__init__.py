"""cascade-lint: repo-specific static analysis for the cascade engines.

Machine-enforces the invariants every parity test assumes — the per-tick
RNG discipline, crc32-not-``hash()`` determinism, jit purity, the expert
pool's lock discipline, the §8 kernel/level contract, and the README docs
contract.  Run ``python -m repro.analysis --strict`` (the CI gate) or see
docs/ANALYSIS.md for the checker catalog and suppression policy.
"""
from repro.analysis.engine import (
    AnalysisResult, Finding, ModuleContext, RepoContext, Rule, fingerprint,
    load_baseline, render_baseline, run_analysis)
from repro.analysis.rules import ALL_RULES

__all__ = [
    "AnalysisResult", "Finding", "ModuleContext", "RepoContext", "Rule",
    "fingerprint", "load_baseline", "render_baseline", "run_analysis",
    "ALL_RULES",
]
