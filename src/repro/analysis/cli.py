"""The ``python -m repro.analysis`` command line.

Exit codes: 0 clean (or informational run), 1 new findings under
``--strict``, 2 bad invocation.  Findings already in the committed
baseline (``analysis-baseline.txt`` at the repo root) are reported but
never fail the run — the baseline is a ratchet that may only shrink.

Output formats (``--format``): ``text`` (the default
``path:line:col: RULE message`` lines), ``json`` (a machine-readable
array, also reachable via the legacy ``--json`` flag), and ``github``
(GitHub Actions ``::error file=...,line=...::`` workflow commands — the
CI analysis job uses it so findings annotate the PR diff inline).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.engine import (
    DEFAULT_PATHS, Finding, fingerprint, load_baseline, render_baseline,
    run_analysis)
from repro.analysis.rules import ALL_RULES

FORMATS = ("text", "json", "github")


def find_repo_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor with a pyproject.toml (else the start dir)."""
    cur = (start or Path.cwd()).resolve()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return cur


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="cascade-lint: repo-specific static analysis "
                    "(CAS001-CAS008; see docs/ANALYSIS.md)")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to scan (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: nearest pyproject.toml)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-baselined finding (the CI gate)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: <root>/"
                         "analysis-baseline.txt)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "and exit 0")
    ap.add_argument("--format", choices=FORMATS, default=None,
                    help="output format (default: text; 'github' emits "
                         "::error workflow-command annotations)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array "
                         "(alias of --format json)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the checker catalog and exit")
    return ap


def _render_json(findings: List[Finding]) -> str:
    """The machine-readable array (--format json / legacy --json)."""
    return json.dumps([f.__dict__ for f in findings], indent=2)


def _render_github(f: Finding, baselined: bool = False) -> str:
    """One GitHub Actions workflow-command annotation per finding.

    Reuses the JSON path's field set (rule/path/line/col/message/
    severity); baselined findings annotate as notices so they are
    visible without failing review attention.
    """
    level = "notice" if baselined else \
        ("warning" if f.severity == "warning" else "error")
    title = f.rule + (" [baselined]" if baselined else "")
    # workflow-command property values cannot contain raw newlines/commas
    # in properties; the message part only escapes newlines and percents
    msg = (f.message.replace("%", "%25").replace("\r", "%0D")
           .replace("\n", "%0A"))
    return (f"::{level} file={f.path},line={f.line},"
            f"col={f.col + 1},title={title}::{msg}")


def _emit(findings: List[Finding], baselined: List[Finding],
          fmt: str, suppressed: int, files: int) -> None:
    if fmt == "json":
        print(_render_json(findings))
        return
    if fmt == "github":
        for f in findings:
            print(_render_github(f))
        for f in baselined:
            print(_render_github(f, baselined=True))
        print(f"cascade-lint: {len(findings)} finding(s), "
              f"{len(baselined)} baselined, {suppressed} suppressed, "
              f"{files} file(s) scanned")
        return
    for f in findings:
        print(f.render())
    for f in baselined:
        print(f"{f.render()}  [baselined]")
    print(f"cascade-lint: {len(findings)} finding(s), "
          f"{len(baselined)} baselined, {suppressed} suppressed, "
          f"{files} file(s) scanned")


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Entry point; returns the process exit code."""
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        for cls in ALL_RULES:
            print(f"{cls.id}  {cls.title}")
        return 0
    fmt = args.format or ("json" if args.json else "text")
    root = (args.root or find_repo_root()).resolve()
    if not root.is_dir():
        print(f"error: root {root} is not a directory", file=sys.stderr)
        return 2
    baseline_path = args.baseline or (root / "analysis-baseline.txt")

    result = run_analysis(root, paths=args.paths or None)
    if args.write_baseline:
        baseline_path.write_text(render_baseline(result.findings),
                                 encoding="utf-8")
        print(f"wrote {len(result.findings)} fingerprint(s) to "
              f"{baseline_path}")
        return 0

    known = load_baseline(baseline_path)
    fresh = [f for f in result.findings if fingerprint(f) not in known]
    old = [f for f in result.findings if fingerprint(f) in known]
    _emit(fresh, old, fmt, result.suppressed, result.files)
    if args.strict and fresh:
        return 1
    return 0
