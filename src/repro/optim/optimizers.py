"""Optimizers built from scratch (no optax offline).

Functional interface:
  opt = adam(lr=1e-3)
  state = opt.init(params)
  params, state = opt.step(params, grads, state)

``ogd_sqrt_t`` is the paper's online gradient descent with the no-regret
learning rate eta_t = eta0 * t^{-1/2} (Theorem 3.1/3.2, Zinkevich 2003).

Adam supports ``state_dtype`` (e.g. bfloat16 moments) — the memory knob used
for the llama3-405b train_4k fit — and all optimizers apply updates in fp32
and cast back to the param dtype (mixed-precision friendly).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    step: Callable[[Any, Any, Any], tuple]
    name: str = "opt"


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def _apply(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def sgd(lr: float, clip: Optional[float] = None) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return _apply(params, updates), {"count": state["count"] + 1}

    return Optimizer(init, step, "sgd")


def momentum(lr: float, beta: float = 0.9,
             clip: Optional[float] = None) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        m = jax.tree.map(lambda m0, g: beta * m0 + g.astype(jnp.float32),
                         state["m"], grads)
        updates = jax.tree.map(lambda m_: -lr * m_, m)
        return _apply(params, updates), {"count": state["count"] + 1, "m": m}

    return Optimizer(init, step, "momentum")


def _adam_like(lr, b1, b2, eps, weight_decay, clip, state_dtype, name):
    sdt = jnp.dtype(state_dtype)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, sdt)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t = state["count"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(
            lambda m0, g: (b1 * m0.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)).astype(sdt),
            state["m"], grads)
        v = jax.tree.map(
            lambda v0, g: (b2 * v0.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))
                           ).astype(sdt),
            state["v"], grads)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            u = -lr * mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, params, m, v)
        return _apply(params, updates), {"count": t, "m": m, "v": v}

    return Optimizer(init, step, name)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         clip: Optional[float] = None,
         state_dtype: str = "float32") -> Optimizer:
    return _adam_like(lr, b1, b2, eps, 0.0, clip, state_dtype, "adam")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip: Optional[float] = 1.0,
          state_dtype: str = "float32") -> Optimizer:
    return _adam_like(lr, b1, b2, eps, weight_decay, clip, state_dtype,
                      "adamw")


def ogd_sqrt_t(eta0: float, clip: Optional[float] = None) -> Optimizer:
    """Online gradient descent with eta_t = eta0 / sqrt(t) (no-regret)."""
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t = state["count"] + 1
        eta = eta0 * jax.lax.rsqrt(t.astype(jnp.float32))
        updates = jax.tree.map(lambda g: -eta * g.astype(jnp.float32), grads)
        return _apply(params, updates), {"count": t}

    return Optimizer(init, step, "ogd")
