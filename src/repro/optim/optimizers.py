"""Optimizers built from scratch (no optax offline).

Functional interface:
  opt = adam(lr=1e-3)
  state = opt.init(params)
  params, state = opt.step(params, grads, state)

``ogd_sqrt_t`` is the paper's online gradient descent with the no-regret
learning rate eta_t = eta0 * t^{-1/2} (Theorem 3.1/3.2, Zinkevich 2003).

Adam supports ``state_dtype`` (e.g. bfloat16 moments) — the memory knob used
for the llama3-405b train_4k fit — and all optimizers apply updates in fp32
and cast back to the param dtype (mixed-precision friendly).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    """A first-order optimizer as an (init, step[, step_k]) triple."""

    init: Callable[[Any], Any]
    step: Callable[[Any, Any, Any], tuple]
    name: str = "opt"
    # step_k(params, grads, state, k) collapses k sequential steps on the
    # same gradient into ONE application: the state transition is the
    # exact k-fold composition (EMA decays raised to k, schedule counters
    # advanced by k), the parameter update a first-order approximation
    # (k times the per-step update; exact for sgd/ogd).  ``k`` is a
    # traced float32 scalar so jitted callers never recompile per k.
    # Used by BatchedCascadeEngine(updates_per_tick="scaled") to close
    # the item-space adaptation gap of one-update-per-tick batching.
    step_k: Optional[Callable] = None


def clip_by_global_norm(grads, max_norm: float):
    """Scale ``grads`` so their global l2 norm is at most ``max_norm``.

    Returns ``(clipped_grads, pre_clip_norm)``."""
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def _apply(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def sgd(lr: float, clip: Optional[float] = None) -> Optimizer:
    """Plain SGD (optional global-norm clip); exact ``step_k``."""
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return _apply(params, updates), {"count": state["count"] + 1}

    def step_k(params, grads, state, k):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        updates = jax.tree.map(lambda g: -lr * k * g.astype(jnp.float32),
                               grads)
        return _apply(params, updates), {
            "count": state["count"] + k.astype(jnp.int32)}

    return Optimizer(init, step, "sgd", step_k)


def momentum(lr: float, beta: float = 0.9,
             clip: Optional[float] = None) -> Optimizer:
    """Heavy-ball momentum; ``step_k`` is the exact k-fold composition."""
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        m = jax.tree.map(lambda m0, g: beta * m0 + g.astype(jnp.float32),
                         state["m"], grads)
        updates = jax.tree.map(lambda m_: -lr * m_, m)
        return _apply(params, updates), {"count": state["count"] + 1, "m": m}

    def step_k(params, grads, state, k):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        bk = beta ** k
        # EXACT k-step composition with a repeated gradient:
        #   m_j = beta^j m_0 + g (1-beta^j)/(1-beta)
        #   sum_{j=1..k} m_j = m_0 A + g (k - A)/(1-beta),
        #   A = beta (1-beta^k)/(1-beta)
        A = beta * (1.0 - bk) / (1.0 - beta)
        m = jax.tree.map(
            lambda m0, g: bk * m0 + g.astype(jnp.float32)
            * (1.0 - bk) / (1.0 - beta),
            state["m"], grads)
        updates = jax.tree.map(
            lambda m0, g: -lr * (A * m0 + g.astype(jnp.float32)
                                 * (k - A) / (1.0 - beta)),
            state["m"], grads)
        return _apply(params, updates), {
            "count": state["count"] + k.astype(jnp.int32), "m": m}

    return Optimizer(init, step, "momentum", step_k)


def _adam_like(lr, b1, b2, eps, weight_decay, clip, state_dtype, name):
    sdt = jnp.dtype(state_dtype)

    def init(params):
        def z(p):
            return jnp.zeros(p.shape, sdt)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t = state["count"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(
            lambda m0, g: (b1 * m0.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)).astype(sdt),
            state["m"], grads)
        v = jax.tree.map(
            lambda v0, g: (b2 * v0.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))
                           ).astype(sdt),
            state["v"], grads)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            u = -lr * mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, params, m, v)
        return _apply(params, updates), {"count": t, "m": m, "v": v}

    def step_k(params, grads, state, k):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t = state["count"] + k.astype(jnp.int32)
        tf = t.astype(jnp.float32)
        b1k, b2k = b1 ** k, b2 ** k
        # k-fold EMA recurrence with a repeated gradient
        m = jax.tree.map(
            lambda m0, g: (b1k * m0.astype(jnp.float32)
                           + (1 - b1k) * g.astype(jnp.float32)).astype(sdt),
            state["m"], grads)
        v = jax.tree.map(
            lambda v0, g: (b2k * v0.astype(jnp.float32)
                           + (1 - b2k) * jnp.square(g.astype(jnp.float32))
                           ).astype(sdt),
            state["v"], grads)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            u = -lr * k * mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u - lr * k * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, params, m, v)
        return _apply(params, updates), {"count": t, "m": m, "v": v}

    return Optimizer(init, step, name, step_k)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         clip: Optional[float] = None,
         state_dtype: str = "float32") -> Optimizer:
    """Adam (no weight decay); ``step_k`` composes EMAs exactly."""
    return _adam_like(lr, b1, b2, eps, 0.0, clip, state_dtype, "adam")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip: Optional[float] = 1.0,
          state_dtype: str = "float32") -> Optimizer:
    """AdamW (decoupled weight decay); ``step_k`` composes EMAs exactly."""
    return _adam_like(lr, b1, b2, eps, weight_decay, clip, state_dtype,
                      "adamw")


def ogd_sqrt_t(eta0: float, clip: Optional[float] = None) -> Optimizer:
    """Online gradient descent with eta_t = eta0 / sqrt(t) (no-regret)."""
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def step(params, grads, state):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t = state["count"] + 1
        eta = eta0 * jax.lax.rsqrt(t.astype(jnp.float32))
        updates = jax.tree.map(lambda g: -eta * g.astype(jnp.float32), grads)
        return _apply(params, updates), {"count": t}

    def step_k(params, grads, state, k):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t0 = state["count"].astype(jnp.float32)
        # total step size of k sequential steps at eta0/sqrt(t), via the
        # midpoint integral (worst case ~3.5% at t0=0, k=1; exact in the
        # large-t limit):  sum_{j=1..k} (t0+j)^-1/2
        #   ~= 2 (sqrt(t0+k+1/2) - sqrt(t0+1/2))
        eta = eta0 * 2.0 * (jnp.sqrt(t0 + k + 0.5) - jnp.sqrt(t0 + 0.5))
        updates = jax.tree.map(lambda g: -eta * g.astype(jnp.float32), grads)
        return _apply(params, updates), {
            "count": state["count"] + k.astype(jnp.int32)}

    return Optimizer(init, step, "ogd", step_k)
