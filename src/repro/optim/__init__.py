"""In-repo first-order optimizers (pytree-based, jit-friendly)."""
from repro.optim.optimizers import (
    Optimizer, adam, adamw, clip_by_global_norm, momentum, ogd_sqrt_t,
    sgd)

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adamw", "ogd_sqrt_t",
           "clip_by_global_norm"]
