from repro.optim.optimizers import (
    Optimizer, sgd, momentum, adam, adamw, ogd_sqrt_t, clip_by_global_norm,
)

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adamw", "ogd_sqrt_t",
           "clip_by_global_norm"]
