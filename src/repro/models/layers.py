"""Shared layers: norms, embeddings, dense MLPs, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

VOCAB_PAD = 512  # pad vocab so the lm-head dim divides the model axis


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rounded up to the next VOCAB_PAD multiple (lm-head dim)."""
    return ((cfg.vocab + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def trunc_normal(key, shape, std, dtype):
    """Truncated-normal (+-2 sigma) init at the given std, cast to dtype."""
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, d_in, d_out, dtype, std=None):
    """Dense weight init; std defaults to the fan-in rule 1/sqrt(d_in)."""
    std = std if std is not None else d_in ** -0.5
    return trunc_normal(key, (d_in, d_out), std, dtype)


# ---------------------------------------------------------------------------
# Norms.  Scales kept in fp32; compute in fp32, cast back.
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, d=None):
    """Norm params for cfg.norm (layernorm: scale+bias; rmsnorm: scale)."""
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(params, x, cfg: ModelConfig, eps=1e-6):
    """Layer/RMS norm per cfg.norm; fp32 compute, cast back to x.dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return y.astype(dtype)


def rms_norm_headwise(x, scale, eps=1e-6):
    """Per-head RMSNorm over the last dim (qk-norm, Qwen3)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig):
    """Token embedding table at the padded vocab size."""
    v = padded_vocab(cfg)
    return {"table": trunc_normal(key, (v, cfg.d_model), cfg.d_model ** -0.5,
                                  cfg.jnp_dtype)}


def embed(params, tokens, cfg: ModelConfig):
    """Gather token embeddings: (...,) ids -> (..., d_model)."""
    return params["table"][tokens]


def init_lm_head(key, cfg: ModelConfig):
    """LM head weights; empty when cfg ties them to the embedding."""
    if cfg.tie_embeddings:
        return {}
    v = padded_vocab(cfg)
    return {"w": dense_init(key, cfg.d_model, v, cfg.jnp_dtype)}


def lm_logits(params, embed_params, x, cfg: ModelConfig):
    """x: (..., d_model) -> logits (..., padded_vocab); pad cols masked."""
    if cfg.tie_embeddings:
        w = embed_params["table"].T
    else:
        w = params["w"]
    logits = jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)
    v = padded_vocab(cfg)
    if v != cfg.vocab:
        pad_mask = (jnp.arange(v) >= cfg.vocab).astype(jnp.float32)
        logits = logits - 1e9 * pad_mask
    return logits


def softmax_xent(logits, targets, mask=None):
    """logits (..., V) fp32, targets (...) int32; mean over mask."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    losses = logz - gold
    if mask is None:
        return jnp.mean(losses)
    mask = mask.astype(jnp.float32)
    return jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig):
    """Dense-MLP weights (in/out, plus gate for swiglu)."""
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d, f, cfg.jnp_dtype),
         "w_out": dense_init(ks[1], f, d, cfg.jnp_dtype)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[2], d, f, cfg.jnp_dtype)
    return p


def apply_mlp(params, x, cfg: ModelConfig):
    """Position-wise MLP: gelu or swiglu per cfg.act."""
    h = x @ params["w_in"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["w_out"]
