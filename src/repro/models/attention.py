"""Attention: RoPE + chunked (flash-style) attention in pure JAX.

The chunked implementations are the *model-level* oracles: they never
materialize the full (Sq, Skv) score matrix, so compiled memory/byte counts
reflect a flash-attention execution schedule (the Pallas kernels in
``repro.kernels`` implement the same schedules for TPU; on CPU / in dry-runs
these jnp paths are what XLA sees).

Position conventions:
* ``q_positions`` (Sq,) and ``kv_positions`` (Skv,) are absolute token
  positions; kv slots holding no token carry position -1 (ring buffers).
* causal mask: kv_pos <= q_pos;  window mask: kv_pos > q_pos - window;
  validity: kv_pos >= 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import flags

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd) with hd even; positions: (S,) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """(Sq, Skv) boolean mask."""
    m = kv_pos[None, :] >= 0
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    return m


# ---------------------------------------------------------------------------
# Chunked attention: scan over KV chunks with online softmax.
# ---------------------------------------------------------------------------
def direct_attention(q, k, v, *, q_positions, kv_positions,
                     causal: bool = True,
                     window: Optional[int] = None) -> jax.Array:
    """Single-pass attention (no kv chunking).  Used for decode (Sq == 1),
    where the (Sq, Skv) score matrix is small and a chunked scan would only
    force GSPMD to reshard the cache inside the while loop."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qg = (q.reshape(B, Sq, K, G, hd).astype(jnp.float32) * scale
          ).astype(q.dtype)
    # bf16 inputs, fp32 accumulation: never materializes an fp32 cache copy
    s = jnp.einsum("bskgh,btkh->bskgt", qg, k,
                   preferred_element_type=jnp.float32)
    msk = _mask(q_positions, kv_positions, causal, window)     # (Sq, Skv)
    s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgt,btkh->bskgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def chunked_attention(q, k, v, *, q_positions, kv_positions,
                      causal: bool = True, window: Optional[int] = None,
                      chunk: int = 1024) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, K, hd); H % K == 0.

    Returns (B, Sq, H, hd).  Flash-style: never materializes (Sq, Skv).
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    if Sq == 1:
        return direct_attention(q, k, v, q_positions=q_positions,
                                kv_positions=kv_positions, causal=causal,
                                window=window)
    if flags.UNROLL_FOR_COST_ANALYSIS:
        chunk = Skv          # single-iteration scan: body counted once
    chunk = min(chunk, Skv)
    if Skv % chunk != 0:
        # non-power-of-two memory (e.g. 1600 image tokens): largest
        # divisor of Skv not exceeding the requested chunk
        chunk = max(c for c in range(1, chunk + 1) if Skv % c == 0)
    assert Skv % chunk == 0, (Skv, chunk)
    n_chunks = Skv // chunk
    scale = hd ** -0.5

    qg = (q.reshape(B, Sq, K, G, hd).astype(jnp.float32) * scale
          ).astype(q.dtype)
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    kvp = kv_positions.reshape(n_chunks, chunk)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, pb = xs  # (B, C, K, hd), (B, C, K, hd), (C,)
        # scores: (B, Sq, K, G, C); bf16 inputs, fp32 accumulation
        s = jnp.einsum("bskgh,bckh->bskgc", qg, kb,
                       preferred_element_type=jnp.float32)
        msk = _mask(q_positions, pb, causal, window)  # (Sq, C)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckh->bskgh", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, K, G, hd), jnp.float32)
    (m_f, l_f, acc_f), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, kvp))
    out = acc_f / jnp.maximum(l_f[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Sliding-window prefill: scan over Q chunks, banded KV slice.
# FLOPs O(S * (window + chunk)) instead of O(S^2).
# ---------------------------------------------------------------------------
def swa_prefill_attention(q, k, v, *, window: int, q_offset: int = 0,
                          chunk: int = 1024) -> jax.Array:
    """Banded prefill attention for a sliding window: scans q chunks,
    slicing only the [q_start - window, q_end) kv band each step."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_q = S // chunk
    # band covers [q_start - window, q_end); round to chunk multiples
    band = ((window + chunk - 1) // chunk) * chunk + chunk
    band = min(band, S)

    def body(_, qi):
        q_start = qi * chunk
        kv_start = jnp.clip(q_start + chunk - band, 0, S - band)
        qb = jax.lax.dynamic_slice_in_dim(q, q_start, chunk, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, kv_start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, kv_start, band, axis=1)
        q_pos = q_offset + q_start + jnp.arange(chunk)
        kv_pos = q_offset + kv_start + jnp.arange(band)
        ob = chunked_attention(
            qb, kb, vb, q_positions=q_pos, kv_positions=kv_pos,
            causal=True, window=window, chunk=min(1024, band))
        return None, ob

    if flags.UNROLL_FOR_COST_ANALYSIS:
        outs = jnp.stack([body(None, jnp.int32(i))[1] for i in range(n_q)])
    else:
        _, outs = jax.lax.scan(body, None, jnp.arange(n_q))
    # outs: (n_q, B, chunk, H, hd) -> (B, S, H, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def causal_prefill_blocked(q, k, v, *, window: Optional[int] = None,
                           q_offset: int = 0, chunk_q: int = 2048,
                           chunk_kv: int = 1024) -> jax.Array:
    """Exact-causal-FLOPs prefill: static Python loop over q blocks, each
    attending only to its (static) kv prefix — the upper triangle is never
    computed, matching what the Pallas flash kernel does on TPU."""
    B, S, H, hd = q.shape
    chunk_q = min(chunk_q, S)
    assert S % chunk_q == 0
    outs = []
    for qi in range(S // chunk_q):
        q_start = qi * chunk_q
        kv_len = q_start + chunk_q
        qb = q[:, q_start:q_start + chunk_q]
        kb, vb = k[:, :kv_len], v[:, :kv_len]
        q_pos = q_offset + q_start + jnp.arange(chunk_q)
        kv_pos = q_offset + jnp.arange(kv_len)
        outs.append(chunked_attention(
            qb, kb, vb, q_positions=q_pos, kv_positions=kv_pos,
            causal=True, window=window, chunk=min(chunk_kv, kv_len)))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def prefill_attention(q, k, v, *, window: Optional[int], q_offset: int = 0,
                      chunk: int = 1024) -> jax.Array:
    """Causal self-attention for prefill.

    Windowed + long sequence -> banded O(S*W) path; otherwise statically
    blocked causal path with exact lower-triangle FLOPs.
    """
    S = q.shape[1]
    if window is not None and S > 2 * window:
        return swa_prefill_attention(q, k, v, window=window,
                                     q_offset=q_offset, chunk=chunk)
    return causal_prefill_blocked(q, k, v, window=window, q_offset=q_offset,
                                  chunk_kv=chunk)


def cross_attention(q, k, v, *, kv_valid_len: Optional[int] = None,
                    chunk: int = 1024, chunk_q: int = 2048) -> jax.Array:
    """Non-causal attention over encoder/image memory.  Long queries are
    processed in static q blocks so the (Sq, Sm) scores never materialize
    at full size."""
    Sq, Skv = q.shape[1], k.shape[1]
    kv_pos = jnp.arange(Skv)
    if kv_valid_len is not None:
        kv_pos = jnp.where(kv_pos < kv_valid_len, kv_pos, -1)

    def block(qb):
        return chunked_attention(
            qb, k, v, q_positions=jnp.zeros((qb.shape[1],), jnp.int32),
            kv_positions=kv_pos, causal=False, window=None, chunk=chunk)

    if Sq <= chunk_q or Sq % chunk_q != 0:
        return block(q)
    outs = [block(q[:, i:i + chunk_q])
            for i in range(0, Sq, chunk_q)]
    return jnp.concatenate(outs, axis=1)
