"""Mamba2 block (state-space duality / SSD) in pure JAX [arXiv:2405.21060].

TPU-native chunked form: intra-chunk work is dense (L x L) matmuls that feed
the MXU; inter-chunk state is carried by a short ``lax.scan`` (n_chunks
steps).  This is the SSD algorithm itself, not a port of the CUDA selective
scan — see DESIGN.md §4.  The Pallas kernel in ``repro.kernels.ssd_scan``
implements the same schedule with explicit VMEM tiling; this module is the
model-level oracle and what dry-runs lower.

Layout (n_groups = 1):
  in_proj : (D, 2*d_in + 2*d_state + n_heads) -> [z, x, B, C, dt]
  conv    : depthwise causal conv over [x, B, C]  (kernel d_conv)
  SSD     : h_t = h_{t-1} * exp(A dt_t) + dt_t * B_t (x) x_t ;  y_t = C_t h_t
  gate    : y = RMSNorm(y * silu(z)) @ out_proj   (+ D skip)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import flags
from repro.models.layers import dense_init, trunc_normal


def dims(cfg: ModelConfig):
    """Derived mamba dims for ``cfg.ssm``: (d_inner, n_heads, d_xbc)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    d_xbc = d_in + 2 * s.d_state
    return d_in, n_heads, d_xbc


def init_mamba(key, cfg: ModelConfig):
    """Initialize one Mamba2 block's params (layout in module docstring)."""
    s = cfg.ssm
    d = cfg.d_model
    d_in, n_heads, d_xbc = dims(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * s.d_state + n_heads
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (n_heads,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))   # inverse softplus
    return {
        "in_proj": dense_init(ks[0], d, proj_out, cfg.jnp_dtype),
        "conv_w": trunc_normal(ks[1], (s.d_conv, d_xbc), d_xbc ** -0.5,
                               cfg.jnp_dtype),
        "conv_b": jnp.zeros((d_xbc,), jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[3], d_in, d, cfg.jnp_dtype),
    }


def _causal_conv(xbc, conv_w, conv_b, prev: jax.Array = None):
    """Depthwise causal conv.  xbc: (B, S, C); conv_w: (K, C).

    ``prev``: (B, K-1, C) left context (decode / chunked prefill), zeros if
    None.  Returns (out (B, S, C), new_prev (B, K-1, C)).
    """
    B, S, C = xbc.shape
    K = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((B, K - 1, C), xbc.dtype)
    full = jnp.concatenate([prev, xbc], axis=1)          # (B, S+K-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):
        out = out + full[:, i:i + S, :].astype(jnp.float32) * conv_w[i]
    out = out + conv_b
    new_prev = full[:, -(K - 1):, :] if K > 1 else prev
    return out.astype(xbc.dtype), new_prev


def _segsum_decay(adt):
    """adt: (..., L) of A*dt (<=0).  Returns (..., L, L) decay matrix
    M[i, j] = exp(sum_{j<k<=i} adt_k) for i >= j, else 0."""
    L = adt.shape[-1]
    cum = jnp.cumsum(adt, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]         # (..., i, j)
    tri = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def ssd_chunked(x, adt, dt, Bmat, Cmat, chunk: int,
                init_state: jax.Array = None):
    """SSD over a sequence, chunked.

    x:    (B, S, H, P)  head inputs
    adt:  (B, S, H)     A * dt  (negative)
    dt:   (B, S, H)
    Bmat: (B, S, N)     input projections (shared across heads, n_groups=1)
    Cmat: (B, S, N)
    Returns (y (B, S, H, P), final_state (B, H, P, N)).
    """
    Bsz, S, H, Pdim = x.shape
    N = Bmat.shape[-1]
    L = min(chunk, S)
    orig_S = S
    if S % L != 0:
        # ragged tail: pad with dt=0 tokens (decay 1, no state update —
        # provably inert) and drop their outputs at the end
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        adt = jnp.pad(adt, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L
    xc = x.reshape(Bsz, nc, L, H, Pdim).astype(jnp.float32)
    ac = adt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    dc = dt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    Bc = Bmat.reshape(Bsz, nc, L, N).astype(jnp.float32)
    Cc = Cmat.reshape(Bsz, nc, L, N).astype(jnp.float32)

    # intra-chunk (dense, MXU-friendly)
    decay = _segsum_decay(ac.transpose(0, 1, 3, 2))      # (B, nc, H, L, L)
    cb = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)           # (B, nc, L, L)
    scores = cb[:, :, None] * decay                      # (B, nc, H, L, L)
    xdt = xc * dc[..., None]                             # (B, nc, L, H, P)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", scores, xdt)

    # chunk states: state_c = sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    cum = jnp.cumsum(ac, axis=2)                         # (B, nc, L, H)
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)         # (B, nc, L, H)
    state_c = jnp.einsum("bclh,bcln,bclhp->bchpn",
                         decay_out * dc, Bc, xc)         # (B, nc, H, P, N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B, nc, H)

    def body(h_prev, xs):
        st, cd = xs                                      # (B,H,P,N), (B,H)
        h_new = h_prev * cd[:, :, None, None] + st
        return h_new, h_prev

    h0 = (jnp.zeros((Bsz, H, Pdim, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    h_final, h_prevs = jax.lax.scan(
        body, h0, (state_c.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)),
        unroll=nc if flags.UNROLL_FOR_COST_ANALYSIS else 1)
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B, nc, H, P, N)

    # inter-chunk: y_i += C_i . (h_prev * exp(cum_i))
    decay_in = jnp.exp(cum)                              # (B, nc, L, H)
    y_inter = jnp.einsum("bcln,bchpn->bclhp", Cc, h_prevs) \
        * decay_in[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pdim)
    if orig_S != S:
        y = y[:, :orig_S]
    return y, h_final


def mamba_forward(params, x, cfg: ModelConfig,
                  conv_prev=None, ssm_state=None, return_state=False,
                  ssd_impl=None):
    """Full-sequence Mamba2 block.  x: (B, S, D) -> (B, S, D).

    ``ssd_impl`` swaps the inner SSD scan: it must match
    ``ssd_chunked``'s signature ``(x, adt, dt, B, C, chunk,
    init_state=...) -> (y, final_state)``.  Default is the jnp chunked
    path (differentiable); ``models.kernel_students`` passes an adapter
    over the Pallas ``kernels.ssd_scan`` for serving forwards."""
    s = cfg.ssm
    d_in, n_heads, d_xbc = dims(cfg)
    B, S, D = x.shape
    proj = x @ params["in_proj"]                          # (B, S, ...)
    z, xi, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + s.d_state,
               2 * d_in + 2 * s.d_state], axis=-1)
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)
    xbc, conv_new = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_prev)
    xbc = jax.nn.silu(xbc)
    xi, Bm, Cm = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                         # (H,)
    adt = A * dt                                          # (B, S, H)
    xh = xi.reshape(B, S, n_heads, s.head_dim)
    impl = ssd_impl if ssd_impl is not None else ssd_chunked
    y, h_final = impl(xh, adt, dt, Bm, Cm, s.chunk,
                      init_state=ssm_state)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in)

    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + 1e-6) * params["gate_norm"]
    out = y.astype(cfg.jnp_dtype) @ params["out_proj"]
    if return_state:
        return out, (conv_new, h_final.astype(jnp.float32))
    return out


def mamba_decode_step(params, x, cfg: ModelConfig, conv_prev, ssm_state):
    """Single-token step.  x: (B, 1, D); states threaded explicitly.

    conv_prev: (B, d_conv-1, d_xbc); ssm_state: (B, H, P, N) fp32.
    """
    s = cfg.ssm
    d_in, n_heads, _ = dims(cfg)
    B = x.shape[0]
    proj = x @ params["in_proj"]
    z, xi, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + s.d_state,
               2 * d_in + 2 * s.d_state], axis=-1)
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)          # (B, 1, d_xbc)
    xbc, conv_new = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_prev)
    xbc = jax.nn.silu(xbc)
    xi, Bm, Cm = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(A * dt)                                  # (B, H)
    xh = xi[:, 0].reshape(B, n_heads, s.head_dim).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)                     # (B, N)
    Cv = Cm[:, 0].astype(jnp.float32)
    # state update: h = h * dA + dt * B (x) x
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bv, xh)
    h_new = ssm_state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cv, h_new)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + 1e-6) * params["gate_norm"]
    out = y.astype(cfg.jnp_dtype) @ params["out_proj"]
    return out, (conv_new, h_new)
