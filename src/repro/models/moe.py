"""Top-k MoE with GShard-style capacity dispatch.

Two execution paths:

* **local**: plain jnp one-hot dispatch on whatever token block the caller
  holds.  Used on single-device (tests / CPU experiments) and as the
  per-shard body of the distributed path.
* **sharded**: ``shard_map`` over the mesh.  Tokens are sharded over
  ('pod','data'); expert weights are sharded over 'model' either on the
  expert-ff dim (``sharding_mode='tensor'``, default) or on the expert dim
  (``'expert'``, requires num_experts % model_axis == 0).  Both modes finish
  with a single psum over 'model' — the hand-scheduled analogue of
  tensor-parallel MLP collectives (see DESIGN.md §4: no NCCL semantics, just
  jax.lax collectives inside shard_map).

Aux losses (load-balance + router-z) are returned for the training objective.
"""
from __future__ import annotations

import inspect
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ModelConfig
from repro.models import flags
from repro.models.layers import dense_init, trunc_normal

try:  # jax >= 0.6 moved shard_map to the top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

# jax >= 0.6 renamed check_rep -> check_vma; pass whichever this jax has
# (without the flag, unreduced-psum replication checks reject the body)
_SHARD_MAP_CHECK_KW = (
    "check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep")


def init_moe(key, cfg: ModelConfig):
    """Router + per-expert SwiGLU weights, stacked on a leading E axis."""
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_in": trunc_normal(ks[1], (e, d, f), d ** -0.5, cfg.jnp_dtype),
        "w_gate": trunc_normal(ks[2], (e, d, f), d ** -0.5, cfg.jnp_dtype),
        "w_out": trunc_normal(ks[3], (e, f, d), f ** -0.5, cfg.jnp_dtype),
    }


def capacity_for(n_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert token capacity (top_k * T / E * factor, rounded to 4)."""
    m = cfg.moe
    c = math.ceil(m.top_k * n_tokens / m.num_experts * m.capacity_factor)
    return max(4, ((c + 3) // 4) * 4)


def route(x2d, router_w, cfg: ModelConfig):
    """x2d: (T, D) -> top-k indices/weights + aux losses (fp32)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ router_w)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, m.top_k)          # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # load-balance loss (Switch): E * sum_e f_e * p_e
    assign = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32)
    frac_tokens = jnp.mean(jnp.sum(assign, axis=1), axis=0)  # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    lb = m.num_experts * jnp.sum(frac_tokens * frac_probs) * m.load_balance_weight
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_weight
    return top_idx, top_w, lb + zl


def _dispatch_combine(top_idx, top_w, n_tokens: int, capacity: int,
                      cfg: ModelConfig):
    """Build (T, E, C) dispatch (0/1) and combine (gated) tensors."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    # Sequential slot priority: earlier top-k slots claim queue positions
    # first (GShard §3.2).
    dispatch = jnp.zeros((n_tokens, E, capacity), jnp.float32)
    combine = jnp.zeros((n_tokens, E, capacity), jnp.float32)
    used = jnp.zeros((E,), jnp.int32)
    for slot in range(k):
        mask = jax.nn.one_hot(top_idx[:, slot], E, dtype=jnp.int32)  # (T, E)
        pos = jnp.cumsum(mask, axis=0) - 1 + used[None, :]           # (T, E)
        keep = (pos < capacity) & (mask > 0)
        pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)    # (T, E, C)
        sel = keep.astype(jnp.float32)[..., None] * pos_oh
        dispatch = dispatch + sel
        combine = combine + sel * top_w[:, slot][:, None, None]
        used = used + jnp.sum(mask, axis=0)
    return dispatch, combine


def _expert_ffn(inp, params, cfg: ModelConfig):
    """inp: (E, C, D) -> (E, C, D) through each expert's SwiGLU."""
    h = jnp.einsum("ecd,edf->ecf", inp, params["w_in"])
    g = jnp.einsum("ecd,edf->ecf", inp, params["w_gate"])
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"])


MOE_GROUP = 2048  # tokens per dispatch group (GShard 'group size')


def _moe_group(x2d, params, cfg: ModelConfig, capacity: int):
    top_idx, top_w, aux = route(x2d, params["router"], cfg)
    dispatch, combine = _dispatch_combine(top_idx, top_w, x2d.shape[0],
                                          capacity, cfg)
    inp = jnp.einsum("tec,td->ecd", dispatch,
                     x2d.astype(jnp.float32)).astype(cfg.jnp_dtype)
    out = _expert_ffn(inp, params, cfg)
    y = jnp.einsum("tec,ecd->td", combine, out.astype(jnp.float32))
    return y.astype(x2d.dtype), aux


def moe_ffn_local(x2d, params, cfg: ModelConfig, capacity: int = None):
    """Single-shard GShard MoE: x2d (T, D) -> (y (T, D), aux loss).

    Tokens are processed in groups of MOE_GROUP: capacity (and therefore
    the (T, E, C) dispatch one-hot) scales with the group, not the full
    shard — without grouping the dispatch einsum is O(T^2) and dwarfs the
    expert matmuls at training token counts (65k tokens/shard -> the
    dispatch alone would be ~20x the expert FLOPs)."""
    T = x2d.shape[0]
    if T <= MOE_GROUP or T % MOE_GROUP != 0:
        capacity = capacity or capacity_for(T, cfg)
        return _moe_group(x2d, params, cfg, capacity)
    n_groups = T // MOE_GROUP
    cap = capacity or capacity_for(MOE_GROUP, cfg)
    xg = x2d.reshape(n_groups, MOE_GROUP, -1)

    def body(_, xb):
        y, aux = _moe_group(xb, params, cfg, cap)
        return None, (y, aux)

    _, (yg, auxg) = jax.lax.scan(
        body, None, xg,
        unroll=n_groups if flags.UNROLL_FOR_COST_ANALYSIS else 1)
    return yg.reshape(T, -1), jnp.mean(auxg)


def _tokens_shardable(n_tokens: int) -> bool:
    mesh = shd.get_mesh()
    if mesh is None:
        return False
    baxes = shd.batch_axes(mesh)
    if not baxes:
        return False
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in baxes]))
    return n_tokens % dp == 0 and n_tokens // dp >= 1


def moe_ffn(x, params, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux).  Chooses sharded vs local path."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    if not _tokens_shardable(B * S):
        y, aux = moe_ffn_local(x2d, params, cfg)
        return y.reshape(B, S, D), aux

    mesh = shd.get_mesh()
    baxes = shd.batch_axes(mesh)
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in baxes]))
    t_loc = (B * S) // dp
    cap = capacity_for(t_loc, cfg)
    mode = cfg.moe.sharding_mode
    model_in_mesh = "model" in mesh.axis_names

    if mode == "expert" and model_in_mesh:
        w_spec_in = P("model", None, None)
        w_spec_out = P("model", None, None)
    else:
        w_spec_in = P(None, None, "model")
        w_spec_out = P(None, "model", None)

    def body(x_loc, router_w, w_in, w_gate, w_out):
        p_loc = {"router": router_w, "w_in": w_in, "w_gate": w_gate,
                 "w_out": w_out}
        if mode == "expert" and model_in_mesh:
            # Experts sharded: dispatch computed redundantly per model rank,
            # each rank runs only its expert slice, psum combines.
            # (Ungrouped: used for decode-scale token counts; the tensor
            # path below is the grouped production path for training.)
            top_idx, top_w, aux = route(x_loc, router_w, cfg)
            dispatch, combine = _dispatch_combine(
                top_idx, top_w, x_loc.shape[0], cap, cfg)
            e_loc = w_in.shape[0]
            midx = jax.lax.axis_index("model")
            # local slice of the (T, E, C) tensors along E
            d_loc = jax.lax.dynamic_slice_in_dim(dispatch, midx * e_loc,
                                                 e_loc, axis=1)
            c_loc = jax.lax.dynamic_slice_in_dim(combine, midx * e_loc,
                                                 e_loc, axis=1)
            inp = jnp.einsum("tec,td->ecd", d_loc,
                             x_loc.astype(jnp.float32)).astype(cfg.jnp_dtype)
            out = _expert_ffn(inp, p_loc, cfg)
            y = jnp.einsum("tec,ecd->td", c_loc, out.astype(jnp.float32))
            y = jax.lax.psum(y, "model")
        else:
            # Tensor mode: every rank has all experts with an ff slice;
            # w_out partial sums -> psum over model.  capacity=None: the
            # grouped local path computes per-GROUP capacity (passing the
            # full-shard capacity here would inflate every group's expert
            # buffers ~T_loc/GROUP-fold — caught by the roofline's
            # model_flops_ratio during the dry-run sweep).
            y, aux = moe_ffn_local(x_loc, p_loc, cfg, capacity=None)
            if model_in_mesh:
                y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, baxes)
        if model_in_mesh:
            aux = jax.lax.pmean(aux, "model")
        return y.astype(x_loc.dtype), aux

    y2d, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(P(baxes, None), P(None, None), w_spec_in, w_spec_in,
                  w_spec_out),
        out_specs=(P(baxes, None), P()),
        **{_SHARD_MAP_CHECK_KW: False},
    )(x2d, params["router"], params["w_in"], params["w_gate"],
      params["w_out"])
    return y2d.reshape(B, S, D), aux
