"""Model assembly: period-blocks, scan-over-periods stacks, LM API.

Every architecture is a repeating ``period`` of blocks (see configs.base).
Parameters and KV/SSM caches are stacked over ``n_periods`` and driven by
``lax.scan`` so the lowered HLO stays small regardless of depth (126-layer
llama3-405b scans 126 homogeneous periods).

Public API (all pure functions):
  init_params(key, cfg)                        -> params
  train_loss(params, batch, cfg)               -> (loss, metrics)
  encode(params, frames_or_none, cfg)          -> memory            (encdec)
  prefill(params, batch, cfg, cache_len)       -> (last_logits, cache)
  decode_step(params, cache, tokens, pos, cfg) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, CROSS, MAMBA, ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    chunked_attention, cross_attention, prefill_attention, rope)
from repro.models.layers import (
    apply_mlp, apply_norm, dense_init, embed, init_embed, init_lm_head,
    init_mlp, init_norm, lm_logits, rms_norm_headwise, softmax_xent)
from repro.models.moe import init_moe, moe_ffn
from repro.sharding import batch_axes, constrain, constrain_tokens


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_attn(key, cfg: ModelConfig, cross: bool = False):
    a = cfg.attn
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, a.n_heads * a.head_dim, cfg.jnp_dtype),
        "wk": dense_init(ks[1], d, a.n_kv_heads * a.head_dim, cfg.jnp_dtype),
        "wv": dense_init(ks[2], d, a.n_kv_heads * a.head_dim, cfg.jnp_dtype),
        "wo": dense_init(ks[3], a.n_heads * a.head_dim, d, cfg.jnp_dtype),
    }
    if a.qk_norm and not cross:
        p["q_scale"] = jnp.ones((a.head_dim,), jnp.float32)
        p["k_scale"] = jnp.ones((a.head_dim,), jnp.float32)
    return p


def _ffn_kind(cfg: ModelConfig, period_idx: int) -> Optional[str]:
    if cfg.moe is not None and period_idx in cfg.moe_period_idx:
        return "moe"
    if cfg.d_ff > 0:
        return "mlp"
    return None


def _init_block(key, cfg: ModelConfig, period_idx: int):
    kind = cfg.period[period_idx]
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg)}
    if kind == ATTN:
        p["attn"] = _init_attn(ks[0], cfg)
    elif kind == CROSS:
        p["attn"] = _init_attn(ks[0], cfg)
        p["norm_x"] = init_norm(cfg)
        p["cross_attn"] = _init_attn(ks[3], cfg, cross=True)
    elif kind == MAMBA:
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg)
    ffn = _ffn_kind(cfg, period_idx)
    if ffn == "moe":
        p["norm2"] = init_norm(cfg)
        p["moe"] = init_moe(ks[1], cfg)
    elif ffn == "mlp":
        p["norm2"] = init_norm(cfg)
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def _init_period_stack(key, cfg: ModelConfig, n_periods: int):
    """Stacked params: {'b{i}': leaves with leading (n_periods,) dim}."""
    blocks = {}
    for i in range(len(cfg.period)):
        keys = jax.random.split(jax.random.fold_in(key, i), n_periods)
        blocks[f"b{i}"] = jax.vmap(
            lambda k: _init_block(k, cfg, i))(keys)
    return blocks


def init_params(key, cfg: ModelConfig):
    """Full zoo-model parameter tree (embed, block stack, head, encoder)."""
    ks = jax.random.split(key, 5)
    params = {
        "embed": init_embed(ks[0], cfg),
        "final_norm": init_norm(cfg),
        "blocks": _init_period_stack(ks[1], cfg, cfg.n_periods),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_lm_head(ks[2], cfg)
    if cfg.encoder is not None:
        enc_cfg = _encoder_cfg(cfg)
        params["encoder"] = {
            "blocks": _init_period_stack(ks[3], enc_cfg,
                                         cfg.encoder.n_layers),
            "final_norm": init_norm(cfg),
        }
    return params


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    a = dataclasses.replace(cfg.attn, causal=False, window=None)
    return dataclasses.replace(
        cfg, period=(ATTN,), moe_period_idx=(), moe=None, attn=a,
        n_layers=cfg.encoder.n_layers)


# ---------------------------------------------------------------------------
# Sublayers
# ---------------------------------------------------------------------------
def _project_qkv(p, x, cfg: ModelConfig, positions, with_rope=True,
                 cross=False):
    a = cfg.attn
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, a.n_heads, a.head_dim)
    k = (x @ p["wk"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    v = (x @ p["wv"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    if a.qk_norm and not cross:
        q = rms_norm_headwise(q, p["q_scale"])
        k = rms_norm_headwise(k, p["k_scale"])
    if with_rope:
        q = rope(q, positions, a.rope_theta)
        k = rope(k, positions, a.rope_theta)
    q = constrain(q, (batch_axes(), None, "model", None))
    return q, k, v


def _attn_out(p, out, cfg: ModelConfig):
    B, S = out.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"]


def _self_attn_full(p, h, cfg: ModelConfig, causal=True, q_offset=0):
    """Full-sequence self-attention (train / prefill / encoder).
    Returns (out, (k, v)) so prefill can build caches."""
    a = cfg.attn
    S = h.shape[1]
    positions = q_offset + jnp.arange(S)
    x = apply_norm(p["norm1"], h, cfg)
    q, k, v = _project_qkv(p["attn"], x, cfg, positions)
    if causal:
        out = prefill_attention(q, k, v, window=a.window, q_offset=q_offset)
    else:
        out = chunked_attention(q, k, v, q_positions=positions,
                                kv_positions=positions, causal=False)
    return _attn_out(p["attn"], out, cfg), (k, v)


def _self_attn_decode(p, h, cfg: ModelConfig, cache, pos):
    """One-token self-attention against the (ring-buffer) cache.

    cache: {'k': (B, W, K, hd), 'v': ..., 'pos': (W,) int32}.
    """
    a = cfg.attn
    B = h.shape[0]
    x = apply_norm(p["norm1"], h, cfg)
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(p["attn"], x, cfg, positions)
    W = cache["k"].shape[1]
    slot = pos % W
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, slot, axis=0)
    out = chunked_attention(q, ck, cv, q_positions=positions,
                            kv_positions=cpos, causal=True, window=a.window)
    new_cache = {"k": ck, "v": cv, "pos": cpos}
    return _attn_out(p["attn"], out, cfg), new_cache


def _cross_attn(p, h, cfg: ModelConfig, memory=None, mem_kv=None):
    """Cross-attention to encoder/image memory.  Either raw ``memory``
    (B, Sm, D) or precomputed ``mem_kv`` (k, v) from the cache."""
    x = apply_norm(p["norm_x"], h, cfg)
    a = cfg.attn
    B, S, _ = x.shape
    q = (x @ p["cross_attn"]["wq"]).reshape(B, S, a.n_heads, a.head_dim)
    q = constrain(q, (batch_axes(), None, "model", None))
    if mem_kv is None:
        Sm = memory.shape[1]
        k = (memory @ p["cross_attn"]["wk"]).reshape(B, Sm, a.n_kv_heads,
                                                     a.head_dim)
        v = (memory @ p["cross_attn"]["wv"]).reshape(B, Sm, a.n_kv_heads,
                                                     a.head_dim)
    else:
        k, v = mem_kv
    out = cross_attention(q, k, v)
    return _attn_out(p["cross_attn"], out, cfg), (k, v)


def _ffn(p, h, cfg: ModelConfig, period_idx: int):
    """Returns (delta, aux_loss)."""
    kind = _ffn_kind(cfg, period_idx)
    if kind is None:
        return jnp.zeros_like(h), jnp.zeros((), jnp.float32)
    x = apply_norm(p["norm2"], h, cfg)
    if kind == "moe":
        y, aux = moe_ffn(x, p["moe"], cfg)
        return y, aux
    return apply_mlp(p["mlp"], x, cfg), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Period application (one scan step)
# ---------------------------------------------------------------------------
def _apply_period_full(pp, h, cfg: ModelConfig, memory, mode: str,
                       cache_len: int = 0):
    """Apply one period in full-sequence mode.  Returns (h, aux, caches)."""
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    B, S, _ = h.shape
    for i, kind in enumerate(cfg.period):
        p = pp[f"b{i}"]
        c = {}
        if kind == ATTN:
            out, (k, v) = _self_attn_full(p, h, cfg, causal=cfg.attn.causal)
            h = h + out
            if mode == "prefill":
                c.update(_build_kv_cache(k, v, cfg, cache_len))
        elif kind == CROSS:
            out, (k, v) = _self_attn_full(p, h, cfg, causal=True)
            h = h + out
            xout, (xk, xv) = _cross_attn(p, h, cfg, memory=memory)
            h = h + xout
            if mode == "prefill":
                c.update(_build_kv_cache(k, v, cfg, cache_len))
                c["xk"], c["xv"] = xk, xv
        elif kind == MAMBA:
            if mode == "prefill":
                x = apply_norm(p["norm1"], h, cfg)
                out, (conv_st, ssm_st) = ssm_mod.mamba_forward(
                    p["mamba"], x, cfg, return_state=True)
                c["conv"], c["ssm"] = conv_st, ssm_st
            else:
                x = apply_norm(p["norm1"], h, cfg)
                out = ssm_mod.mamba_forward(p["mamba"], x, cfg)
            h = h + out
        delta, aux = _ffn(p, h, cfg, i)
        h = h + delta
        aux_total = aux_total + aux
        h = constrain_tokens(h)
        if mode == "prefill":
            caches[f"b{i}"] = c
    return h, aux_total, caches


def _build_kv_cache(k, v, cfg: ModelConfig, cache_len: int):
    """Turn prefill K/V (B, S, K, hd) into a ring cache of length cache_len.

    All production shapes keep S a multiple of the window, so the ring
    layout slot = pos % W reduces to a plain slice of the last W tokens.
    """
    B, S = k.shape[:2]
    W = cache_len
    if S >= W:
        assert S % W == 0, (S, W)
        ck, cv = k[:, S - W:], v[:, S - W:]
        cpos = jnp.arange(S - W, S, dtype=jnp.int32)
    else:
        pad = W - S
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cpos = jnp.concatenate(
            [jnp.arange(S, dtype=jnp.int32),
             jnp.full((pad,), -1, jnp.int32)])
    return {"k": ck, "v": cv, "pos": cpos}


def _apply_period_decode(pp, h, cfg: ModelConfig, cache, pos):
    """One period, one token.  Returns (h, new_cache)."""
    new_cache = {}
    for i, kind in enumerate(cfg.period):
        p = pp[f"b{i}"]
        c = cache[f"b{i}"]
        nc = {}
        if kind == ATTN:
            out, nc = _self_attn_decode(p, h, cfg, c, pos)
            h = h + out
        elif kind == CROSS:
            out, nc = _self_attn_decode(p, h, cfg, c, pos)
            h = h + out
            xout, _ = _cross_attn(p, h, cfg, mem_kv=(c["xk"], c["xv"]))
            h = h + xout
            nc["xk"], nc["xv"] = c["xk"], c["xv"]
        elif kind == MAMBA:
            x = apply_norm(p["norm1"], h, cfg)
            out, (conv_st, ssm_st) = ssm_mod.mamba_decode_step(
                p["mamba"], x, cfg, c["conv"], c["ssm"])
            h = h + out
            nc = {"conv": conv_st, "ssm": ssm_st}
        delta, _ = _ffn(p, h, cfg, i)
        h = h + delta
        new_cache[f"b{i}"] = nc
    return h, new_cache


# ---------------------------------------------------------------------------
# Stacks (scan over periods)
# ---------------------------------------------------------------------------
def _stack_full(params_blocks, h, cfg: ModelConfig, memory, mode: str,
                cache_len: int = 0, remat: bool = False,
                unroll: bool = False):
    def body(carry, pp):
        h, aux = carry
        fn = _apply_period_full
        if remat:
            fn = jax.checkpoint(
                functools.partial(_apply_period_full, cfg=cfg, memory=memory,
                                  mode=mode, cache_len=cache_len),
                policy=jax.checkpoint_policies.nothing_saveable)
            h2, aux2, caches = fn(pp, h)
        else:
            h2, aux2, caches = fn(pp, h, cfg, memory, mode, cache_len)
        return (h2, aux + aux2), caches

    n = jax.tree.leaves(params_blocks)[0].shape[0]
    (h, aux), caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                    params_blocks,
                                    unroll=n if unroll else 1)
    return h, aux, caches


def _stack_decode(params_blocks, h, cfg: ModelConfig, cache, pos,
                  unroll: bool = False):
    def body(h, xs):
        pp, c = xs
        h, nc = _apply_period_decode(pp, h, cfg, c, pos)
        return h, nc

    n = jax.tree.leaves(params_blocks)[0].shape[0]
    h, new_cache = jax.lax.scan(body, h, (params_blocks, cache),
                                unroll=n if unroll else 1)
    return h, new_cache


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
def encode(params, frames, cfg: ModelConfig, unroll: bool = False):
    """Encoder forward (enc-dec archs).  frames: (B, S_enc, D) embeddings
    (modality frontend is the sanctioned stub)."""
    enc_cfg = _encoder_cfg(cfg)
    h = constrain_tokens(frames.astype(cfg.jnp_dtype))
    h, _, _ = _stack_full(params["encoder"]["blocks"], h, enc_cfg,
                          memory=None, mode="train", unroll=unroll)
    return apply_norm(params["encoder"]["final_norm"], h, cfg)


def _memory_from_batch(params, batch, cfg: ModelConfig,
                       unroll: bool = False):
    if cfg.encoder is not None:
        return encode(params, batch["frames"], cfg, unroll=unroll)
    if cfg.vision_stub:
        return batch["image_embeds"].astype(cfg.jnp_dtype)
    return None


def forward(params, batch, cfg: ModelConfig, remat: bool = False,
            unroll: bool = False):
    """Teacher-forced decoder forward.  Returns (logits, aux)."""
    tokens = batch["tokens"]
    memory = _memory_from_batch(params, batch, cfg, unroll=unroll)
    h = embed(params["embed"], tokens, cfg)
    h = constrain_tokens(h)
    h, aux, _ = _stack_full(params["blocks"], h, cfg, memory, mode="train",
                            remat=remat, unroll=unroll)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = lm_logits(params.get("lm_head", {}), params["embed"], h, cfg)
    logits = constrain(logits, (batch_axes(), None, "model"))
    return logits, aux


def _hidden_for_loss(params, batch, cfg: ModelConfig, remat, unroll):
    tokens = batch["tokens"]
    memory = _memory_from_batch(params, batch, cfg, unroll=unroll)
    h = embed(params["embed"], tokens, cfg)
    h = constrain_tokens(h)
    h, aux, _ = _stack_full(params["blocks"], h, cfg, memory, mode="train",
                            remat=remat, unroll=unroll)
    return apply_norm(params["final_norm"], h, cfg), aux


def train_loss(params, batch, cfg: ModelConfig, remat: bool = True,
               unroll: bool = False, loss_chunk: int = 0):
    """Teacher-forced LM loss.  ``loss_chunk`` > 0 computes the softmax
    cross-entropy in sequence chunks wrapped in jax.checkpoint so the
    (B, S, vocab) fp32 logits (and their gradient) are never materialized
    at once — a beyond-paper memory optimization (§Perf)."""
    if loss_chunk <= 0:
        logits, aux = forward(params, batch, cfg, remat=remat,
                              unroll=unroll)
        loss = softmax_xent(logits, batch["targets"], batch.get("mask"))
        return loss + aux, {"xent": loss, "aux": aux}

    h, aux = _hidden_for_loss(params, batch, cfg, remat, unroll)
    B, S, D = h.shape
    n = S // loss_chunk
    assert S % loss_chunk == 0, (S, loss_chunk)
    hc = h.reshape(B, n, loss_chunk, D).transpose(1, 0, 2, 3)
    tc = batch["targets"].reshape(B, n, loss_chunk).transpose(1, 0, 2)
    head = params.get("lm_head", {})

    @jax.checkpoint
    def chunk_loss(hb, tb):
        logits = lm_logits(head, params["embed"], hb, cfg)
        logits = constrain(logits, (batch_axes(), None, "model"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def body(acc, xs):
        hb, tb = xs
        return acc + chunk_loss(hb, tb), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    loss = total / (B * S)
    return loss + aux, {"xent": loss, "aux": aux}


def prefill(params, batch, cfg: ModelConfig, cache_len: Optional[int] = None,
            unroll: bool = False):
    """Process the prompt, build caches.  Returns (last_logits, cache).

    cache_len defaults to prompt length (full attention) or the attention
    window (SWA archs).
    """
    tokens = batch["tokens"]
    S = tokens.shape[1]
    if cache_len is None:
        cache_len = S if cfg.attn is None or cfg.attn.window is None \
            else min(S, cfg.attn.window)
    memory = _memory_from_batch(params, batch, cfg, unroll=unroll)
    h = embed(params["embed"], tokens, cfg)
    h = constrain_tokens(h)
    h, _, caches = _stack_full(params["blocks"], h, cfg, memory,
                               mode="prefill", cache_len=cache_len,
                               unroll=unroll)
    h_last = apply_norm(params["final_norm"], h[:, -1:], cfg)
    logits = lm_logits(params.get("lm_head", {}), params["embed"], h_last,
                       cfg)
    return logits[:, 0], caches


def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                unroll: bool = False):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 (the
    absolute position being written).  Returns (logits (B, V), new_cache)."""
    h = embed(params["embed"], tokens, cfg)
    h, new_cache = _stack_decode(params["blocks"], h, cfg, cache, pos,
                                 unroll=unroll)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = lm_logits(params.get("lm_head", {}), params["embed"], h, cfg)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# Cache specs (for dry-runs: ShapeDtypeStructs, no allocation)
# ---------------------------------------------------------------------------
def cache_struct(cfg: ModelConfig, batch: int, cache_len: int,
                 memory_len: int = 0):
    """ShapeDtypeStruct pytree matching what ``prefill`` would emit."""
    P = cfg.n_periods
    dt = cfg.jnp_dtype
    a = cfg.attn
    out = {}
    for i, kind in enumerate(cfg.period):
        W = cache_len if a is None or a.window is None \
            else min(cache_len, a.window)
        c = {}
        if kind in (ATTN, CROSS):
            c["k"] = jax.ShapeDtypeStruct((P, batch, W, a.n_kv_heads,
                                           a.head_dim), dt)
            c["v"] = jax.ShapeDtypeStruct((P, batch, W, a.n_kv_heads,
                                           a.head_dim), dt)
            c["pos"] = jax.ShapeDtypeStruct((P, W), jnp.int32)
        if kind == CROSS:
            c["xk"] = jax.ShapeDtypeStruct((P, batch, memory_len,
                                            a.n_kv_heads, a.head_dim), dt)
            c["xv"] = jax.ShapeDtypeStruct((P, batch, memory_len,
                                            a.n_kv_heads, a.head_dim), dt)
        if kind == MAMBA:
            s = cfg.ssm
            d_in, n_heads, d_xbc = ssm_mod.dims(cfg)
            c["conv"] = jax.ShapeDtypeStruct((P, batch, s.d_conv - 1, d_xbc),
                                             dt)
            c["ssm"] = jax.ShapeDtypeStruct((P, batch, n_heads, s.head_dim,
                                             s.d_state), jnp.float32)
        out[f"b{i}"] = c
    return out
