"""Tracing-time flags.

UNROLL_FOR_COST_ANALYSIS: when True, every inner lax.scan in the model
(attention kv-chunks, SWA q-chunks, SSD chunks, MoE token groups) is
replaced by straight-line code so XLA's HloCostAnalysis — which counts a
while-loop body exactly once — sees the true op counts.  Only the dry-run's
small (P, B) cost probes set this; production paths always use rolled
scans.  The math is identical either way (same FLOPs), only intermediates'
materialization differs, which is irrelevant at probe sizes.
"""

UNROLL_FOR_COST_ANALYSIS = False


def set_unroll(v: bool) -> None:
    """Toggle scan unrolling for HloCostAnalysis probes (see module doc)."""
    global UNROLL_FOR_COST_ANALYSIS
    UNROLL_FOR_COST_ANALYSIS = v
