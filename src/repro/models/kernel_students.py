"""Kernel-backed cascade students (the real-model levels, §ROADMAP).

Two students that put the Pallas kernels on the cascade's serving path:

* ``tinytf_flash`` — a *causal* tiny-transformer classifier whose
  per-layer attention runs through ``kernels.flash_attention`` and whose
  classification readout is a learned-query attention pool through
  ``kernels.decode_attention`` (the ring-cache ``pos`` mask gives exact
  pad exclusion for free).  Causality is what makes the kernel usable:
  pads sit at the END of a ``hash_ids`` buffer, a causal mask means no
  real token ever attends to a pad, and the pooled readout drops the pad
  positions — so real-token logits are provably pad-independent without
  the hand-rolled key-mask of ``students.tinytf_logits``.
* ``ssm`` — an embedded Mamba2 stack (``models.ssm``) whose inner SSD
  scan runs through ``kernels.ssd_scan``.

Both expose a ``use_kernels`` switch selecting between the Pallas path
(``kernels/*/ops.py``; interpret-mode on CPU) and the pure-jnp reference
path (``kernels/*/ref.py`` / ``models.ssm.ssd_chunked``).  The serving
route pass predicts through the kernel path; the online-imitation loss
differentiates through the reference path — ``pallas_call`` has no VJP,
and the two paths are tolerance-pinned equal (tests/test_kernel_levels.py)
so the gradient is taken on the same math the kernels compute.

Shape/dtype contract (all float32 activations):
  tokens : (B, L) int32 hashed ids from ``data.features.hash_ids``;
           0 = pad, pads only at the end; L = spec.max_len.
  logits : (B, n_classes) float32.
Block constraints: ``max_len`` must be divisible by ``block_q`` /
``block_kv`` (flash) and by ``chunk`` (SSD) after each is min'd to the
sequence length — powers of two keep every default legal.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.models.layers import dense_init
from repro.models.ssm import init_mamba, mamba_forward, ssd_chunked


@dataclass(frozen=True)
class TinyTFFlashSpec:
    """Causal tiny transformer on the flash/decode kernel path.

    ``d_model`` must divide by ``n_heads``; ``max_len`` must divide by
    ``block_q``/``block_kv`` (after min'ing to the sequence — powers of
    two are always safe).  Head dim below 128 is zero-padded to the MXU
    lane width inside the ops wrapper on TPU (free on CPU interpret).
    """

    vocab: int = 4096          # hashed token ids (0 = pad)
    max_len: int = 128
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    n_classes: int = 2
    block_q: int = 64          # flash q-tile (VMEM block rows)
    block_kv: int = 64         # flash/decode kv-tile


@dataclass(frozen=True)
class SSMStudentSpec:
    """Embedded Mamba2 classifier on the ``ssd_scan`` kernel path.

    ``expand * d_model`` must divide by ``head_dim``; ``max_len`` must
    divide by ``chunk`` (after min'ing to the sequence).  Sized one
    capability notch above the flash transformer in the default kernel
    ladder (metrics.costs keeps the c_i ordering honest).
    """

    vocab: int = 4096
    max_len: int = 128
    d_model: int = 192
    d_state: int = 32          # N, the SSD state width
    d_conv: int = 4
    expand: int = 2            # d_inner = expand * d_model
    head_dim: int = 64
    chunk: int = 64            # SSD chunk length (VMEM tile)
    n_layers: int = 2
    n_classes: int = 2


# CI-sized specs: the smallest shapes the kernels' tiling constraints
# allow.  Interpret-mode Pallas on CPU is an emulation, so the tier-1
# parity tests, benchmarks/kernel_levels.py, and ``serve.py --ladder
# kernel-ci`` all run these instead of the defaults above.
TINY_TF_CI = TinyTFFlashSpec(vocab=256, max_len=32, d_model=32, n_heads=2,
                             n_layers=1, d_ff=64, block_q=16, block_kv=16)
TINY_SSM_CI = SSMStudentSpec(vocab=256, max_len=32, d_model=16, d_state=8,
                             expand=2, head_dim=16, chunk=16, n_layers=1)


def _ln(x, scale):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale


# ---------------------------------------------------------------------------
# tinytf_flash: causal transformer, flash-attention layers, decode readout
# ---------------------------------------------------------------------------
def tinytf_flash_init(key, spec: TinyTFFlashSpec):
    """Initialize params: embed/pos tables, per-layer attn+FF, readout.

    The readout is a learned per-head query ``ro_q`` (H, hd) plus k/v
    projections — classification = one decode-attention step over the
    final hidden states.  Classifier head starts at zero like every
    other student (the cascade learns it online)."""
    ks = jax.random.split(key, 3 + spec.n_layers)
    d, f, H = spec.d_model, spec.d_ff, spec.n_heads
    hd = d // H
    params = {
        "embed": jax.random.normal(ks[0], (spec.vocab, d)) * 0.02,
        "pos": jax.random.normal(ks[1], (spec.max_len, d)) * 0.02,
        "layers": [],
        "ro_q": jax.random.normal(ks[2], (H, hd)) * 0.02,
        "ro_wk": dense_init(jax.random.fold_in(ks[2], 1), d, d, jnp.float32),
        "ro_wv": dense_init(jax.random.fold_in(ks[2], 2), d, d, jnp.float32),
        "ln_f": jnp.ones((d,), jnp.float32),
        "cls_w": jnp.zeros((d, spec.n_classes), jnp.float32),
        "cls_b": jnp.zeros((spec.n_classes,), jnp.float32),
    }
    layers = []
    for i in range(spec.n_layers):
        lk = jax.random.split(ks[3 + i], 5)
        layers.append({
            "wq": dense_init(lk[0], d, d, jnp.float32),
            "wk": dense_init(lk[1], d, d, jnp.float32),
            "wv": dense_init(lk[2], d, d, jnp.float32),
            "wo": dense_init(lk[3], d, d, jnp.float32),
            "w1": dense_init(lk[4], d, f, jnp.float32),
            "w2": dense_init(jax.random.fold_in(lk[4], 1), f, d, jnp.float32),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
        })
    params["layers"] = layers
    return params


def _causal_attend(q, k, v, spec: TinyTFFlashSpec, use_kernels: bool):
    """One causal attention, (B, L, H, hd) in and out.

    Kernel path: ``flash_attention`` (online-softmax Pallas kernel, its
    native layout).  Ref path: the jnp oracle ``attention_ref`` (B, H,
    S, hd layout) — differentiable, tolerance-equal."""
    if use_kernels:
        return flash_attention(q, k, v, causal=True,
                               block_q=spec.block_q, block_kv=spec.block_kv)
    out = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    return out.transpose(0, 2, 1, 3)


def _pool_readout(hf, pos_ids, params, spec: TinyTFFlashSpec,
                  use_kernels: bool):
    """Learned-query attention pool over valid positions -> (B, d).

    The final hidden states are the "ring cache", the learned query is
    the "new token", and ``pos_ids`` (-1 on pads) is exactly the decode
    kernel's empty-slot mask — pad exclusion without a separate mask
    tensor."""
    B, L, d = hf.shape
    H = spec.n_heads
    hd = d // H
    k = (hf @ params["ro_wk"]).reshape(B, L, H, hd)
    v = (hf @ params["ro_wv"]).reshape(B, L, H, hd)
    q = jnp.broadcast_to(params["ro_q"][None, None], (B, 1, H, hd))
    if use_kernels:
        pooled = decode_attention(q, k, v, pos_ids,
                                  block_kv=spec.block_kv)[:, 0]
    else:
        pooled = decode_attention_ref(
            q[:, 0].reshape(B, H, 1, hd), k, v, pos_ids).reshape(B, H, hd)
    return pooled.reshape(B, d)


def tinytf_flash_logits(params, tokens, spec: TinyTFFlashSpec,
                        use_kernels: bool = True):
    """tokens: (B, L) int32, 0 = pad (pads at the end) -> (B, C) logits.

    ``use_kernels=True`` runs flash attention + the decode-attention
    readout (serving route pass); ``False`` runs the jnp reference path
    (the differentiable loss path — ``pallas_call`` has no VJP)."""
    B, L = tokens.shape
    mask = tokens > 0
    h = params["embed"][tokens] + params["pos"][None, :L]
    H = spec.n_heads
    hd = spec.d_model // H
    for lp in params["layers"]:
        x = _ln(h, lp["ln1"])
        q = (x @ lp["wq"]).reshape(B, L, H, hd)
        k = (x @ lp["wk"]).reshape(B, L, H, hd)
        v = (x @ lp["wv"]).reshape(B, L, H, hd)
        att = _causal_attend(q, k, v, spec, use_kernels)
        h = h + att.reshape(B, L, spec.d_model) @ lp["wo"]
        x = _ln(h, lp["ln2"])
        h = h + jax.nn.gelu(x @ lp["w1"]) @ lp["w2"]
    hf = _ln(h, params["ln_f"])
    # position 0 stays valid even for an empty doc so the readout
    # softmax never sees an all-masked row
    ar = jnp.arange(L)
    pos_ids = jnp.where(mask | (ar == 0)[None], ar[None], -1)
    pos_ids = jnp.broadcast_to(pos_ids, (B, L)).astype(jnp.int32)
    pooled = _pool_readout(hf, pos_ids, params, spec, use_kernels)
    return pooled @ params["cls_w"] + params["cls_b"]


def tinytf_flash_predict(params, tokens, spec: TinyTFFlashSpec):
    """Softmax class probabilities via the kernel path (route pass)."""
    return jax.nn.softmax(
        tinytf_flash_logits(params, tokens, spec, use_kernels=True), axis=-1)


def tinytf_flash_loss_weighted(params, tokens, labels, w,
                               spec: TinyTFFlashSpec):
    """Per-item-weighted xent on the differentiable reference path."""
    from repro.models.students import _weighted_xent
    logits = tinytf_flash_logits(params, tokens, spec, use_kernels=False)
    return _weighted_xent(logits, labels, w)


# ---------------------------------------------------------------------------
# ssm: embedded Mamba2 stack on the ssd_scan kernel path
# ---------------------------------------------------------------------------
def ssm_model_config(spec: SSMStudentSpec) -> ModelConfig:
    """The internal ``ModelConfig`` driving ``models.ssm`` for this
    student (one mamba block per layer, float32, no attention)."""
    return ModelConfig(
        name="ssm-student", family="ssm", n_layers=spec.n_layers,
        d_model=spec.d_model, d_ff=0, vocab=spec.vocab,
        ssm=SSMConfig(d_state=spec.d_state, d_conv=spec.d_conv,
                      expand=spec.expand, head_dim=spec.head_dim,
                      chunk=spec.chunk),
        period=("mamba",), dtype="float32")


def _ssd_kernel_impl(x, adt, dt, B, C, chunk, init_state=None):
    """``models.ssm.ssd_chunked``-shaped adapter over ``kernels.ssd_scan``
    (forward-only: the kernel carries no resumable state)."""
    assert init_state is None, "kernel SSD path is forward-only"
    return ssd_scan(x, adt, dt, B, C, chunk=chunk), None


def ssm_student_init(key, spec: SSMStudentSpec):
    """Initialize params: embed table, per-layer mamba blocks + norms,
    final norm, zero classifier head."""
    cfg = ssm_model_config(spec)
    ks = jax.random.split(key, 1 + spec.n_layers)
    d = spec.d_model
    return {
        "embed": jax.random.normal(ks[0], (spec.vocab, d)) * 0.02,
        "blocks": [init_mamba(ks[1 + i], cfg) for i in range(spec.n_layers)],
        "norms": [jnp.ones((d,), jnp.float32) for _ in range(spec.n_layers)],
        "ln_f": jnp.ones((d,), jnp.float32),
        "cls_w": jnp.zeros((d, spec.n_classes), jnp.float32),
        "cls_b": jnp.zeros((spec.n_classes,), jnp.float32),
    }


def ssm_student_logits(params, tokens, spec: SSMStudentSpec,
                       use_kernels: bool = True):
    """tokens: (B, L) int32, 0 = pad (pads at the end) -> (B, C) logits.

    The mamba recurrence is causal, so masked-mean pooling over valid
    positions is pad-independent (trailing pads never feed a valid
    position's state).  ``use_kernels`` selects ``kernels.ssd_scan`` vs
    the jnp ``ssd_chunked`` oracle for the inner scan."""
    cfg = ssm_model_config(spec)
    impl = _ssd_kernel_impl if use_kernels else ssd_chunked
    mask = tokens > 0
    h = params["embed"][tokens]                          # (B, L, d) f32
    for blk, scale in zip(params["blocks"], params["norms"]):
        h = h + mamba_forward(blk, _ln(h, scale), cfg, ssd_impl=impl)
    hf = _ln(h, params["ln_f"])
    m = mask.astype(jnp.float32)[..., None]
    pooled = jnp.sum(hf * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return pooled @ params["cls_w"] + params["cls_b"]


def ssm_student_predict(params, tokens, spec: SSMStudentSpec):
    """Softmax class probabilities via the kernel path (route pass)."""
    return jax.nn.softmax(
        ssm_student_logits(params, tokens, spec, use_kernels=True), axis=-1)


def ssm_student_loss_weighted(params, tokens, labels, w,
                              spec: SSMStudentSpec):
    """Per-item-weighted xent on the differentiable reference path."""
    from repro.models.students import _weighted_xent
    logits = ssm_student_logits(params, tokens, spec, use_kernels=False)
    return _weighted_xent(logits, labels, w)
