"""Cascade student models (the paper's m_1 ... m_{N-1}).

* ``LogisticRegression`` over hashed bag-of-words features — the paper's
  level-1 model (cost 1 in its units).
* ``MLP`` — a deep dense classifier over the same hashed bag-of-words
  (a fastText-style intermediate student).  Its forward is a pure GEMM
  chain, which makes it the compute-bound workhorse of the sharded
  serving benchmarks: batched dense chains partition cleanly over a
  lane-sharded mesh.
* ``TinyTransformer`` — a small encoder classifier standing in for
  BERT-base/large (offline container: no HF weights).  The capability and
  cost ordering LR << MLP << TinyTF << expert matches the paper's cascade;
  relative costs are recomputed from our FLOP model (metrics.costs).

Both expose the same functional interface:
  init(key, spec)            -> params
  predict(params, feats)     -> probability vector (batch, n_classes)
  loss(params, feats, label) -> scalar xent (for OGD updates)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclass(frozen=True)
class LRSpec:
    """Logistic-regression student over hashed bag-of-words."""

    n_features: int = 2048
    n_classes: int = 2


@dataclass(frozen=True)
class TinyTFSpec:
    """Bidirectional tiny-transformer encoder classifier."""

    vocab: int = 4096          # hashed token ids
    max_len: int = 128
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    n_classes: int = 2


# ---------------------------------------------------------------------------
# Logistic regression
# ---------------------------------------------------------------------------
def lr_init(key, spec: LRSpec):
    """Zero-initialized weights/bias (convex objective; OGD from 0)."""
    return {"w": jnp.zeros((spec.n_features, spec.n_classes), jnp.float32),
            "b": jnp.zeros((spec.n_classes,), jnp.float32)}


def lr_logits(params, feats):
    """(B, n_features) -> (B, n_classes) affine logits."""
    return feats @ params["w"] + params["b"]


def lr_predict(params, feats):
    """Class probabilities (softmax over the LR logits)."""
    return jax.nn.softmax(lr_logits(params, feats), axis=-1)


def lr_loss(params, feats, labels):
    """Mean xent (the unweighted sequential-reference objective)."""
    logits = lr_logits(params, feats)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def _weighted_xent(logits, labels, w):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.sum((logz - gold) * w) / jnp.maximum(jnp.sum(w), 1.0)


def lr_loss_weighted(params, feats, labels, w):
    """Per-item-weighted xent — the OGD imitation objective shared by the
    sequential cascade and the batched engine (identical float ops)."""
    return _weighted_xent(lr_logits(params, feats), labels, w)


def tinytf_loss_weighted(params, tokens, labels, w, spec: "TinyTFSpec"):
    """Per-item-weighted xent on tiny-transformer logits."""
    return _weighted_xent(tinytf_logits(params, tokens, spec), labels, w)


# ---------------------------------------------------------------------------
# Deep MLP over hashed bag-of-words
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MLPSpec:
    """Deep tanh MLP over hashed bag-of-words."""

    n_features: int = 2048
    hidden: int = 1024
    n_layers: int = 4          # hidden layers (tanh)
    n_classes: int = 2


def mlp_init(key, spec: MLPSpec):
    """Fan-in-init hidden layers; zero-init classifier head."""
    dims = [spec.n_features] + [spec.hidden] * spec.n_layers
    keys = jax.random.split(key, spec.n_layers + 1)
    params = {
        "layers": [{"w": dense_init(k, d_in, d_out, jnp.float32),
                    "b": jnp.zeros((d_out,), jnp.float32)}
                   for k, d_in, d_out in zip(keys, dims[:-1], dims[1:])],
        "cls_w": jnp.zeros((dims[-1], spec.n_classes), jnp.float32),
        "cls_b": jnp.zeros((spec.n_classes,), jnp.float32),
    }
    return params


def mlp_logits(params, feats):
    """Tanh MLP chain -> (B, n_classes) logits."""
    h = feats
    for lp in params["layers"]:
        h = jnp.tanh(h @ lp["w"] + lp["b"])
    return h @ params["cls_w"] + params["cls_b"]


def mlp_predict(params, feats):
    """Class probabilities (softmax over the MLP logits)."""
    return jax.nn.softmax(mlp_logits(params, feats), axis=-1)


def mlp_loss_weighted(params, feats, labels, w):
    """Per-item-weighted xent on MLP logits."""
    return _weighted_xent(mlp_logits(params, feats), labels, w)


# ---------------------------------------------------------------------------
# Tiny transformer encoder classifier
# ---------------------------------------------------------------------------
def tinytf_init(key, spec: TinyTFSpec):
    """Embed/pos tables + per-layer attn/MLP weights; zero-init head."""
    ks = jax.random.split(key, 2 + spec.n_layers)
    d, f, H = spec.d_model, spec.d_ff, spec.n_heads
    params = {
        "embed": (jax.random.normal(ks[0], (spec.vocab, d)) * 0.02),
        "pos": (jax.random.normal(ks[1], (spec.max_len, d)) * 0.02),
        "layers": [],
        "cls_w": jnp.zeros((d, spec.n_classes), jnp.float32),
        "cls_b": jnp.zeros((spec.n_classes,), jnp.float32),
    }
    layers = []
    for i in range(spec.n_layers):
        lk = jax.random.split(ks[2 + i], 5)
        layers.append({
            "wq": dense_init(lk[0], d, d, jnp.float32),
            "wk": dense_init(lk[1], d, d, jnp.float32),
            "wv": dense_init(lk[2], d, d, jnp.float32),
            "wo": dense_init(lk[3], d, d, jnp.float32),
            "w1": dense_init(lk[4], d, f, jnp.float32),
            "w2": dense_init(jax.random.fold_in(lk[4], 1), f, d, jnp.float32),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
        })
    params["layers"] = layers
    return params


def _ln(x, scale):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale


def tinytf_logits(params, tokens, spec: TinyTFSpec):
    """tokens: (B, L) int32 hashed ids; 0 = pad."""
    B, L = tokens.shape
    mask = (tokens > 0)
    h = params["embed"][tokens] + params["pos"][None, :L]
    H = spec.n_heads
    hd = spec.d_model // H
    neg = jnp.where(mask, 0.0, -1e30)[:, None, None, :]   # (B,1,1,L)
    for lp in params["layers"]:
        x = _ln(h, lp["ln1"])
        q = (x @ lp["wq"]).reshape(B, L, H, hd).transpose(0, 2, 1, 3)
        k = (x @ lp["wk"]).reshape(B, L, H, hd).transpose(0, 2, 1, 3)
        v = (x @ lp["wv"]).reshape(B, L, H, hd).transpose(0, 2, 1, 3)
        s = q @ k.transpose(0, 1, 3, 2) * hd ** -0.5 + neg
        att = jax.nn.softmax(s, axis=-1) @ v               # (B,H,L,hd)
        att = att.transpose(0, 2, 1, 3).reshape(B, L, spec.d_model)
        h = h + att @ lp["wo"]
        x = _ln(h, lp["ln2"])
        h = h + jax.nn.gelu(x @ lp["w1"]) @ lp["w2"]
    # masked mean pool
    m = mask.astype(jnp.float32)[..., None]
    pooled = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return pooled @ params["cls_w"] + params["cls_b"]


def tinytf_predict(params, tokens, spec: TinyTFSpec):
    """Class probabilities (softmax over the transformer logits)."""
    return jax.nn.softmax(tinytf_logits(params, tokens, spec), axis=-1)


def tinytf_loss(params, tokens, labels, spec: TinyTFSpec):
    """Mean xent (the unweighted sequential-reference objective)."""
    logits = tinytf_logits(params, tokens, spec)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
