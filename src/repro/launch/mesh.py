"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """A Mesh with the given axis sizes/names (thin jax wrapper)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh with model = 1."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def parse_mesh_spec(spec: str):
    """``"data=8"`` / ``"pod=2,data=4"`` -> a Mesh with those axes.

    The CLI knob behind ``serve.py --mesh``: axis sizes must multiply to
    at most the available device count (use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for virtual
    CPU devices).  Returns None for an empty/absent spec.
    """
    if not spec:
        return None
    shape, axes = [], []
    for part in spec.split(","):
        name, _, size = part.partition("=")
        name = name.strip()
        if not name or not size.strip().isdigit() or int(size) < 1:
            raise ValueError(f"bad mesh spec {spec!r}: expected "
                             f"'axis=N[,axis=N...]' with N >= 1, "
                             f"got {part!r}")
        axes.append(name)
        shape.append(int(size))
    return make_mesh(tuple(shape), tuple(axes))
