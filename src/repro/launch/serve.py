"""Streaming cascade server — the paper's deployment shape (serving kind).

Processes a query stream in micro-batches:
  1. every query runs through the cascade students + deferral MLPs,
  2. deferred queries are batched into ONE expert forward (batched
     requests — the serving pattern App. B.1 could not reach on GPUs),
  3. expert annotations feed the online updates (Algorithm 1), in stream
     order.

Per-sample updates within a micro-batch are applied in arrival order, so
with --microbatch 1 this is exactly Algorithm 1; larger micro-batches trade
a bounded annotation delay for expert-batch throughput (documented
deviation, EXPERIMENTS.md §Paper/Serving).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --dataset hatespeech \
      --samples 2000 --mu 3e-7 --microbatch 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OnlineCascade, SimulatedExpert, default_cascade_config
from repro.core.experts import ModelExpert, train_model_expert
from repro.data import make_stream
from repro.data.features import hash_ids
from repro.models.students import tinytf_predict


class BatchedModelExpert(ModelExpert):
    """ModelExpert with a batched label path for the serving loop."""

    def label_batch(self, docs) -> np.ndarray:
        if not docs:
            return np.zeros((0,), np.int32)
        ids = np.stack([hash_ids(d, self.spec.vocab, self.spec.max_len)
                        for d in docs])
        probs = self._predict(self.params, jnp.asarray(ids))
        return np.asarray(jnp.argmax(probs, axis=-1), np.int32)


class _BatchProxy:
    """Expert proxy serving precomputed labels to the cascade during the
    replay pass of a micro-batch; falls back to a single expert call when
    the routing probe mispredicted (rare: post-update gate flips)."""

    def __init__(self, expert):
        self.expert = expert
        self.cost = expert.cost
        self.table = {}
        self.fallback_calls = 0

    def label(self, idx: int, doc) -> int:
        if idx in self.table:
            return int(self.table[idx])
        self.fallback_calls += 1
        return int(self.expert.label(idx, doc))


def probe_route(cascade: OnlineCascade, idx: int, doc, rng) -> bool:
    """Predict whether ``process(idx, doc)`` would consult the expert,
    WITHOUT mutating cascade state.  Mirrors the level loop's rng draws
    using a cloned generator so jump decisions line up with the replay."""
    import jax.numpy as jnp
    for i, lvl in enumerate(cascade.levels):
        if (not cascade._budget_exhausted() and rng.random() < lvl.beta):
            return True                      # DAgger jump
        x = lvl.featurize(doc)
        probs, dprob = lvl._predict_and_defer(
            lvl.params, lvl.dparams, jnp.asarray(x))
        defer = float(dprob) > 0.5
        if cascade._budget_exhausted() and i == len(cascade.levels) - 1:
            defer = False
        if not defer:
            return False
    return True


def serve_stream(dataset: str, samples: int, mu: float, microbatch: int,
                 expert_kind: str = "model", seed: int = 0,
                 log_every: int = 500):
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    n_classes = stream.spec.n_classes

    if expert_kind == "model":
        print("training stand-in LLM expert ...", flush=True)
        base = train_model_expert(stream, n_classes, epochs=2,
                                  max_samples=min(4000, samples), seed=seed)
        expert = BatchedModelExpert(params=base.params, spec=base.spec,
                                    cost=base.cost)
    else:
        expert = SimulatedExpert(stream, "gpt-3.5-turbo")

    proxy = _BatchProxy(expert)
    cfg = default_cascade_config(n_classes=n_classes, mu=mu, seed=seed,
                                 expert_cost=expert.cost)
    cascade = OnlineCascade(cfg, proxy)

    preds = np.zeros(len(stream), np.int32)
    t0 = time.time()
    expert_batch_sizes = []
    i = 0
    import copy
    while i < len(stream):
        j = min(i + microbatch, len(stream))
        batch_idx = list(range(i, j))
        # Pass 1 (probe): predict which queries will reach the expert,
        # using a CLONE of the rng so the replay sees identical jump draws.
        probe_rng = copy.deepcopy(cascade.rng)
        need = [k for k in batch_idx
                if probe_route(cascade, k, stream.docs[k], probe_rng)]
        # Batched expert forward for just the deferred subset.
        if need:
            if expert_kind == "model":
                labels = expert.label_batch([stream.docs[k] for k in need])
            else:
                labels = [expert.label(k, stream.docs[k]) for k in need]
            for k, y in zip(need, labels):
                proxy.table[k] = int(y)
            expert_batch_sizes.append(len(need))
        # Pass 2 (replay): stream-order Algorithm 1 with online updates.
        for k in batch_idx:
            out = cascade.process(k, stream.docs[k])
            preds[k] = out["prediction"]
        i = j
        if log_every and i % max(log_every, microbatch) < microbatch:
            acc = float(np.mean(preds[:i] == stream.labels[:i]))
            print(f"[{i}/{len(stream)}] acc={acc:.4f} "
                  f"expert_calls={cascade.expert_calls} "
                  f"({(time.time()-t0)/i*1000:.1f} ms/query)", flush=True)

    acc = float(np.mean(preds == stream.labels))
    frac = cascade.expert_calls / len(stream)
    mean_eb = float(np.mean(expert_batch_sizes)) if expert_batch_sizes else 0
    print(f"\nserved {len(stream)} queries in {time.time()-t0:.1f}s")
    print(f"accuracy={acc:.4f}  expert_calls={cascade.expert_calls} "
          f"({frac:.1%} of stream)  cost_saving={1-frac:.1%}")
    print(f"mean expert batch={mean_eb:.1f}  "
          f"probe mispredicts (single-call fallbacks)={proxy.fallback_calls}")
    print(f"level fractions: "
          f"{[round(f, 3) for f in (cascade.level_counts / len(stream))]}")
    return {"accuracy": acc, "expert_calls": cascade.expert_calls,
            "mean_expert_batch": mean_eb,
            "fallback_calls": proxy.fallback_calls,
            "predictions": preds}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech",
                    choices=["imdb", "hatespeech", "isear", "fever"])
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--microbatch", type=int, default=16)
    ap.add_argument("--expert", default="model",
                    choices=["model", "simulated"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve_stream(args.dataset, args.samples, args.mu, args.microbatch,
                 expert_kind=args.expert, seed=args.seed)


if __name__ == "__main__":
    main()
