"""Streaming cascade server — the paper's deployment shape (serving kind).

Two engines:

* ``--engine batched`` (default): ``BatchedCascadeEngine`` serves S
  concurrent stream lanes in lockstep — per-level batched student
  forwards over the gathered alive subset, one batched expert forward per
  tick for the deferred lanes, and per-tick weighted student/deferral
  updates (see core/batched.py for the RNG/equivalence contract).
* ``--engine sequential``: the per-item Algorithm-1 reference loop, with
  micro-batched expert calls via a probe/replay pass (the pre-batched
  serving path, kept for comparison and as the semantics oracle).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --dataset hatespeech \
      --samples 2000 --mu 3e-7 --batch 64
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize as _san
from repro.core import (BatchedCascadeEngine, OnlineCascade, SimulatedExpert,
                        default_cascade_config)
from repro.core.experts import train_model_expert
from repro.core.rng import tick_rngs


class _BatchProxy:
    """Expert proxy serving precomputed labels to the cascade during the
    replay pass of a micro-batch; falls back to a single expert call when
    the routing probe mispredicted (rare: post-update gate flips)."""

    def __init__(self, expert):
        self.expert = expert
        self.cost = expert.cost
        self.table = {}
        self.fallback_calls = 0

    def label(self, idx: int, doc) -> int:
        """Serve item ``idx``'s precomputed label (or fall back live)."""
        if idx in self.table:
            return int(self.table[idx])
        self.fallback_calls += 1
        return int(self.expert.label(idx, doc))


def probe_route(cascade: OnlineCascade, doc, tick: int) -> bool:
    """Predict whether processing ``doc`` at ``tick`` would consult the
    expert, WITHOUT mutating cascade state.  The per-tick pre-split RNG
    discipline (core.rng) lets the probe reproduce the exact DAgger jump
    draws — and, under ``cfg.sample_actions``, the exact sampled-action
    draws — that the replay pass will see.  (The probe previously always
    thresholded dprob at 0.5; with sampled actions that mispredicted the
    route whenever the draw disagreed with the threshold, degrading the
    micro-batch to single-call expert fallbacks.)"""
    cfg = cascade.cfg
    n_levels = len(cascade.levels)
    rngs = tick_rngs(cfg.seed, cascade.stream_id, tick, n_levels)
    u_jump = rngs.jump.random(n_levels)
    u_act = rngs.action.random(n_levels) if cfg.sample_actions else None
    for i, lvl in enumerate(cascade.levels):
        if not cascade._budget_exhausted() and u_jump[i] < lvl.beta:
            return True                      # DAgger jump
        x = lvl.featurize(doc)
        _, dprob = lvl._predict_and_defer(
            lvl.params, lvl.dparams, jnp.asarray(x))
        if cfg.sample_actions:
            # float32 comparison, identical to OnlineCascade.process
            defer = float(np.float32(u_act[i])) < float(dprob)
        else:
            defer = float(dprob) > 0.5
        if cascade._budget_exhausted() and i == n_levels - 1:
            defer = False
        if not defer:
            return False
    return True


def _make_expert(stream, n_classes, expert_kind, samples, seed,
                 workers=1, backend: str = "thread"):
    if expert_kind == "model":
        print("training stand-in LLM expert ...", flush=True)
        return train_model_expert(stream, n_classes, epochs=2,
                                  max_samples=min(4000, samples), seed=seed,
                                  workers=workers, backend=backend)
    if backend != "thread":
        print(f"(simulated expert ignores --expert-backend {backend}: "
              "table lookups need no process pool)")
    return SimulatedExpert(stream, "gpt-3.5-turbo", workers=workers)


def parse_autoscale(spec: str):
    """Parse ``--autoscale``: '' -> None, 'auto' -> (1, 8), 'LO:HI' ->
    (LO, HI).  The engine scales the expert pool within these bounds off
    queue depth, deterministically at tick boundaries."""
    if not spec:
        return None
    if spec == "auto":
        return (1, 8)
    lo, _, hi = spec.partition(":")
    try:
        return (int(lo), int(hi))
    except ValueError:
        raise SystemExit(
            f"--autoscale expects 'auto' or 'LO:HI', got {spec!r}")


def serve_stream_batched(dataset: str, samples: int, mu: float,
                         batch: int = 64, expert_kind: str = "model",
                         seed: int = 0, log_every: int = 500,
                         mesh=None, updates_per_tick: str = "single",
                         async_delay: int = 0, pipeline_depth: int = 0,
                         expert_workers: int = 1, per_lane: bool = False,
                         ladder: str = "default", trace_out: str = "",
                         arrivals: str = "none", lane_budget: int = 0,
                         admission: str = "queue", queue_limit: int = 0,
                         arrival_rate: float = 1.0, request_len: int = 8,
                         burst_size: int = 8, expert_backend: str = "thread",
                         expert_timeout=None, autoscale=None,
                         checkpoint_every: int = 0,
                         checkpoint_path: str = "", restore: str = ""):
    """Default serving path: the batched multi-stream engine.

    ``mesh`` (a jax Mesh, e.g. from ``launch.mesh.parse_mesh_spec``)
    shards the stream lanes over the mesh's ('pod','data') axes; the
    cascade state stays replicated.  ``updates_per_tick="scaled"``
    lr-scales the per-tick update by the number of expert demos, closing
    the item-space adaptation gap of one-update-per-tick batching.
    ``async_delay >= 1`` overlaps the expert forward with the next ticks'
    student compute (deferred lanes answer provisionally; annotations
    land within that many ticks — core/batched.py ``max_delay``).
    ``pipeline_depth >= 1`` additionally overlaps the route passes
    themselves: up to that many ticks' level-0 forwards stay in flight
    while older ticks' host routing resolves, with results unchanged
    (core/batched.py pipelined route mode).  ``expert_workers >= 2``
    sizes the expert annotation pool (sharded ``submit_many`` tickets),
    and ``per_lane=True`` commits each lane's annotation on the spread
    sub-deadline schedule with per-item updates (core/batched.py
    per-lane commit mode — pair it with the pool).  ``arrivals`` other
    than "none" switches to the continuous-batching front-end
    (core/admission.py): requests arrive on the named seeded schedule
    (data/streams.py), claim lanes from a pool of ``lane_budget``
    (default ``batch``) and retire at their own length, with
    ``admission`` = "queue" (unbounded FCFS wait) or "shed" (drop
    arrivals beyond ``queue_limit`` waiting requests); the report adds
    per-stream time-to-answer percentiles.  ``ladder`` picks the
    level stack: "default" = lr -> tinytf (dense jnp students);
    "kernel" = lr -> tinytf_flash -> ssm with the upper levels' batched
    forwards routed through the Pallas kernels at full default spec
    sizes (TPU-appropriate; interpret-emulated and slow on CPU);
    "kernel-ci" = the same ladder at the CI-sized specs the tier-1
    parity tests pin (docs/MODELS.md).  All of it composes."""
    from repro.data import make_stream
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    expert = _make_expert(stream, stream.spec.n_classes, expert_kind,
                          samples, seed,
                          workers="auto" if autoscale else expert_workers,
                          backend=expert_backend)
    if ladder == "default":
        cfg = default_cascade_config(n_classes=stream.spec.n_classes,
                                     mu=mu, seed=seed,
                                     expert_cost=expert.cost)
    else:
        from repro.core import kernel_cascade_config
        from repro.models.kernel_students import TINY_SSM_CI, TINY_TF_CI
        spec_kw = ({"tf_flash_spec": TINY_TF_CI, "ssm_spec": TINY_SSM_CI}
                   if ladder == "kernel-ci" else {})
        cfg = kernel_cascade_config(n_classes=stream.spec.n_classes,
                                    mu=mu, seed=seed,
                                    expert_cost=expert.cost, **spec_kw)
    lanes_n = lane_budget or batch
    # history_limit=0: the serving loop only reads aggregate metrics, so
    # per-item history would grow without bound on long streams.  The
    # front-end path keeps the per-lane commit log on top of that — its
    # per-stream records need the commit ticks
    engine = BatchedCascadeEngine(cfg, expert, n_streams=lanes_n,
                                  mesh=mesh,
                                  updates_per_tick=updates_per_tick,
                                  max_delay=async_delay,
                                  pipeline_depth=pipeline_depth,
                                  per_lane=per_lane,
                                  history_limit=0,
                                  commit_log=arrivals != "none" or None,
                                  expert_timeout=expert_timeout,
                                  autoscale=autoscale)
    if restore:
        engine.restore_state(restore)
        print(f"restored live state from {restore} (resuming at tick "
              f"{engine.t}, item {engine.t * engine.n_streams})")
    if arrivals != "none":
        return _serve_frontend(
            engine, stream, arrivals, admission=admission,
            queue_limit=queue_limit, arrival_rate=arrival_rate,
            request_len=request_len, burst_size=burst_size, seed=seed,
            trace_out=trace_out)
    t0 = time.time()
    metrics = engine.run(stream, log_every=log_every,
                         checkpoint_every=checkpoint_every,
                         checkpoint_path=checkpoint_path)
    dt = time.time() - t0
    _save_trace(engine, trace_out)
    frac = metrics["expert_calls"] / len(stream)
    lanes = (f"batch={batch}" if mesh is None else
             f"batch={batch} mesh={dict(mesh.shape)}")
    if ladder != "default":
        lanes += f" ladder={ladder}"
    if async_delay:
        lanes += f" async_delay={async_delay}"
    if pipeline_depth:
        st = engine.pipeline_stats
        lanes += (f" pipeline_depth={pipeline_depth} "
                  f"(refetches={st['refetches']} "
                  f"fences={st['update_fences'] + st['budget_fences']})")
    if expert_workers > 1 or per_lane:
        lanes += (f" expert_workers={expert_workers}"
                  f" commit={'lane' if per_lane else 'tick'}")
    cs = engine.commit_stats
    if cs["lanes"]:
        print(f"annotation commits: {cs['lanes']} lanes, "
              f"mean age {cs['age_sum'] / cs['lanes']:.2f} ticks, "
              f"mean latency {cs['wall_sum'] / cs['lanes'] * 1e3:.1f} ms")
    fs = engine.fault_stats
    if any(fs.values()):
        print(f"fault stats: timeouts={fs['timeouts']} "
              f"worker_deaths={fs['worker_deaths']} "
              f"requeues={fs['requeues']} "
              f"dropped_annotations={fs['dropped_annotations']} "
              f"fleet resizes={len(engine.fleet_log)} "
              f"(final width {engine.expert.workers})")
    print(f"\nserved {len(stream)} queries in {dt:.1f}s "
          f"({metrics['items_per_sec']:.0f} items/s, {lanes})")
    print(f"accuracy={metrics['accuracy']:.4f}  "
          f"expert_calls={metrics['expert_calls']} "
          f"({frac:.1%} of stream)  cost_saving={1-frac:.1%}")
    print(f"level fractions: "
          f"{[round(f, 3) for f in metrics['level_fractions']]}")
    return metrics


def _serve_frontend(engine, stream, arrivals: str, *, admission: str,
                    queue_limit: int, arrival_rate: float,
                    request_len: int, burst_size: int, seed: int,
                    trace_out: str = ""):
    """Continuous-batching serving path: seeded arrival schedule through
    the admission front-end, with a per-stream latency report."""
    from repro.core import CascadeFrontEnd
    from repro.data import arrival_schedule
    if arrivals == "lockstep":
        kw = {"n_lanes": engine.n_streams}
    elif arrivals == "poisson":
        kw = {"rate": arrival_rate, "mean_len": request_len, "seed": seed}
    else:
        kw = {"burst": burst_size, "mean_len": request_len, "seed": seed,
              "every": max(1, int(round(burst_size / arrival_rate)))}
    requests = arrival_schedule(arrivals, len(stream), **kw)
    fe = CascadeFrontEnd(engine, stream, admission=admission,
                         queue_limit=queue_limit)
    t0 = time.time()
    fe.serve(requests)
    dt = time.time() - t0
    _save_trace(engine, trace_out)
    m = fe.metrics()
    served = m["predictions"] >= 0
    acc = (float(np.mean(m["predictions"][served]
                         == stream.labels[served]))
           if served.any() else 0.0)
    cs = engine.commit_stats
    print(f"\nserved {m['items_done']} items of {m['requests']} "
          f"requests in {dt:.1f}s over {m['ticks']} ticks "
          f"(arrivals={arrivals}, lanes={engine.n_streams}, "
          f"admission={admission})")
    print(f"answered={m['answered']} shed={m['shed']}  "
          f"goodput={m['items_done'] / max(dt, 1e-9):.0f} items/s  "
          f"occupancy={m['occupancy_mean']:.2f}/{engine.n_streams} "
          f"(idle ticks={m['idle_ticks']})")
    print(f"time-to-answer p50={m['tta_p50']:.0f} "
          f"p99={m['tta_p99']:.0f} ticks  "
          f"mean queue delay={m['queue_delay_mean']:.2f} ticks")
    if cs["lanes"]:
        print(f"annotation commits: {cs['lanes']} lanes, "
              f"mean age {cs['age_sum'] / cs['lanes']:.2f} ticks")
    print(f"accuracy={acc:.4f} over served items  "
          f"expert_calls={engine.expert_calls_total}")
    m["accuracy"] = acc
    m["records"] = fe.records
    return m


def _save_trace(engine, trace_out: str) -> None:
    """Persist the engine's determinism-sanitizer trace, if both exist.

    Two runs' saved traces (e.g. ``--expert-workers 1`` vs ``4``, or
    ``--pipeline-depth 0`` vs ``2``) feed
    ``repro.analysis.sanitize.diff_traces`` / ``Trace.load`` for a
    first-divergence report at (tick, lane, level, attr) granularity.
    """
    tr = _san.trace_of(engine)
    if not trace_out:
        return
    if tr is None:
        print("--trace-out set but no determinism trace was recorded "
              "(enable with --sanitize determinism)")
        return
    tr.save(trace_out)
    print(f"determinism trace: {len(tr)} tick record(s) -> {trace_out}")


def _sanitizer_reports(modes) -> None:
    """Post-run reports for the enabled runtime sanitizers."""
    if "retrace" in modes:
        rep = _san.retrace_report()
        total = sum(rep.values())
        print(f"retrace sanitizer: {total} compile(s) across "
              f"{len(rep)} step function(s)")
        flagged = _san.retrace_check(limit=16)
        for name, n in sorted(flagged.items()):
            print(f"  UNEXPECTED RETRACES: {name} compiled {n}x — a "
                  "shape/dtype is leaking into the traced signature")
    if "locks" in modes:
        violations = _san.lock_order_violations()
        print(f"lock sanitizer: clean run, "
              f"{len(violations)} order violation(s)")
        for v in violations:
            print(f"  {v}")


def serve_stream(dataset: str, samples: int, mu: float, microbatch: int,
                 expert_kind: str = "model", seed: int = 0,
                 log_every: int = 500, trace_out: str = ""):
    """Sequential reference loop with probe/replay expert micro-batching."""
    from repro.data import make_stream
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    n_classes = stream.spec.n_classes
    expert = _make_expert(stream, n_classes, expert_kind, samples, seed)

    proxy = _BatchProxy(expert)
    cfg = default_cascade_config(n_classes=n_classes, mu=mu, seed=seed,
                                 expert_cost=expert.cost)
    cascade = OnlineCascade(cfg, proxy, history_limit=0)

    preds = np.zeros(len(stream), np.int32)
    t0 = time.time()
    expert_batch_sizes = []
    i = 0
    while i < len(stream):
        j = min(i + microbatch, len(stream))
        batch_idx = list(range(i, j))
        # Pass 1 (probe): predict which queries will reach the expert.
        # Item k of the batch will be processed at tick cascade.t + k + 1;
        # the pre-split tick keys make the probe's jump draws exact.
        need = [k for off, k in enumerate(batch_idx)
                if probe_route(cascade, stream.docs[k],
                               cascade.t + off + 1)]
        # Batched expert forward for just the deferred subset.
        if need:
            lb = getattr(expert, "label_batch", None)
            if lb is not None:
                labels = lb(need, [stream.docs[k] for k in need])
            else:
                labels = [expert.label(k, stream.docs[k]) for k in need]
            for k, y in zip(need, labels):
                proxy.table[k] = int(y)
            expert_batch_sizes.append(len(need))
        # Pass 2 (replay): stream-order Algorithm 1 with online updates.
        for k in batch_idx:
            out = cascade.process(k, stream.docs[k])
            preds[k] = out["prediction"]
        # the replayed micro-batch's precomputed labels are spent — prune
        # them so the proxy table stays O(microbatch), not O(stream)
        for k in batch_idx:
            proxy.table.pop(k, None)
        i = j
        if log_every and i % max(log_every, microbatch) < microbatch:
            acc = float(np.mean(preds[:i] == stream.labels[:i]))
            print(f"[{i}/{len(stream)}] acc={acc:.4f} "
                  f"expert_calls={cascade.expert_calls} "
                  f"({(time.time()-t0)/i*1000:.1f} ms/query)", flush=True)

    _save_trace(cascade, trace_out)
    acc = float(np.mean(preds == stream.labels))
    frac = cascade.expert_calls / len(stream)
    mean_eb = float(np.mean(expert_batch_sizes)) if expert_batch_sizes else 0
    print(f"\nserved {len(stream)} queries in {time.time()-t0:.1f}s")
    print(f"accuracy={acc:.4f}  expert_calls={cascade.expert_calls} "
          f"({frac:.1%} of stream)  cost_saving={1-frac:.1%}")
    print(f"mean expert batch={mean_eb:.1f}  "
          f"probe mispredicts (single-call fallbacks)={proxy.fallback_calls}")
    print(f"level fractions: "
          f"{[round(float(f), 3) for f in (cascade.level_counts / len(stream))]}")
    return {"accuracy": acc, "expert_calls": cascade.expert_calls,
            "mean_expert_batch": mean_eb,
            "fallback_calls": proxy.fallback_calls,
            "predictions": preds}


def main():
    """CLI entry point: parse serving flags and run the chosen engine.

    Engine-composition cheat sheet (all batched-engine knobs compose):
    ``--batch`` sets the lane count, ``--mesh`` shards those lanes over
    devices, ``--async-delay`` takes the expert off the critical path,
    ``--pipeline-depth`` takes the per-tick route sync off it, and
    ``--updates scaled`` keeps item-space adaptation at large batch.
    docs/ARCHITECTURE.md walks the whole tick lifecycle."""
    ap = argparse.ArgumentParser(
        description="Streaming cascade server (online cascade learning)")
    ap.add_argument("--dataset", default="hatespeech",
                    choices=["imdb", "hatespeech", "isear", "fever"],
                    help="which simulated stream corpus to serve "
                         "(data/streams.py; paper's four benchmarks)")
    ap.add_argument("--samples", type=int, default=2000,
                    help="stream length in items (queries served)")
    ap.add_argument("--mu", type=float, default=3e-7,
                    help="cost weighting factor mu (Eq. 1): the user's "
                         "accuracy-vs-LLM-cost budget knob; larger mu "
                         "closes the deferral gates sooner")
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "sequential"],
                    help="'batched' = BatchedCascadeEngine (S lanes in "
                         "lockstep, the serving default); 'sequential' = "
                         "per-item Algorithm-1 reference loop with "
                         "probe/replay expert micro-batching (semantics "
                         "oracle)")
    ap.add_argument("--batch", type=int, default=64,
                    help="concurrent stream lanes S (batched engine): "
                         "each tick serves one item per lane; S=1 is "
                         "bit-identical to the sequential reference")
    ap.add_argument("--mesh", default="",
                    help="lane-shard the batched engine over a device "
                         "mesh, e.g. 'data=8' or 'pod=2,data=4' (set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N for virtual CPU devices); cascade "
                         "state stays replicated, --batch must divide "
                         "by the lane-device count")
    ap.add_argument("--updates", default="single",
                    choices=["single", "scaled"],
                    help="per-tick update scheduling (batched engine): "
                         "'scaled' lr-scales the one weighted step by "
                         "the tick's expert-demo count (Optimizer."
                         "step_k), pinning expert-call counts near the "
                         "sequential reference at large --batch")
    ap.add_argument("--async-delay", type=int, default=0,
                    help="bounded annotation delay in ticks (batched "
                         "engine): >=1 overlaps the expert forward with "
                         "student compute — deferred lanes answer "
                         "provisionally and annotations commit exactly "
                         "that many ticks later; 0 = synchronous "
                         "(bit-exact reference semantics)")
    ap.add_argument("--pipeline-depth", type=int, default=0,
                    help="route-pipeline depth P (batched engine): >=1 "
                         "keeps up to P ticks' level-0 forwards in "
                         "flight while older ticks' host routing "
                         "resolves, hiding featurization and transfer "
                         "latency behind device compute; predictions, "
                         "levels and expert calls are identical for any "
                         "P (update ticks fence the pipeline); 0 = "
                         "unpipelined")
    ap.add_argument("--expert-workers", type=int, default=1,
                    help="expert annotation pool size W (batched "
                         "engine): >=2 shards each deferred batch over "
                         "W concurrent annotation workers "
                         "(expert.submit_many) with per-item ticket "
                         "completion; annotations and routing are "
                         "invariant to W — only latency/throughput "
                         "change")
    ap.add_argument("--expert-backend", default="thread",
                    choices=["thread", "process"],
                    help="expert pool backend (batched engine, --expert "
                         "model): 'thread' shares the in-process jit "
                         "cache; 'process' isolates annotation workers "
                         "in spawned processes (ModelExpert ships its "
                         "params to each child once) so a worker crash "
                         "cannot take the engine down — pair with "
                         "--expert-timeout for full fault tolerance")
    ap.add_argument("--expert-timeout", type=float, default=None,
                    help="per-shard annotation deadline in seconds "
                         "(batched engine): a shard that misses it is "
                         "requeued to another worker (up to max_requeues "
                         "times), then dropped gracefully — the lane "
                         "commits its provisional student answer and "
                         "the drop is counted in fault stats; default = "
                         "wait forever (no requeue path)")
    ap.add_argument("--autoscale", default="",
                    help="elastic expert-fleet bounds 'LO:HI' (or "
                         "'auto' = 1:8): the engine resizes the "
                         "annotation pool within the bounds off pending "
                         "queue depth, decided deterministically at "
                         "tick boundaries (fleet log in fault stats); "
                         "empty = fixed --expert-workers pool")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="save live engine state every N ticks to "
                         "--checkpoint-path (classic serving path): "
                         "params, optimizer/deferral state, ring "
                         "buffers, pending annotation queue and fault "
                         "stats — resuming via --restore reproduces the "
                         "uninterrupted run bitwise; 0 = off")
    ap.add_argument("--checkpoint-path", default="",
                    help="checkpoint prefix for --checkpoint-every "
                         "(written atomically; also the --restore "
                         "argument)")
    ap.add_argument("--restore", default="",
                    help="resume serving from a live-state checkpoint "
                         "written by --checkpoint-every; the engine "
                         "picks up at the saved tick and the finished "
                         "run is bitwise the uninterrupted one")
    ap.add_argument("--per-lane-commit", action="store_true",
                    help="per-lane commit granularity (batched engine, "
                         "with --async-delay >= 2): each lane's "
                         "annotation commits on a deterministic "
                         "sub-deadline inside the delay window as a "
                         "per-item update (mean commit age ~(D+1)/2 "
                         "instead of D), in strict (tick, lane) order; "
                         "results are bitwise invariant to worker "
                         "count/latency")
    ap.add_argument("--arrivals", default="none",
                    choices=["none", "lockstep", "poisson", "burst"],
                    help="continuous-batching front-end (batched "
                         "engine, core/admission.py): requests arrive "
                         "on this seeded schedule, claim a lane from "
                         "the pool, run to their own length and retire; "
                         "'none' = classic lockstep batch serving, "
                         "'lockstep' = all requests at t=0 (bitwise the "
                         "classic run), 'poisson'/'burst' = open-loop "
                         "staggered traffic (data/streams.py)")
    ap.add_argument("--lane-budget", type=int, default=0,
                    help="lane-pool capacity for --arrivals serving "
                         "(concurrent streams); 0 = use --batch")
    ap.add_argument("--admission", default="queue",
                    choices=["queue", "shed"],
                    help="overload policy for --arrivals serving: "
                         "'queue' waits arrivals FCFS without bound; "
                         "'shed' drops arrivals beyond --queue-limit "
                         "waiting requests (dropped requests are "
                         "recorded, never served)")
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="waiting-request capacity under --admission "
                         "shed (beyond the free lanes)")
    ap.add_argument("--arrival-rate", type=float, default=1.0,
                    help="offered load for --arrivals poisson/burst, in "
                         "requests per tick")
    ap.add_argument("--request-len", type=int, default=8,
                    help="mean request length in items (geometric) for "
                         "--arrivals poisson/burst")
    ap.add_argument("--burst-size", type=int, default=8,
                    help="requests per burst for --arrivals burst")
    ap.add_argument("--microbatch", type=int, default=16,
                    help="expert micro-batch size (sequential engine): "
                         "the probe/replay pass batches this many "
                         "items' deferred expert calls into one forward")
    ap.add_argument("--expert", default="model",
                    choices=["model", "simulated"],
                    help="'model' trains an in-repo transformer as the "
                         "LLM stand-in (real expert compute); "
                         "'simulated' replays the stream's precomputed "
                         "noisy-teacher annotations (zero compute)")
    ap.add_argument("--ladder", default="default",
                    choices=["default", "kernel", "kernel-ci"],
                    help="level stack (batched engine): 'default' = "
                         "lr -> tinytf dense students; 'kernel' = "
                         "lr -> tinytf_flash -> ssm with the upper "
                         "forwards routed through the Pallas kernels "
                         "(flash/decode attention, SSD scan) at "
                         "full-size specs — TPU-appropriate, interpret-"
                         "emulated on CPU; 'kernel-ci' = the same "
                         "ladder at the CI-sized specs the tier-1 "
                         "parity tests pin (docs/MODELS.md)")
    ap.add_argument("--seed", type=int, default=0,
                    help="stream/cascade RNG seed (core/rng.py per-tick "
                         "key discipline)")
    ap.add_argument("--sanitize", default="",
                    help="comma list of runtime sanitizers to serve "
                         "under (repro.analysis.sanitize): "
                         "'determinism' records the per-tick trace "
                         "(save with --trace-out, diff two runs with "
                         "diff_traces), 'locks' enforces the expert "
                         "pool's # guarded-by: annotations at runtime "
                         "+ lock-order cycles, 'retrace' counts jit "
                         "compiles per step function and flags leaks")
    ap.add_argument("--trace-out", default="",
                    help="write the determinism-sanitizer trace to this "
                         "JSONL path after serving (requires "
                         "--sanitize determinism)")
    args = ap.parse_args()
    modes = {m.strip() for m in args.sanitize.split(",") if m.strip()}
    if modes:
        _san.enable(modes)    # before engine build: jit probes hook in
    if args.engine == "batched":
        from repro.launch.mesh import parse_mesh_spec
        serve_stream_batched(args.dataset, args.samples, args.mu,
                             batch=args.batch, expert_kind=args.expert,
                             seed=args.seed,
                             mesh=parse_mesh_spec(args.mesh),
                             updates_per_tick=args.updates,
                             async_delay=args.async_delay,
                             pipeline_depth=args.pipeline_depth,
                             expert_workers=args.expert_workers,
                             per_lane=args.per_lane_commit,
                             ladder=args.ladder,
                             trace_out=args.trace_out,
                             arrivals=args.arrivals,
                             lane_budget=args.lane_budget,
                             admission=args.admission,
                             queue_limit=args.queue_limit,
                             arrival_rate=args.arrival_rate,
                             request_len=args.request_len,
                             burst_size=args.burst_size,
                             expert_backend=args.expert_backend,
                             expert_timeout=args.expert_timeout,
                             autoscale=parse_autoscale(args.autoscale),
                             checkpoint_every=args.checkpoint_every,
                             checkpoint_path=args.checkpoint_path,
                             restore=args.restore)
    else:
        serve_stream(args.dataset, args.samples, args.mu, args.microbatch,
                     expert_kind=args.expert, seed=args.seed,
                     trace_out=args.trace_out)
    if modes:
        _sanitizer_reports(modes)


if __name__ == "__main__":
    main()
