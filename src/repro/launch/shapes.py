"""Assigned input shapes + ShapeDtypeStruct builders (no allocation).

Shapes (assignment):
  train_4k      seq=4096    global_batch=256   (training)
  prefill_32k   seq=32768   global_batch=32    (inference prefill)
  decode_32k    seq=32768   global_batch=128   (decode ONE token, cache=seq)
  long_500k     seq=524288  global_batch=1     (long-context decode)

Decode shapes lower ``decode_step`` (one new token against a KV cache of
seq_len), never ``train_step``.  ``long_500k`` applies the sliding-window
override (cfg.long_context_window) to full-attention layers — the
assignment's sanctioned sub-quadratic variant — so every architecture,
including pure-attention ones, lowers it (DESIGN.md §3).

Enc-dec note: the audio encoder consumes ``seq`` frames; the text decoder
sees seq_len tokens for train, seq//8 for prefill prompts (speech-to-text
length ratio), and the full seq-sized self+cross caches for decode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf_model


@dataclass(frozen=True)
class InputShape:
    """One dry-run workload: step kind + (batch, seq) dims."""

    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def needs_long_context_override(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k on archs whose attention is full -> apply SWA override."""
    return (shape.name == "long_500k" and cfg.attn is not None
            and cfg.attn.window is None)


def resolve_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply shape-dependent config overrides (long-context window)."""
    if needs_long_context_override(cfg, shape):
        return cfg.with_window(cfg.long_context_window)
    return cfg


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape.

    Returns kwargs for the step function chosen by ``shape.kind``:
      train   -> {'batch': {...}}
      prefill -> {'batch': {...}}
      decode  -> {'cache': ..., 'tokens': ..., 'pos': ...}
    """
    cfg = resolve_config(cfg, shape)
    B, S = shape.batch, shape.seq
    dt = cfg.jnp_dtype

    def extras(batch, seq):
        out = {}
        if cfg.encoder is not None:
            out["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                 dt)
        if cfg.vision_stub:
            out["image_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_image_tokens, cfg.d_model), dt)
        return out

    if shape.kind == "train":
        batch = {"tokens": _tok(B, S), "targets": _tok(B, S),
                 **extras(B, S)}
        return {"batch": batch}
    if shape.kind == "prefill":
        dec_len = max(S // 8, 128) if cfg.encoder is not None else S
        batch = {"tokens": _tok(B, dec_len), **extras(B, S)}
        return {"batch": batch}
    if shape.kind == "decode":
        mem_len = cfg.n_image_tokens if cfg.vision_stub else \
            (S if cfg.encoder is not None else 0)
        cache = tf_model.cache_struct(cfg, B, S, memory_len=mem_len)
        return {"cache": cache, "tokens": _tok(B, 1),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)
