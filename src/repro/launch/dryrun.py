"""Multi-pod dry-run driver: AOT lower + compile every arch x shape x
mesh combination without hardware (see ``DOC`` below for the full
story); must set XLA_FLAGS before any jax import."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh).

For each combination this proves, without hardware:
  * the sharding config is coherent (lower succeeds),
  * the collective schedule is partitionable (compile succeeds),
  * the memory fits (memory_analysis printed / recorded),
and extracts the roofline inputs (cost_analysis FLOPs/bytes + HLO
collective bytes) into a JSON artifact consumed by benchmarks/roofline.

Cost extrapolation: XLA's HloCostAnalysis counts a while-loop body once
regardless of trip count, so FLOPs/bytes/collectives of scanned stacks are
measured by small straight-line probes (inner scans unrolled via
flags.UNROLL_FOR_COST_ANALYSIS) at (periods P, batch B) in {1,2} x
{dp, 2dp} and extended along the exact bilinear law
cost(P, B) = a0 + a1*P + (c0 + c1*P)*B.  memory_analysis and the
compile-success proof always come from the FULL-depth model.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k
  python -m repro.launch.dryrun --all                  # 40 pairs, 16x16
  python -m repro.launch.dryrun --all --multipod       # 40 pairs, 2x16x16
Options for perf experiments (EXPERIMENTS.md SPerf):
  --moe-mode expert|tensor   --zero   --opt-dtype bfloat16   --no-remat
"""

import argparse
import dataclasses
import functools
import json
import time
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs import get_config, list_architectures
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import INPUT_SHAPES, input_specs, resolve_config
from repro.metrics.roofline import (
    V5E, model_flops_6nd, parse_collective_bytes, roofline_terms)
from repro.models import transformer as tf_model
from repro.optim import adamw
from repro.sharding import param_pspecs


# ---------------------------------------------------------------------------
# Sharding spec builders
# ---------------------------------------------------------------------------
def _div(n, mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return False
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0 and n >= size


def batch_pspecs(batch_struct, mesh):
    """PartitionSpecs sharding every batch leaf's dim 0 over lanes."""
    baxes = shd.batch_axes(mesh)

    def one(leaf):
        dims = [None] * len(leaf.shape)
        if baxes and _div(leaf.shape[0], mesh, baxes):
            dims[0] = baxes if len(baxes) > 1 else baxes[0]
        elif len(leaf.shape) >= 2 and baxes and _div(leaf.shape[1], mesh,
                                                     baxes):
            dims[1] = baxes if len(baxes) > 1 else baxes[0]
        return P(*dims)

    return jax.tree.map(one, batch_struct)


def cache_pspecs(cache_struct, mesh):
    """KV/SSM cache shardings: batch over (pod,data) when divisible, else
    the cache length dim; kv-heads / ssm-heads / conv channels over model
    when divisible, else sequence-parallel cache over model."""
    baxes = shd.batch_axes(mesh)

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        shape = leaf.shape
        dims = [None] * len(shape)
        if name in ("k", "v", "xk", "xv"):
            # (Pd, B, W, K, hd)
            if baxes and _div(shape[1], mesh, baxes):
                dims[1] = baxes if len(baxes) > 1 else baxes[0]
            elif baxes and _div(shape[2], mesh, baxes):
                dims[2] = baxes if len(baxes) > 1 else baxes[0]
            if _div(shape[3], mesh, ("model",)):
                dims[3] = "model"
            elif dims[2] is None and _div(shape[2], mesh, ("model",)):
                dims[2] = "model"
        elif name == "ssm":
            # (Pd, B, H, hp, N)
            if baxes and _div(shape[1], mesh, baxes):
                dims[1] = baxes if len(baxes) > 1 else baxes[0]
            if _div(shape[2], mesh, ("model",)):
                dims[2] = "model"
        elif name == "conv":
            # (Pd, B, d_conv-1, d_xbc)
            if baxes and _div(shape[1], mesh, baxes):
                dims[1] = baxes if len(baxes) > 1 else baxes[0]
            if _div(shape[3], mesh, ("model",)):
                dims[3] = "model"
        return P(*dims)

    return jax.tree_util.tree_map_with_path(one, cache_struct)


def zero_shard_specs(specs, struct, mesh):
    """ZeRO-style optimizer-state sharding: add the data axis to the first
    unsharded, divisible dim of each moment tensor."""
    baxes = shd.batch_axes(mesh)
    if not baxes:
        return specs

    def one(spec, leaf):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (d, s) in enumerate(zip(leaf.shape, dims)):
            if s is None and _div(d, mesh, baxes):
                dims[i] = baxes if len(baxes) > 1 else baxes[0]
                break
        return P(*dims)

    return jax.tree.map(one, specs, struct,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg, opt, remat: bool = True, unroll: bool = False,
                    loss_chunk: int = 0):
    """Build the (params, opt_state, batch) -> loss train step."""
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = tf_model.train_loss(p, batch, cfg, remat=remat,
                                                unroll=unroll,
                                                loss_chunk=loss_chunk)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state = opt.step(params, grads, opt_state)
        return loss, params, opt_state
    return train_step


def make_prefill_step(cfg, cache_len: Optional[int] = None,
                      unroll: bool = False):
    """Build the (params, batch) -> (logits, cache) prefill step."""
    def prefill_step(params, batch):
        return tf_model.prefill(params, batch, cfg, cache_len=cache_len,
                                unroll=unroll)
    return prefill_step


def make_decode_step(cfg, unroll: bool = False):
    """Build the single-token (params, cache, tokens, pos) step."""
    def decode_step(params, cache, tokens, pos):
        return tf_model.decode_step(params, cache, tokens, pos, cfg,
                                    unroll=unroll)
    return decode_step


# ---------------------------------------------------------------------------
# Core
# ---------------------------------------------------------------------------
def _with_periods(cfg, n_periods: int):
    new = dataclasses.replace(cfg, n_layers=len(cfg.period) * n_periods)
    if cfg.encoder is not None:
        new = dataclasses.replace(
            new, encoder=dataclasses.replace(cfg.encoder,
                                             n_layers=n_periods))
    return new


def _compile_combo(cfg, shape, mesh, *, zero, opt_dtype, remat,
                   unroll=False, seq_parallel=False, loss_chunk=0,
                   shard_params_data=False):
    """Lower + compile one (cfg, shape) on mesh.  Returns (compiled, secs)."""
    from repro.models import flags
    flags.set_unroll(unroll)
    shd.specs.set_seq_parallel(seq_parallel)
    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(
        functools.partial(tf_model.init_params, cfg=cfg), key)
    pspecs = param_pspecs(params_struct,
                          moe_mode=cfg.moe.sharding_mode if cfg.moe else
                          "tensor")
    if shard_params_data:
        # Serving-only (beyond-paper): no optimizer binds weights to data
        # ranks, so spread every tensor's first free divisible dim over
        # (pod, data) as well -> weights occupy total/|mesh| per chip and
        # are all-gathered on use.
        pspecs = zero_shard_specs(pspecs, params_struct, mesh)
    params_ns = shd.tree_named_shardings(mesh, pspecs)
    specs = input_specs(cfg, shape)

    t0 = time.time()
    if shape.kind == "train":
        opt = adamw(3e-4, state_dtype=opt_dtype)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        opt_specs = {"count": P()}
        for mom in ("m", "v"):
            opt_specs[mom] = pspecs
            if zero:
                opt_specs[mom] = zero_shard_specs(
                    opt_specs[mom], params_struct, mesh)
        opt_ns = shd.tree_named_shardings(mesh, opt_specs)
        batch_ns = shd.tree_named_shardings(
            mesh, batch_pspecs(specs["batch"], mesh))
        step = make_train_step(cfg, opt, remat=remat, unroll=unroll,
                               loss_chunk=loss_chunk)
        jitted = jax.jit(step, in_shardings=(params_ns, opt_ns, batch_ns),
                         donate_argnums=(0, 1))
        args = (params_struct, opt_struct, specs["batch"])
    elif shape.kind == "prefill":
        batch_ns = shd.tree_named_shardings(
            mesh, batch_pspecs(specs["batch"], mesh))
        step = make_prefill_step(cfg, unroll=unroll)
        jitted = jax.jit(step, in_shardings=(params_ns, batch_ns))
        args = (params_struct, specs["batch"])
    else:  # decode
        cache_ns = shd.tree_named_shardings(
            mesh, cache_pspecs(specs["cache"], mesh))
        tok_ns = NamedSharding(mesh, P(None, None))
        pos_ns = NamedSharding(mesh, P())
        step = make_decode_step(cfg, unroll=unroll)
        jitted = jax.jit(step,
                         in_shardings=(params_ns, cache_ns, tok_ns, pos_ns),
                         donate_argnums=(1,))
        args = (params_struct, specs["cache"], specs["tokens"],
                specs["pos"])

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    flags.set_unroll(False)
    shd.specs.set_seq_parallel(False)
    return compiled, t_lower, t_compile


def _extract_cost(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(compiled.as_text())
    return np.array([flops, nbytes, float(coll["total"])]), coll


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               moe_mode: Optional[str] = None, zero: bool = False,
               opt_dtype: str = "float32", remat: bool = True,
               seq_parallel: bool = False, loss_chunk: int = 0,
               shard_params_data: bool = False,
               extrapolate: bool = True, hw=V5E,
               verbose: bool = True) -> dict:
    """Lower+compile one combination; returns the roofline record."""
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if moe_mode and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, sharding_mode=moe_mode))
    cfg = resolve_config(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    shd.set_mesh(mesh)
    opts = dict(zero=zero, opt_dtype=opt_dtype, remat=remat,
                seq_parallel=seq_parallel, loss_chunk=loss_chunk,
                shard_params_data=shard_params_data)

    # full-depth compile: proves lower/compile + memory analysis
    compiled, t_lower, t_compile = _compile_combo(cfg, shape, mesh, **opts)
    mem = compiled.memory_analysis()
    cost_full, coll_full = _extract_cost(compiled)

    # Cost extrapolation.  XLA counts while-loop bodies once, so the cost
    # probes (a) straighten every inner scan (flags.UNROLL_FOR_COST_ANALYSIS)
    # and (b) run at reduced depth/batch, then extend along the exact
    # bilinear law cost(P, B) = a0 + a1*P + (c0 + c1*P)*B:
    #   per-token work  ~ c-terms (attention, FFN, activation collectives),
    #   per-param work  ~ a-terms (optimizer, gradient all-reduce).
    n_periods = cfg.n_periods
    dp = int(np.prod([mesh.shape[a] for a in shd.batch_axes(mesh)]))
    b_full = shape.batch
    can_vary_b = b_full >= 2 * dp and b_full % dp == 0

    def _probe(p, b):
        sh = dataclasses.replace(shape, batch=b)
        return _extract_cost(_compile_combo(_with_periods(cfg, p), sh,
                                            mesh, unroll=True, **opts)[0])

    if extrapolate and n_periods > 2 and can_vary_b:
        b1, b2 = dp, 2 * dp
        f11, k11 = _probe(1, b1)
        f21, k21 = _probe(2, b1)
        f12, k12 = _probe(1, b2)
        f22, k22 = _probe(2, b2)

        def bilinear(v11, v21, v12, v22):
            s1 = (v12 - v11) / (b2 - b1)          # c0 + c1
            s2 = (v22 - v21) / (b2 - b1)          # c0 + 2 c1
            c1 = s2 - s1
            c0 = 2 * s1 - s2
            a1 = (v21 - v11) - c1 * b1
            a0 = v11 - a1 - (c0 + c1) * b1
            return (a0 + a1 * n_periods
                    + (c0 + c1 * n_periods) * b_full)

        cost_vec = bilinear(f11, f21, f12, f22)
        coll = {}
        for key_ in coll_full:
            if key_ == "count":
                continue
            coll[key_] = int(max(bilinear(
                k11.get(key_, 0), k21.get(key_, 0),
                k12.get(key_, 0), k22.get(key_, 0)), 0))
        coll["total"] = sum(coll[c] for c in coll if c != "count")
        coll["count"] = coll_full["count"]
        extrapolated = "bilinear(P,B)"
    elif extrapolate and n_periods > 2:
        c1v, coll1 = _probe(1, b_full)
        c2v, coll2 = _probe(2, b_full)
        cost_vec = c1v + (n_periods - 1) * (c2v - c1v)
        coll = {k: int(max(coll1.get(k, 0)
                           + (n_periods - 1) * (coll2.get(k, 0)
                                                - coll1.get(k, 0)), 0))
                for k in coll_full if k != "count"}
        coll["total"] = sum(coll[c] for c in coll if c != "count")
        coll["count"] = coll_full["count"]
        extrapolated = "linear(P)"
    else:
        cost_vec, coll = cost_full, coll_full
        extrapolated = False

    flops_dev, bytes_dev, coll_dev = [float(x) for x in cost_vec]
    terms = roofline_terms(flops_dev, bytes_dev, coll_dev, hw)

    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)
    args_b = mem_fields.get("argument_size_in_bytes") or 0
    temp_b = mem_fields.get("temp_size_in_bytes") or 0
    out_b = mem_fields.get("output_size_in_bytes") or 0
    alias_b = mem_fields.get("alias_size_in_bytes") or 0
    per_dev_hbm = args_b + temp_b
    # 'bytes accessed' counts every op's operands+results (VMEM reuse and
    # XLA-CPU bf16 emulation inflate it).  The floor is what must cross HBM
    # at least once: live arguments + (non-aliased) outputs.
    bytes_floor = args_b + max(out_b - alias_b, 0)
    terms["memory_floor_s"] = bytes_floor / hw.hbm_bw

    if shape.kind == "train":
        n_tokens = shape.batch * shape.seq
        model_flops = model_flops_6nd(cfg, n_tokens)
    elif shape.kind == "prefill":
        model_flops = model_flops_6nd(cfg, shape.batch * shape.seq) / 3.0
    else:
        model_flops = model_flops_6nd(cfg, shape.batch) / 3.0

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "roofline": terms,
        "model_flops": model_flops,
        "hlo_flops_global": flops_dev * n_dev,
        "model_flops_ratio": (model_flops / (flops_dev * n_dev)
                              if flops_dev else None),
        "memory": mem_fields,
        "bytes_floor_per_device": bytes_floor,
        "hbm_per_device_gb": per_dev_hbm / 1e9,
        "fits_hbm": bool(per_dev_hbm <= hw.hbm_bytes),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "extrapolated": extrapolated,
        "options": {"moe_mode": moe_mode, "zero": zero,
                    "opt_dtype": opt_dtype, "remat": remat,
                    "seq_parallel": seq_parallel, "loss_chunk": loss_chunk,
                    "shard_params_data": shard_params_data},
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {result['mesh']} "
              f"({n_dev} devices) ==")
        print(f"memory_analysis: {mem}")
        print(f"cost_analysis (extrapolated={extrapolated}): "
              f"flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e}")
        print(f"collectives/dev: {coll}")
        print(f"roofline: compute={terms['compute_s']:.4f}s "
              f"memory={terms['memory_s']:.4f}s "
              f"(floor {terms['memory_floor_s']:.4f}s) "
              f"collective={terms['collective_s']:.4f}s "
              f"dominant={terms['dominant']}")
        print(f"hbm/dev={result['hbm_per_device_gb']:.2f} GB "
              f"fits={result['fits_hbm']}  "
              f"model_flops_ratio={result['model_flops_ratio']:.3f}"
              if result['model_flops_ratio'] else "")
        print(f"lower={t_lower:.1f}s compile={t_compile:.1f}s", flush=True)
    shd.set_mesh(None)
    return result


def main():
    """CLI driver: dry-run the requested (arch, shape) grid."""
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--moe-mode", type=str, default=None,
                    choices=["tensor", "expert"])
    ap.add_argument("--zero", action="store_true")
    ap.add_argument("--opt-dtype", type=str, default="float32")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--out", type=str, default="artifacts/dryrun")
    ap.add_argument("--tag", type=str, default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for arch in list_architectures():
            for shape in INPUT_SHAPES:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        tag = args.tag + ("_mp" if args.multipod else "")
        fname = os.path.join(args.out, f"{arch}__{shape}{tag}.json")
        if args.skip_existing and os.path.exists(fname):
            print(f"skip existing {fname}")
            continue
        try:
            res = dryrun_one(arch, shape, multi_pod=args.multipod,
                             moe_mode=args.moe_mode, zero=args.zero,
                             opt_dtype=args.opt_dtype,
                             remat=not args.no_remat,
                             seq_parallel=args.seq_parallel,
                             loss_chunk=args.loss_chunk,
                             extrapolate=not args.no_extrapolate)
            with open(fname, "w") as f:
                json.dump(res, f, indent=1)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures.append((arch, shape, repr(e)[:500]))
            print(f"FAILED {arch} x {shape}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nAll {len(combos)} dry-runs succeeded.")


if __name__ == "__main__":
    main()
