"""Training driver: pjit a zoo model on whatever devices exist.

On the CPU container this trains reduced configs (the examples use it for
the ~100M-param student-expert run); on real hardware the same code path
drives the production mesh — the sharding rules are identical to the
dry-run's.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --batch 8 --seq 256 --lr 1e-3 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.checkpoint import save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data.streams import lm_batches
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf_model
from repro.optim import adamw
from repro.sharding import param_pspecs


def train(arch: str, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 256, lr: float = 1e-3, seed: int = 0,
          ckpt: str = None, log_every: int = 10, remat: bool = False):
    """Train a zoo model on synthetic LM batches (pjit on host mesh)."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_host_mesh()
    shd.set_mesh(mesh)
    key = jax.random.PRNGKey(seed)
    params = tf_model.init_params(key, cfg)
    opt = adamw(lr)
    opt_state = opt.init(params)

    pspecs = param_pspecs(params)
    params = jax.device_put(params, shd.tree_named_shardings(mesh, pspecs))

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step_fn(params, opt_state, batch_arrs):
        def loss_fn(p):
            loss, metrics = tf_model.train_loss(p, batch_arrs, cfg,
                                                remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state = opt.step(params, grads, opt_state)
        return loss, params, opt_state

    losses = []
    t0 = time.time()
    extras = {}
    if cfg.encoder is not None:
        extras["frames"] = jnp.zeros((batch, seq, cfg.d_model), cfg.jnp_dtype)
    if cfg.vision_stub:
        extras["image_embeds"] = jnp.zeros(
            (batch, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype)
    for i, b in enumerate(lm_batches(cfg.vocab, batch, seq, steps, seed)):
        arrs = {k: jnp.asarray(v) for k, v in b.items()}
        arrs.update(extras)
        loss, params, opt_state = step_fn(params, opt_state, arrs)
        losses.append(float(loss))
        if log_every and (i + 1) % log_every == 0:
            dt = time.time() - t0
            print(f"step {i+1}/{steps} loss={losses[-1]:.4f} "
                  f"({dt/(i+1):.2f}s/step)", flush=True)
    if ckpt:
        save_checkpoint(ckpt, {"params": params},
                        metadata={"arch": arch, "steps": steps,
                                  "final_loss": losses[-1]})
        print(f"checkpoint written to {ckpt}")
    shd.set_mesh(None)
    return losses


def main():
    """CLI wrapper around ``train``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()
    losses = train(args.arch, smoke=args.smoke, steps=args.steps,
                   batch=args.batch, seq=args.seq, lr=args.lr,
                   seed=args.seed, ckpt=args.ckpt, remat=args.remat)
    print(f"first loss {losses[0]:.4f} -> final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
