"""Logical-axis -> mesh-axis sharding rules.

The production mesh is ``(pod, data, model)`` (multi-pod) or ``(data, model)``
(single pod).  Rules:

* batch-like dims            -> ('pod', 'data')   [whatever subset exists]
* attention heads / d_ff / experts' ff / mamba d_inner / vocab -> 'model'
* everything else replicated.

A module-level "current mesh" avoids threading the mesh through every model
function; ``constrain`` is a no-op when no mesh is set (single-device tests)
or when a dim is not divisible by the axis size (e.g. batch=1 long_500k).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT_MESH: Optional[Mesh] = None

# Sequence parallelism (beyond-paper, §Perf): shard the sequence dim of
# inter-block activations over 'model' in addition to batch over
# (pod,data).  GSPMD then turns the tensor-parallel all-reduces into
# reduce-scatter/all-gather pairs and the stored scan carries shrink by
# the model-axis size (Megatron-SP pattern, via sharding constraints).
SEQ_PARALLEL = False


def set_seq_parallel(v: bool) -> None:
    """Toggle the Megatron-SP activation-sharding pattern globally."""
    global SEQ_PARALLEL
    SEQ_PARALLEL = v


def set_mesh(mesh: Optional[Mesh]) -> None:
    """Install ``mesh`` as the process-wide default device mesh."""
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    """The process-wide default device mesh, if one is installed."""
    return _CURRENT_MESH


def batch_axes(mesh: Optional[Mesh] = None):
    """The mesh axes a batch dim shards over ('pod','data' subset)."""
    mesh = mesh or _CURRENT_MESH
    if mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


# ---------------------------------------------------------------------------
# Lane sharding (batched cascade engine)
# ---------------------------------------------------------------------------
# The batched cascade engine's per-lane state is lane-major: feature
# batches, deferral probs, alive/called masks, expert labels, per-lane
# weights.  Lanes shard over the batch-like mesh axes ('pod','data');
# the shared cascade state (student params, deferral MLPs, optimizer
# state, demonstration ring buffers) is replicated — it is one cascade
# serving S lanes, not S cascades.

def lane_count(mesh: Mesh) -> int:
    """Number of devices the lane dim shards over ('pod' x 'data')."""
    return _axis_size(mesh, batch_axes(mesh))


def lane_spec(mesh: Mesh) -> P:
    """PartitionSpec sharding dim 0 over the lane axes."""
    axes = batch_axes(mesh)
    return P(axes) if axes else P()


def lane_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding placing dim 0 on the lane ('pod','data') axes."""
    return NamedSharding(mesh, lane_spec(mesh))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding replicating a value on every device of ``mesh``."""
    return NamedSharding(mesh, P())


def put_lanes(x, mesh: Mesh) -> jax.Array:
    """Place a lane-major host array with dim 0 sharded over the lane
    axes; falls back to replication when the dim does not divide (e.g. a
    partial final tick), mirroring ``constrain``'s divisibility rule."""
    x = np.asarray(x)
    if x.ndim and _fits(mesh, x.shape[0], batch_axes(mesh)):
        return jax.device_put(x, lane_sharding(mesh))
    return jax.device_put(x, replicated_sharding(mesh))


def put_replicated(x, mesh: Mesh) -> jax.Array:
    """Place ``x`` replicated over every device of ``mesh``."""
    return jax.device_put(x, replicated_sharding(mesh))


# ---------------------------------------------------------------------------
# In-flight route buffers (pipelined batched engine)
# ---------------------------------------------------------------------------
# The pipelined route mode (core/batched.py ``pipeline_depth``) keeps a
# P-deep ring of dispatched-but-unresolved ticks.  Each in-flight tick
# pins one padded lane feature buffer (the route pass input) and one
# (probs, dprob) output pair on the device until host routing resolves
# it.  Two annotations keep that ring cheap:

def jit_route_pass(fn, mesh: Optional[Mesh] = None):
    """Jit a per-level route pass ``fn(params, dparams, xb)``.

    ``xb`` is the padded lane-major feature buffer built fresh for each
    dispatch and never read again by the host.  With a mesh (where
    ``put_lanes`` has committed it to devices) it is donated, so a
    pipeline holding P ticks in flight pins only the route *outputs*
    instead of also keeping P dead input buffers alive.  Without a mesh
    the inputs may be uncommitted host-local arrays — donation would be
    ignored with a warning — so the plain jit is returned.
    """
    if mesh is None:
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=(2,))


def jit_cache_scatter(fn, mesh: Optional[Mesh] = None):
    """Jit the demonstration ring-buffer scatter ``fn(cx, cy, feats, y,
    called, ptr)`` with the ring buffers donated.

    The buffers mutate in place instead of copying — and with a mesh the
    outputs are pinned replicated so the donated buffers keep the same
    placement call after call.  Placement stability matters doubly in
    per-lane commit mode (core/batched.py ``per_lane=True``), where the
    scatter runs once per committed *lane* rather than once per tick:
    any placement drift would break the donation chain on every lane.
    """
    if mesh is None:
        return jax.jit(fn, donate_argnums=(0, 1))
    return jax.jit(fn, donate_argnums=(0, 1),
                   out_shardings=replicated_sharding(mesh))


def host_prefetch(arrays) -> None:
    """Start async device->host copies for ``arrays`` (non-blocking).

    The pipelined route ring calls this right after dispatching a tick's
    forwards: the D2H transfer of the in-flight ``(probs, dprob)`` pair
    is enqueued behind their producing computation, so it overlaps the
    next ticks' device compute and the eventual ``np.asarray`` at host
    resolution is a wait on a transfer already done, not a round trip.
    """
    for a in arrays:
        copy = getattr(a, "copy_to_host_async", None)
        if copy is not None:
            copy()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(mesh: Mesh, dim: int, axes) -> bool:
    size = _axis_size(mesh, axes)
    return size > 0 and dim % size == 0


def constrain(x, spec: Sequence) -> jax.Array:
    """with_sharding_constraint against the current mesh.

    ``spec`` entries are mesh-axis names (or tuples of them) per dim, or None.
    Dims whose size is not divisible by the axis size are silently
    replicated instead, so the same model code serves batch=256 training and
    batch=1 long-context decode.
    """
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    cleaned = []
    for dim, axes in zip(x.shape, spec):
        if axes is None:
            cleaned.append(None)
            continue
        present = tuple(a for a in (axes if isinstance(axes, tuple) else (axes,))
                        if a in mesh.axis_names)
        if present and _fits(mesh, dim, present):
            cleaned.append(present if len(present) > 1 else present[0])
        else:
            cleaned.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))


def constrain_tokens(x) -> jax.Array:
    """Shard (B, S, ...) activations: batch over (pod,data); if batch cannot
    shard (batch=1 long-context), shard the sequence dim instead.  With
    SEQ_PARALLEL also shard the sequence dim over 'model'."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    baxes = batch_axes(mesh)
    seq_ax = "model" if (SEQ_PARALLEL and x.ndim >= 2
                         and "model" in mesh.axis_names
                         and _fits(mesh, x.shape[1], ("model",))) else None
    if baxes and _fits(mesh, x.shape[0], baxes):
        return constrain(x, (baxes, seq_ax) + (None,) * (x.ndim - 2))
    if x.ndim >= 2 and baxes and _fits(mesh, x.shape[1], baxes):
        return constrain(x, (None, baxes) + (None,) * (x.ndim - 2))
    return x


# ---------------------------------------------------------------------------
# Parameter partition specs (path-based rules)
# ---------------------------------------------------------------------------
# Each rule: (path regex, spec builder taking ndim -> tuple). The leading
# n_periods stacking dim (present on block params) is always replicated.
# Specs below are for the *unstacked* suffix dims.

_RULES = [
    # embeddings / lm head: shard vocab over model
    (r"embed/table$",        lambda nd: ("model", None)),
    (r"lm_head/w$",          lambda nd: (None, "model")),
    # attention projections
    (r"(attn|self_attn|cross_attn)/wq$", lambda nd: (None, "model")),
    (r"(attn|self_attn|cross_attn)/wk$", lambda nd: (None, "model")),
    (r"(attn|self_attn|cross_attn)/wv$", lambda nd: (None, "model")),
    (r"(attn|self_attn|cross_attn)/wo$", lambda nd: ("model", None)),
    # dense mlp
    (r"mlp/w_gate$",         lambda nd: (None, "model")),
    (r"mlp/w_in$",           lambda nd: (None, "model")),
    (r"mlp/w_out$",          lambda nd: ("model", None)),
    # moe: tensor mode shards expert ff dim; router replicated
    (r"moe/w_gate$",         lambda nd: (None, None, "model")),
    (r"moe/w_in$",           lambda nd: (None, None, "model")),
    (r"moe/w_out$",          lambda nd: (None, "model", None)),
    (r"moe/router$",         lambda nd: (None, None)),
    # mamba: shard d_inner / heads over model
    (r"mamba/in_proj$",      lambda nd: (None, "model")),
    (r"mamba/conv_w$",       lambda nd: (None, "model")),
    (r"mamba/conv_b$",       lambda nd: ("model",)),
    (r"mamba/A_log$",        lambda nd: ("model",)),
    (r"mamba/D$",            lambda nd: ("model",)),
    (r"mamba/dt_bias$",      lambda nd: ("model",)),
    (r"mamba/gate_norm$",    lambda nd: ("model",)),
    (r"mamba/out_proj$",     lambda nd: ("model", None)),
]

_EXPERT_MODE_RULES = [
    # expert-parallel: shard the expert dim instead of ff
    (r"moe/w_gate$",         lambda nd: ("model", None, None)),
    (r"moe/w_in$",           lambda nd: ("model", None, None)),
    (r"moe/w_out$",          lambda nd: ("model", None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_path(path_str: str, ndim: int, stacked: bool,
                  moe_mode: str = "tensor") -> P:
    """PartitionSpec for a parameter path via the placement rule table."""
    rules = list(_RULES)
    if moe_mode == "expert":
        rules = _EXPERT_MODE_RULES + rules
    for pat, builder in rules:
        if re.search(pat, path_str):
            suffix = builder(ndim)
            if stacked:
                # leading n_periods dim replicated; pad/trim to ndim
                suffix = (None,) + tuple(suffix)
            suffix = tuple(suffix)[:ndim]
            suffix = suffix + (None,) * (ndim - len(suffix))
            return P(*suffix)
    return P(*([None] * ndim))


def param_pspecs(params, moe_mode: str = "tensor"):
    """Tree of PartitionSpec matching ``params`` (shapes or arrays)."""
    def one(path, leaf):
        ps = _path_str(path)
        ndim = len(leaf.shape)
        stacked = "/blocks/" in ("/" + ps + "/") or ps.startswith("blocks/")
        return spec_for_path(ps, ndim, stacked, moe_mode)
    return jax.tree_util.tree_map_with_path(one, params)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    """Bind one PartitionSpec to ``mesh`` as a NamedSharding."""
    return NamedSharding(mesh, spec)


def tree_named_shardings(mesh: Mesh, spec_tree):
    """Map a PartitionSpec tree to NamedShardings on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
