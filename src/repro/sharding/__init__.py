"""Sharding rules and mesh placement helpers (see ``specs`` module)."""
from repro.sharding.specs import (
    batch_axes, constrain, constrain_tokens, get_mesh, host_prefetch,
    jit_cache_scatter, jit_route_pass, lane_count, lane_sharding,
    lane_spec, named_sharding,
    param_pspecs, put_lanes, put_replicated, replicated_sharding, set_mesh,
    tree_named_shardings,
)

__all__ = [
    "set_mesh", "get_mesh", "constrain", "constrain_tokens", "batch_axes",
    "lane_count", "lane_spec", "lane_sharding", "replicated_sharding",
    "put_lanes", "put_replicated", "jit_route_pass", "jit_cache_scatter",
    "host_prefetch",
    "param_pspecs", "named_sharding", "tree_named_shardings",
]
