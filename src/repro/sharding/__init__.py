from repro.sharding.specs import (
    set_mesh, get_mesh, constrain, constrain_tokens, batch_axes,
    param_pspecs, named_sharding, tree_named_shardings,
)

__all__ = [
    "set_mesh", "get_mesh", "constrain", "constrain_tokens", "batch_axes",
    "param_pspecs", "named_sharding", "tree_named_shardings",
]
