# The paper's primary contribution: online cascade learning (Alg. 1).
from repro.core.mdp import episode_cost, policy_value
from repro.core.deferral import (
    DeferralSpec, deferral_init, deferral_prob)
from repro.core.cascade import (
    LevelSpec, CascadeConfig, OnlineCascade, default_cascade_config)
from repro.core.batched import BatchedCascadeEngine
from repro.core.experts import SimulatedExpert, ModelExpert
from repro.core.ensemble import OnlineEnsemble
from repro.core.distill import distill_students

__all__ = [
    "episode_cost", "policy_value",
    "DeferralSpec", "deferral_init", "deferral_prob",
    "LevelSpec", "CascadeConfig", "OnlineCascade", "default_cascade_config",
    "BatchedCascadeEngine",
    "SimulatedExpert", "ModelExpert", "OnlineEnsemble", "distill_students",
]
