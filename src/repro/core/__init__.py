"""The paper's primary contribution: online cascade learning (Alg. 1).

Public surface: the sequential reference ``OnlineCascade``, the
serving-scale ``BatchedCascadeEngine`` (batched / sharded / async /
pipelined), the deferral-gate math, and the expert implementations.
"""
from repro.core.admission import (
    CascadeFrontEnd, StreamRecord, serve_requests)
from repro.core.batched import BatchedCascadeEngine
from repro.core.cascade import (
    CascadeConfig, LevelSpec, OnlineCascade, default_cascade_config,
    kernel_cascade_config)
from repro.core.deferral import (
    DeferralSpec, deferral_init, deferral_prob, reexploration_floor)
from repro.core.distill import distill_students
from repro.core.ensemble import OnlineEnsemble
from repro.core.experts import (
    ExpertShardError, ExpertShardTimeout, ExpertWorkerDied, FlakyExpert,
    ModelExpert, SimulatedExpert)
from repro.core.mdp import episode_cost, policy_value

__all__ = [
    "episode_cost", "policy_value",
    "DeferralSpec", "deferral_init", "deferral_prob",
    "reexploration_floor",
    "LevelSpec", "CascadeConfig", "OnlineCascade", "default_cascade_config",
    "kernel_cascade_config", "BatchedCascadeEngine",
    "CascadeFrontEnd", "StreamRecord", "serve_requests",
    "SimulatedExpert", "ModelExpert", "FlakyExpert",
    "ExpertShardError", "ExpertShardTimeout", "ExpertWorkerDied",
    "OnlineEnsemble", "distill_students",
]
