"""Offline knowledge-distillation baseline (§4).

The stream is split 50/50: the first half provides distillation labels (LLM
annotations, up to the budget N), the second half is the test set.  Students
are trained offline (epochs over the annotated pool) and evaluated frozen —
no ensemble, no cascade, no online adaptation.  Mirrors the paper's
"Distilled LR" / "Distilled BERT" rows.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.features import hash_bow, hash_ids
from repro.models.students import (
    LRSpec, TinyTFSpec, lr_init, lr_predict, tinytf_init, tinytf_logits,
    tinytf_predict)
from repro.optim import adam


def distill_students(stream, expert, budget_n: int,
                     n_features: int = 2048,
                     tf_spec: TinyTFSpec = None,
                     epochs: int = 5, batch: int = 8, lr: float = 1e-3,
                     seed: int = 0) -> Dict[str, dict]:
    """Returns {'lr': {...}, 'tinytf': {...}} with test accuracy/recall."""
    n = len(stream)
    half = n // 2
    n_classes = stream.spec.n_classes
    tf_spec = tf_spec or TinyTFSpec(n_classes=n_classes)
    from dataclasses import replace
    tf_spec = replace(tf_spec, n_classes=n_classes)

    rng = np.random.default_rng(seed)
    train_idx = rng.choice(half, size=min(budget_n, half), replace=False)
    test_idx = np.arange(half, n)

    y_train = np.array([expert.label(int(i), stream.docs[int(i)])
                        for i in train_idx], np.int32)
    y_test = stream.labels[test_idx]

    results = {}

    # ---- logistic regression ----
    Xtr = np.stack([hash_bow(stream.docs[int(i)], n_features)
                    for i in train_idx])
    Xte = np.stack([hash_bow(stream.docs[int(i)], n_features)
                    for i in test_idx])
    lrspec = LRSpec(n_features=n_features, n_classes=n_classes)
    params = lr_init(jax.random.PRNGKey(seed), lrspec)
    opt = adam(0.05)
    state = opt.init(params)

    @jax.jit
    def lr_step(params, state, xb, yb):
        def loss_fn(p):
            logits = xb @ p["w"] + p["b"]
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)
        grads = jax.grad(loss_fn)(params)
        return opt.step(params, grads, state)

    for _ in range(epochs):
        order = rng.permutation(len(train_idx))
        for s in range(0, len(order) - batch + 1, batch):
            sel = order[s:s + batch]
            params, state = lr_step(params, state, jnp.asarray(Xtr[sel]),
                                    jnp.asarray(y_train[sel]))
    preds = np.asarray(jnp.argmax(lr_predict(params, jnp.asarray(Xte)),
                                  axis=-1))
    results["lr"] = _metrics(preds, y_test, n_classes)

    # ---- tiny transformer ----
    Itr = np.stack([hash_ids(stream.docs[int(i)], tf_spec.vocab,
                             tf_spec.max_len) for i in train_idx])
    Ite = np.stack([hash_ids(stream.docs[int(i)], tf_spec.vocab,
                             tf_spec.max_len) for i in test_idx])
    params = tinytf_init(jax.random.PRNGKey(seed + 1), tf_spec)
    opt = adam(lr)
    state = opt.init(params)

    @jax.jit
    def tf_step(params, state, xb, yb):
        def loss_fn(p):
            logits = tinytf_logits(p, xb, tf_spec)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)
        grads = jax.grad(loss_fn)(params)
        return opt.step(params, grads, state)

    for _ in range(epochs):
        order = rng.permutation(len(train_idx))
        for s in range(0, len(order) - batch + 1, batch):
            sel = order[s:s + batch]
            params, state = tf_step(params, state, jnp.asarray(Itr[sel]),
                                    jnp.asarray(y_train[sel]))
    preds = []
    for s in range(0, len(Ite), 256):
        p = tinytf_predict(params, jnp.asarray(Ite[s:s + 256]), tf_spec)
        preds.append(np.asarray(jnp.argmax(p, axis=-1)))
    preds = np.concatenate(preds)
    results["tinytf"] = _metrics(preds, y_test, n_classes)
    results["test_idx"] = test_idx
    return results


def _metrics(preds, labels, n_classes):
    out = {"accuracy": float(np.mean(preds == labels))}
    if n_classes == 2:
        pos = labels == 1
        tp = float(np.sum((preds == 1) & pos))
        out["recall"] = tp / max(float(np.sum(pos)), 1.0)
    return out
