"""Expert models m_N for the cascade.

* ``SimulatedExpert`` — the default for paper-reproduction runs: returns the
  stream's precomputed expert annotations (ground truth corrupted at the
  paper's per-dataset LLM accuracy, length-biased; data.streams).  Zero
  compute, exact control of the noisy-teacher regime.
* ``ModelExpert`` — a real in-repo model: a transformer classifier trained
  offline on ground truth to stand in for a zero-shot LLM.  Used by the
  end-to-end example so the full pipeline (featurize -> students -> deferral
  -> expert forward -> online updates) exercises real compute.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.features import hash_ids
from repro.data.streams import Stream
from repro.models.students import (
    TinyTFSpec, tinytf_init, tinytf_loss, tinytf_predict)
from repro.optim import adam


class SimulatedExpert:
    def __init__(self, stream: Stream, name: str = "gpt-3.5-turbo",
                 cost: float = 1.0e6):
        self.name = name
        self.cost = cost
        self._labels = stream.expert_labels(name)

    def label(self, idx: int, doc: np.ndarray) -> int:
        return int(self._labels[idx])

    def label_batch(self, idxs, docs) -> np.ndarray:
        """Annotate a deferred batch in one call (zero compute here; the
        batched engine routes all deferrals of a tick through this)."""
        return self._labels[np.asarray(idxs, np.int64)].astype(np.int32)


@dataclass
class ModelExpert:
    """A trained transformer classifier acting as the LLM expert."""
    params: dict
    spec: TinyTFSpec
    name: str = "model-expert"
    cost: float = 1.0e6

    def __post_init__(self):
        spec = self.spec
        self._predict = jax.jit(
            lambda p, ids: tinytf_predict(p, ids, spec))

    def label(self, idx: int, doc: np.ndarray) -> int:
        ids = hash_ids(doc, self.spec.vocab, self.spec.max_len)[None]
        probs = self._predict(self.params, jnp.asarray(ids))
        return int(jnp.argmax(probs[0]))

    def label_batch(self, idxs, docs) -> np.ndarray:
        """One batched forward for a tick's whole deferred subset."""
        if len(docs) == 0:
            return np.zeros((0,), np.int32)
        ids = np.stack([hash_ids(d, self.spec.vocab, self.spec.max_len)
                        for d in docs])
        probs = self._predict(self.params, jnp.asarray(ids))
        return np.asarray(jnp.argmax(probs, axis=-1), np.int32)


def train_model_expert(stream: Stream, n_classes: int,
                       d_model: int = 256, n_layers: int = 4,
                       epochs: int = 3, batch: int = 32,
                       lr: float = 1e-3, seed: int = 0,
                       max_samples: Optional[int] = None,
                       cost: float = 1.0e6) -> ModelExpert:
    """Train the stand-in LLM on ground truth (offline, before serving)."""
    spec = TinyTFSpec(d_model=d_model, n_layers=n_layers, d_ff=4 * d_model,
                      n_classes=n_classes)
    params = tinytf_init(jax.random.PRNGKey(seed), spec)
    opt = adam(lr)
    state = opt.init(params)
    n = len(stream) if max_samples is None else min(max_samples, len(stream))
    ids = np.stack([hash_ids(stream.docs[i], spec.vocab, spec.max_len)
                    for i in range(n)])
    labels = stream.labels[:n]

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: tinytf_loss(p, xb, yb, spec))(params)
        params, state = opt.step(params, grads, state)
        return params, state, loss

    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - batch + 1, batch):
            sel = order[s:s + batch]
            params, state, _ = step(params, state,
                                    jnp.asarray(ids[sel]),
                                    jnp.asarray(labels[sel]))
    return ModelExpert(params=params, spec=spec, cost=cost)
