"""Expert models m_N for the cascade.

* ``SimulatedExpert`` — the default for paper-reproduction runs: returns the
  stream's precomputed expert annotations (ground truth corrupted at the
  paper's per-dataset LLM accuracy, length-biased; data.streams).  Zero
  compute, exact control of the noisy-teacher regime.
* ``ModelExpert`` — a real in-repo model: a transformer classifier trained
  offline on ground truth to stand in for a zero-shot LLM.  Used by the
  end-to-end example so the full pipeline (featurize -> students -> deferral
  -> expert forward -> online updates) exercises real compute.

Async annotation interface (``submit``/``poll``)
------------------------------------------------
At serving scale the expert forward is the latency wall, so both experts
expose a two-phase interface the batched engine's deferred-lane queue
drives (core/batched.py ``max_delay``):

  ``ticket = expert.submit(idxs, docs)``   # enqueue a batch annotation
  ``labels = expert.poll(ticket)``         # block until done
  ``expert.poll(ticket, block=False)``     # None while still in flight

``SimulatedExpert`` resolves tickets inline (its labels are a table
lookup — there is nothing to overlap).  ``ModelExpert`` runs the batched
forward on a background thread, so the host-side expert compute overlaps
the next tick's student compute; jitted JAX dispatch is thread-safe and
releases the GIL while the device executes.  Either way the ticket for a
given (idxs, docs) batch resolves to exactly the labels ``label_batch``
would have returned synchronously — delay never changes annotations.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.features import hash_ids
from repro.data.streams import Stream
from repro.models.students import (
    TinyTFSpec, tinytf_init, tinytf_loss, tinytf_predict)
from repro.optim import adam


class ExpertTicket:
    """Handle for one in-flight batched annotation request.

    Wraps either an already-resolved label array (synchronous experts) or
    a ``concurrent.futures.Future`` producing one (thread-backed experts).
    """

    __slots__ = ("_labels", "_future")

    def __init__(self, labels: Optional[np.ndarray] = None, future=None):
        if (labels is None) == (future is None):
            raise ValueError("exactly one of labels/future required")
        self._labels = labels
        self._future = future

    def done(self) -> bool:
        """True once the labels are available without blocking."""
        return self._future is None or self._future.done()

    def result(self) -> np.ndarray:
        """Block until the labels are available and return them."""
        if self._future is not None:
            self._labels = np.asarray(self._future.result(), np.int32)
            self._future = None
        return self._labels


def poll_ticket(ticket: ExpertTicket,
                block: bool = True) -> Optional[np.ndarray]:
    """Shared ``poll`` body: labels when ready, else None (non-blocking)."""
    if not block and not ticket.done():
        return None
    return ticket.result()


class SimulatedExpert:
    """Zero-compute expert replaying precomputed noisy-LLM labels."""

    def __init__(self, stream: Stream, name: str = "gpt-3.5-turbo",
                 cost: float = 1.0e6):
        self.name = name
        self.cost = cost
        self._labels = stream.expert_labels(name)

    def label(self, idx: int, doc: np.ndarray) -> int:
        """Annotate one stream item (table lookup)."""
        return int(self._labels[idx])

    def label_batch(self, idxs, docs) -> np.ndarray:
        """Annotate a deferred batch in one call (zero compute here; the
        batched engine routes all deferrals of a tick through this)."""
        return self._labels[np.asarray(idxs, np.int64)].astype(np.int32)

    # -- async interface (resolved inline: a table lookup has no latency
    #    to overlap, but the engine drives one code path for all experts)
    def submit(self, idxs, docs) -> ExpertTicket:
        """Enqueue a batch annotation (resolved inline — no latency)."""
        return ExpertTicket(labels=self.label_batch(idxs, docs))

    def poll(self, ticket: ExpertTicket,
             block: bool = True) -> Optional[np.ndarray]:
        """Labels when ready, else None (non-blocking poll)."""
        return poll_ticket(ticket, block)


@dataclass
class ModelExpert:
    """A trained transformer classifier acting as the LLM expert."""
    params: dict
    spec: TinyTFSpec
    name: str = "model-expert"
    cost: float = 1.0e6
    _executor: Optional[ThreadPoolExecutor] = field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self):
        spec = self.spec
        self._predict = jax.jit(
            lambda p, ids: tinytf_predict(p, ids, spec))

    def label(self, idx: int, doc: np.ndarray) -> int:
        """Annotate one stream item with a single model forward."""
        ids = hash_ids(doc, self.spec.vocab, self.spec.max_len)[None]
        probs = self._predict(self.params, jnp.asarray(ids))
        return int(jnp.argmax(probs[0]))

    def label_batch(self, idxs, docs) -> np.ndarray:
        """One batched forward for a tick's whole deferred subset."""
        if len(docs) == 0:
            return np.zeros((0,), np.int32)
        ids = np.stack([hash_ids(d, self.spec.vocab, self.spec.max_len)
                        for d in docs])
        probs = self._predict(self.params, jnp.asarray(ids))
        return np.asarray(jnp.argmax(probs, axis=-1), np.int32)

    # -- async interface: the batched forward runs on a worker thread, so
    #    the expert's host+device time overlaps the engine's next-tick
    #    student compute (one worker keeps submission order = completion
    #    order, which the engine's FIFO queue relies on)
    def submit(self, idxs, docs) -> ExpertTicket:
        """Enqueue a batch annotation on the worker thread."""
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix=self.name)
        return ExpertTicket(
            future=self._executor.submit(self.label_batch, list(idxs),
                                         list(docs)))

    def poll(self, ticket: ExpertTicket,
             block: bool = True) -> Optional[np.ndarray]:
        """Labels when ready, else None (non-blocking poll)."""
        return poll_ticket(ticket, block)

    def close(self) -> None:
        """Reap the worker thread (long-lived processes that cycle
        through many experts should call this; idempotent)."""
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    def __del__(self):  # best-effort: don't leak the worker at GC
        try:
            self.close()
        except Exception:
            pass


def train_model_expert(stream: Stream, n_classes: int,
                       d_model: int = 256, n_layers: int = 4,
                       epochs: int = 3, batch: int = 32,
                       lr: float = 1e-3, seed: int = 0,
                       max_samples: Optional[int] = None,
                       cost: float = 1.0e6) -> ModelExpert:
    """Train the stand-in LLM on ground truth (offline, before serving)."""
    spec = TinyTFSpec(d_model=d_model, n_layers=n_layers, d_ff=4 * d_model,
                      n_classes=n_classes)
    params = tinytf_init(jax.random.PRNGKey(seed), spec)
    opt = adam(lr)
    state = opt.init(params)
    n = len(stream) if max_samples is None else min(max_samples, len(stream))
    ids = np.stack([hash_ids(stream.docs[i], spec.vocab, spec.max_len)
                    for i in range(n)])
    labels = stream.labels[:n]

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: tinytf_loss(p, xb, yb, spec))(params)
        params, state = opt.step(params, grads, state)
        return params, state, loss

    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - batch + 1, batch):
            sel = order[s:s + batch]
            params, state, _ = step(params, state,
                                    jnp.asarray(ids[sel]),
                                    jnp.asarray(labels[sel]))
    return ModelExpert(params=params, spec=spec, cost=cost)
