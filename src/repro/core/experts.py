"""Expert models m_N for the cascade.

* ``SimulatedExpert`` — the default for paper-reproduction runs: returns the
  stream's precomputed expert annotations (ground truth corrupted at the
  paper's per-dataset LLM accuracy, length-biased; data.streams).  Zero
  compute, exact control of the noisy-teacher regime.
* ``ModelExpert`` — a real in-repo model: a transformer classifier trained
  offline on ground truth to stand in for a zero-shot LLM.  Used by the
  end-to-end example so the full pipeline (featurize -> students -> deferral
  -> expert forward -> online updates) exercises real compute.

Async annotation interface (``submit``/``submit_many``/``poll``)
----------------------------------------------------------------
At serving scale the expert forward is the latency wall, so both experts
expose a two-phase interface the batched engine's deferred-lane queue
drives (core/batched.py ``max_delay``):

  ``ticket = expert.submit(idxs, docs)``        # one batch, one request
  ``ticket = expert.submit_many(idxs, docs)``   # sharded over the pool
  ``labels = expert.poll(ticket)``              # block until ALL done
  ``expert.poll(ticket, block=False)``          # None while in flight
  ``expert.poll_partial(ticket)``               # (ready_mask, labels)

``submit_many`` splits the batch into ``min(workers, k)`` contiguous
shards (``shard_bounds`` — a pure function of (k, workers), never of
worker timing) and annotates them on W concurrent workers; the returned
``ExpertTicket`` tracks **per-item completion**, so the engine's
per-lane commit drain (``BatchedCascadeEngine(per_lane=True)``) can
block on exactly the prefix it needs (``result_slice``) instead of the
whole batch.  ``SimulatedExpert`` resolves labels lazily *at poll time*
(never at submit — an optional fake latency, counted in non-blocking
``done()`` probes, makes its tickets genuinely in-flight so delay/pool
tests exercise the real poll path).  ``ModelExpert`` runs each shard's
batched forward on a pool thread, so the host-side expert compute
overlaps the engine's next-tick student compute; jitted JAX dispatch is
thread-safe and releases the GIL while the device executes.  Either way
a ticket resolves to exactly the labels ``label_batch`` would have
returned synchronously on each shard — delay and worker count never
change annotations for the table-lookup expert, and are deterministic
functions of (k, workers) for the model expert.

Failure semantics (ARCHITECTURE.md §10)
---------------------------------------
A shard that fails to resolve raises a typed error carrying its item
range: ``ExpertShardTimeout`` when ``result_slice(..., timeout=)``
expires, ``ExpertWorkerDied`` when the worker raised or its process
vanished.  The engine reacts by *requeuing* the failed range to another
worker (``ExpertTicket.replace`` splices a fresh sub-ticket over the
dead shard), or — past ``max_requeues`` — by force-resolving it to the
``-1`` dropped-annotation sentinel (``force_resolve``) so commits never
deadlock.  ``FlakyExpert`` wraps any expert with scripted or seeded
fault injection (timeout / worker-death / slow-shard schedules) so the
chaos tests and ``benchmarks/fault_tolerance.py`` share one fault
model.  ``ModelExpert(backend="process")`` runs shard forwards in a
spawn-context process pool for GIL-bound annotators; a broken pool is
detected and rebuilt on the next submit, which is what turns a real
worker death into an ``ExpertWorkerDied`` + successful requeue.
"""
from __future__ import annotations

import threading
import zlib
from concurrent.futures import (ProcessPoolExecutor, ThreadPoolExecutor,
                                TimeoutError as _FuturesTimeout)
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize as _san
from repro.data.features import hash_ids
from repro.data.streams import Stream
from repro.models.students import (
    TinyTFSpec, tinytf_init, tinytf_loss, tinytf_predict)
from repro.optim import adam


class ExpertShardError(RuntimeError):
    """A ticket shard failed to resolve.

    Carries the failed item range ``[lo, hi)`` (``hi`` is None for a
    legacy future-form shard whose length was never observed — the
    holder of the ticket knows the submitted batch size and substitutes
    it).  The engine's requeue path catches this, never user code on the
    synchronous ``label_batch`` surface.
    """

    def __init__(self, lo: int, hi: Optional[int], msg: str,
                 cause: Optional[BaseException] = None):
        super().__init__(f"{msg} (items [{lo}, {hi}))")
        self.lo = int(lo)
        self.hi = None if hi is None else int(hi)
        self.cause = cause


class ExpertShardTimeout(ExpertShardError):
    """``result_slice(..., timeout=)`` expired before the shard landed."""

    def __init__(self, lo, hi, cause=None):
        super().__init__(lo, hi, "expert shard timed out", cause)


class ExpertWorkerDied(ExpertShardError):
    """The worker annotating a shard raised or its process vanished."""

    def __init__(self, lo, hi, cause=None):
        super().__init__(lo, hi, f"expert worker died: {cause!r}", cause)


def shard_bounds(k: int, workers: int) -> List[Tuple[int, int]]:
    """Contiguous balanced split of ``k`` items into ``min(workers, k)``
    shards: shard j covers ``[j*k//w, (j+1)*k//w)``.

    A pure function of (k, workers) — never of worker timing — so a
    pooled annotation's shard layout (and therefore, for a model expert,
    its per-shard batched forwards) is deterministic.  Contiguous shards
    match the engine's (tick, lane) commit order: the per-lane drain
    blocks on a prefix, which touches the fewest shards possible.
    """
    if k <= 0:
        return []
    w = max(1, min(int(workers), k))
    edges = [(j * k) // w for j in range(w + 1)]
    return [(edges[j], edges[j + 1]) for j in range(w)]


class ExpertTicket:
    """Handle for one in-flight batched annotation request.

    The ticket is a list of contiguous *shards*, each either an already
    resolved ``np.ndarray`` of labels or a future-like object exposing
    ``done()``/``result()`` (``concurrent.futures.Future`` for
    thread-backed experts, ``_SimulatedAnnotation`` for the fake-latency
    simulated expert).  Per-item completion is observable through
    ``item_done``/``ready_mask``, and ``result_slice`` blocks on exactly
    the shards overlapping the requested range — the primitive the
    engine's per-lane commit drain is built on.

    Thread safety: the shard table is mutated in place as shards resolve
    (``_resolve`` swaps a future for its labels, ``_settle_bounds`` fills
    a legacy shard's unknown upper bound), and tickets may be probed
    while pool workers are completing those futures — so every shard
    access goes through ``self._lock`` (re-entrant: the per-item surface
    calls the internals).  cascade-lint CAS004 enforces the enclosure.
    """

    __slots__ = ("_shards", "_lock")

    def __init__(self, labels: Optional[np.ndarray] = None, future=None,
                 shards: Optional[Sequence] = None):
        if sum(x is not None for x in (labels, future, shards)) != 1:
            raise ValueError(
                "exactly one of labels/future/shards required")
        self._lock = threading.RLock()
        if labels is not None:
            labels = np.asarray(labels, np.int32)
            self._shards = [[0, len(labels), labels]]  # guarded-by: _lock
        elif future is not None:
            # length unknown until resolution (legacy single-future form)
            self._shards = [[0, None, future]]
        else:
            # hi None = legacy future-form span (length settles on
            # resolution); preserved so ``wrapped`` round-trips it
            self._shards = [[int(lo), None if hi is None else int(hi),
                             payload] for lo, hi, payload in shards]

    # -- internals ------------------------------------------------------
    def _resolve(self, shard, timeout: Optional[float] = None) -> np.ndarray:
        if not isinstance(shard[2], np.ndarray):
            try:
                # no-timeout waits stay a plain result() call: futures
                # here are duck-typed and need not take a timeout arg
                labels = (shard[2].result() if timeout is None
                          else shard[2].result(timeout))
            except (_FuturesTimeout, TimeoutError) as e:
                raise ExpertShardTimeout(shard[0], shard[1], cause=e) from e
            except ExpertShardError:
                raise
            except Exception as e:
                # anything else out of a future is the worker's demise:
                # an exception it raised, or BrokenProcessPool after its
                # process vanished
                raise ExpertWorkerDied(shard[0], shard[1], cause=e) from e
            shard[2] = np.asarray(labels, np.int32)
            if shard[1] is None:
                shard[1] = shard[0] + len(shard[2])
        return shard[2]

    @staticmethod
    def _shard_done(shard) -> bool:
        return isinstance(shard[2], np.ndarray) or shard[2].done()

    def _settle_bounds(self, shard) -> None:
        """Resolve a shard whose upper bound is unknown (the legacy
        ``future=`` form) once it is done, so per-item queries can
        bound-check without blocking on in-flight work."""
        if shard[1] is None and self._shard_done(shard):
            self._resolve(shard)

    def _n_items(self) -> int:
        with self._lock:
            last = self._shards[-1] if self._shards else None
            if last is None:
                return 0
            self._settle_bounds(last)
            if last[1] is None:
                raise ValueError("ticket length unknown while its legacy "
                                 "future-form shard is still in flight")
            return int(last[1])

    # -- whole-ticket interface (the PR-3 per-tick commit path) ---------
    def done(self) -> bool:
        """True once every item's labels are available without blocking.

        Probes EVERY shard (no short-circuit), so fake-latency shards
        (``_SimulatedAnnotation`` credits) drain uniformly — one credit
        per shard per whole-ticket poll, the same rate ``ready_mask``
        consumes them."""
        with self._lock:
            return all([self._shard_done(s) for s in self._shards])

    def result(self) -> np.ndarray:
        """Block until every shard resolves; return all labels in order."""
        with self._lock:
            if not self._shards:
                return np.zeros((0,), np.int32)
            return np.concatenate([self._resolve(s) for s in self._shards])

    # -- per-item interface (the per-lane commit path) ------------------
    def item_done(self, i: int) -> bool:
        """True once item ``i``'s label is available without blocking.

        Raises IndexError for out-of-range ``i``; while a legacy
        future-form shard is still in flight its length is unknown, so
        indices past its start conservatively report not-done."""
        with self._lock:
            for shard in self._shards:
                self._settle_bounds(shard)
                lo, hi = shard[0], shard[1]
                if lo <= i and (hi is None or i < hi):
                    return self._shard_done(shard)
        raise IndexError(i)

    def ready_mask(self) -> np.ndarray:
        """(n,) bool — which items are resolvable without blocking."""
        with self._lock:
            for shard in self._shards:
                self._settle_bounds(shard)
            mask = np.zeros(self._n_items(), bool)
            for shard in self._shards:
                mask[shard[0]:shard[1]] = self._shard_done(shard)
            return mask

    def result_slice(self, lo: int, hi: int,
                     timeout: Optional[float] = None) -> np.ndarray:
        """Labels for items ``[lo, hi)``, blocking only on the shards
        that overlap the range (other shards stay in flight).

        ``timeout`` bounds the wait on EACH overlapping shard; on expiry
        an ``ExpertShardTimeout`` carrying that shard's range escapes —
        the engine's requeue deadline (core/batched.py).
        """
        parts = []
        with self._lock:
            for s in self._shards:
                s_lo, s_hi = s[0], s[1]
                if s_hi is not None and (s_hi <= lo or s_lo >= hi):
                    continue
                labels = self._resolve(s, timeout)
                s_hi = s[1]
                if s_hi <= lo or s_lo >= hi:
                    continue
                parts.append(labels[max(lo - s_lo, 0):hi - s_lo])
        if not parts:
            return np.zeros((0,), np.int32)
        return np.concatenate(parts)

    # -- failure handling (the engine's requeue path) -------------------
    def _find_shard(self, lo: int, hi: int) -> int:
        with self._lock:      # re-entrant under replace/force_resolve
            for i, s in enumerate(self._shards):
                if s[0] == lo and (s[1] == hi or s[1] is None):
                    return i
        raise ValueError(f"no shard covering exactly [{lo}, {hi})")

    def replace(self, lo: int, hi: int, ticket: "ExpertTicket") -> None:
        """Splice ``ticket`` (a fresh annotation of items ``[lo, hi)``,
        indexed from 0) over the failed shard covering that range —
        the requeue primitive.  The replacement's shards are re-based
        to this ticket's coordinates."""
        with self._lock:
            i = self._find_shard(lo, hi)
            with ticket._lock:
                repl = [[lo + s[0],
                         hi if s[1] is None else lo + s[1],
                         s[2]] for s in ticket._shards]
            self._shards[i:i + 1] = repl

    def force_resolve(self, lo: int, hi: int, labels: np.ndarray) -> None:
        """Overwrite the shard covering ``[lo, hi)`` with fixed labels —
        the graceful-degradation terminal after ``max_requeues`` (the
        engine passes the ``-1`` dropped-annotation sentinel)."""
        with self._lock:
            i = self._find_shard(lo, hi)
            self._shards[i] = [lo, hi, np.asarray(labels, np.int32)]

    def wrapped(self, fn: Callable) -> "ExpertTicket":
        """A new ticket over the same shard spans with each payload
        replaced by ``fn(shard_idx, payload)`` — the fault-injection
        hook ``FlakyExpert`` builds on."""
        with self._lock:
            return ExpertTicket(shards=[
                (s[0], s[1], fn(j, s[2]))
                for j, s in enumerate(self._shards)])


def poll_ticket(ticket: ExpertTicket,
                block: bool = True) -> Optional[np.ndarray]:
    """Shared ``poll`` body: labels when ready, else None (non-blocking)."""
    if not block and not ticket.done():
        return None
    return ticket.result()


def poll_ticket_partial(
        ticket: ExpertTicket) -> Tuple[np.ndarray, np.ndarray]:
    """Non-blocking partial poll: ``(ready_mask, labels)``.

    ``labels[i]`` is valid only where ``ready_mask[i]``; unready slots
    hold -1 (the same in-flight sentinel the engine's tick outputs use).
    """
    mask = ticket.ready_mask()
    labels = np.full(mask.shape, -1, np.int32)
    lo = 0
    while lo < mask.size:
        if not mask[lo]:
            lo += 1
            continue
        hi = lo
        while hi < mask.size and mask[hi]:
            hi += 1
        labels[lo:hi] = ticket.result_slice(lo, hi)
        lo = hi
    return mask, labels


LatencyLike = Union[None, int, Callable[[int, int], int]]


class _SimulatedAnnotation:
    """Future-like shard payload for ``SimulatedExpert``.

    Labels are computed lazily at resolution (``result``), never at
    submit — so the engine's poll path is exercised for real.  The fake
    latency is counted in non-blocking ``done()`` probes: each probe
    consumes one credit, and the shard reports ready once its credits
    run out.  The engine polls once per tick boundary, so a credit is
    roughly one tick of simulated annotation latency.  ``result()``
    always resolves (a blocking poll "waits out" the remaining latency)
    — latency shifts *when* labels are observable, never *what* they
    are.
    """

    __slots__ = ("_fn", "_credits")

    def __init__(self, fn: Callable[[], np.ndarray], credits: int):
        self._fn = fn
        self._credits = max(int(credits), 0)

    def done(self) -> bool:
        if self._credits > 0:
            self._credits -= 1
            return False
        return True

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        # a blocking resolve waits out any remaining latency, so the
        # timeout can never expire on a simulated shard
        self._credits = 0
        return self._fn()


class SimulatedExpert:
    """Zero-compute expert replaying precomputed noisy-LLM labels.

    ``workers`` sets how many shards ``submit_many`` splits a batch into
    (mirroring ``ModelExpert``'s pool so the engine's per-lane drain
    sees the same per-item ticket shape).  ``latency`` simulates
    annotation delay: an int applies to every shard; a callable
    ``(submit_seq, shard_idx) -> int`` scripts adversarial per-shard
    schedules (credits are consumed by non-blocking ``done()`` probes —
    see ``_SimulatedAnnotation``).  Labels are a pure table lookup, so
    they are invariant to workers and latency by construction.
    """

    def __init__(self, stream: Stream, name: str = "gpt-3.5-turbo",
                 cost: float = 1.0e6, *, workers: Union[int, str] = 1,
                 latency: LatencyLike = None):
        self.name = name
        self.cost = cost
        # workers="auto" asks the ENGINE to drive the width off queue
        # depth (core/batched.py autoscale); the fleet starts at 1
        self.auto_workers = workers == "auto"
        self.workers = 1 if self.auto_workers else max(int(workers), 1)
        self.latency = latency
        self._labels = stream.expert_labels(name)
        self._lock = threading.RLock()
        self._submit_seq = 0   # guarded-by: _lock

    def label(self, idx: int, doc: np.ndarray) -> int:
        """Annotate one stream item (table lookup)."""
        return int(self._labels[idx])

    def label_batch(self, idxs, docs) -> np.ndarray:
        """Annotate a deferred batch in one call (zero compute here; the
        batched engine routes all deferrals of a tick through this)."""
        return self._labels[np.asarray(idxs, np.int64)].astype(np.int32)

    # -- async interface ------------------------------------------------
    def _shard_delay(self, seq: int, j: int) -> int:
        lat = self.latency
        if lat is None:
            return 0
        if callable(lat):
            return int(lat(seq, j))
        return int(lat)

    def _make_ticket(self, idxs, docs, nshards: int) -> ExpertTicket:
        idx_arr = np.asarray(idxs, np.int64)
        with self._lock:
            seq = self._submit_seq
            self._submit_seq += 1
        shards = []
        for j, (lo, hi) in enumerate(shard_bounds(len(idx_arr), nshards)):
            sel = idx_arr[lo:hi]
            shards.append((lo, hi, _SimulatedAnnotation(
                lambda sel=sel: self._labels[sel].astype(np.int32),
                self._shard_delay(seq, j))))
        return ExpertTicket(shards=shards)

    def submit(self, idxs, docs) -> ExpertTicket:
        """Enqueue a batch annotation as one lazily-resolving shard."""
        return self._make_ticket(idxs, docs, 1)

    def submit_many(self, idxs, docs) -> ExpertTicket:
        """Enqueue a batch sharded into ``min(workers, k)`` lazily
        resolving sub-requests with per-item completion."""
        return self._make_ticket(idxs, docs, self.workers)

    def poll(self, ticket: ExpertTicket,
             block: bool = True) -> Optional[np.ndarray]:
        """Labels when ready, else None (non-blocking poll)."""
        return poll_ticket(ticket, block)

    def poll_partial(self, ticket: ExpertTicket):
        """Non-blocking partial poll: (ready_mask, labels-with--1)."""
        return poll_ticket_partial(ticket)


def _fault_draw(seed: int, seq: int, shard: int, salt: str) -> float:
    """Deterministic uniform in [0, 1) for one (submit, shard) cell.

    A keyed hash, not a Generator: fault draws must be a pure function
    of the submit sequence so a replayed schedule injects the same
    faults, and constructing RNGs per shard would trip the repo's RNG
    discipline (cascade-lint CAS001) for no benefit.
    """
    h = zlib.crc32(f"{seed}:{seq}:{shard}:{salt}".encode())
    return (h & 0xFFFFFF) / float(1 << 24)


class _FaultyShard:
    """Payload wrapper injecting one scripted fault into a shard.

    * ``"timeout"`` — a hung worker: never reports done, and ``result``
      raises ``TimeoutError`` even on a blocking resolve (so tests and
      the no-timeout engine path stay deadlock-free; the engine treats
      it exactly like an expired deadline).
    * ``"die"`` — the worker crashed: reports done, ``result`` raises.
    * ``("slow", n)`` — adds ``n`` extra not-done probes before
      delegating (per-shard latency skew for readiness/commit-age
      tests).
    """

    __slots__ = ("_inner", "_kind", "_credits")

    def __init__(self, inner, fault):
        if isinstance(fault, tuple):
            kind, credits = fault
        else:
            kind, credits = fault, 0
        if kind not in ("timeout", "die", "slow"):
            raise ValueError(f"unknown fault kind {kind!r}")
        self._inner = inner
        self._kind = kind
        self._credits = max(int(credits), 0)

    def done(self) -> bool:
        if self._kind == "timeout":
            return False
        if self._kind == "die":
            return True
        if self._credits > 0:
            self._credits -= 1
            return False
        return (isinstance(self._inner, np.ndarray)
                or self._inner.done())

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if self._kind == "timeout":
            raise TimeoutError("injected shard timeout (hung worker)")
        if self._kind == "die":
            raise RuntimeError("injected worker death")
        self._credits = 0
        if isinstance(self._inner, np.ndarray):
            return self._inner
        return self._inner.result(timeout)


class FlakyExpert:
    """Fault-injection wrapper around any expert (chaos harness).

    Faults apply per (submit sequence, shard index) cell and are chosen
    either by an explicit ``schedule(seq, shard) -> None | "timeout" |
    "die" | ("slow", n)`` callable, or by seeded per-cell rates
    (``timeout_rate`` / ``death_rate`` / ``slow_rate``, drawn via a
    keyed hash — deterministic, replayable, CAS001-clean).  Requeued
    shards arrive as NEW submits with fresh sequence numbers, so a
    scripted schedule decides whether a retry succeeds or fails again.

    Labels themselves are never altered: a fault only changes *whether
    and when* a shard resolves.  That is what makes the chaos suite's
    bitwise-invariance assertions meaningful — any divergence under
    injected faults is an engine bug, not injected noise (the one
    exception being annotations the engine explicitly drops after
    ``max_requeues``, which it must count in ``dropped_annotation``).
    """

    def __init__(self, inner, *, schedule: Optional[Callable] = None,
                 timeout_rate: float = 0.0, death_rate: float = 0.0,
                 slow_rate: float = 0.0, slow_credits: int = 2,
                 seed: int = 0):
        self.inner = inner
        self.name = getattr(inner, "name", "flaky")
        self.cost = getattr(inner, "cost", 0.0)
        self.schedule = schedule
        self.timeout_rate = float(timeout_rate)
        self.death_rate = float(death_rate)
        self.slow_rate = float(slow_rate)
        self.slow_credits = int(slow_credits)
        self.seed = int(seed)
        self._lock = threading.RLock()
        self._submit_seq = 0        # guarded-by: _lock
        self.injected = {"timeout": 0, "die": 0, "slow": 0}

    # fleet-width plumbing: autoscale drives the INNER pool through the
    # wrapper, so a flaky fleet still scales
    @property
    def workers(self) -> int:
        return getattr(self.inner, "workers", 1)

    @workers.setter
    def workers(self, w: int) -> None:
        self.inner.workers = w

    @property
    def auto_workers(self) -> bool:
        return getattr(self.inner, "auto_workers", False)

    def label(self, idx, doc):
        """Synchronous single-item surface is passed through un-faulted."""
        return self.inner.label(idx, doc)

    def label_batch(self, idxs, docs):
        """Synchronous batch surface is passed through un-faulted."""
        return self.inner.label_batch(idxs, docs)

    def _fault(self, seq: int, j: int):
        if self.schedule is not None:
            return self.schedule(seq, j)
        if (self.timeout_rate
                and _fault_draw(self.seed, seq, j, "t") < self.timeout_rate):
            return "timeout"
        if (self.death_rate
                and _fault_draw(self.seed, seq, j, "d") < self.death_rate):
            return "die"
        if (self.slow_rate
                and _fault_draw(self.seed, seq, j, "s") < self.slow_rate):
            return ("slow", self.slow_credits)
        return None

    def _wrap(self, ticket: ExpertTicket) -> ExpertTicket:
        with self._lock:
            seq = self._submit_seq
            self._submit_seq += 1

        def inject(j, payload):
            fault = self._fault(seq, j)
            if fault is None:
                return payload
            kind = fault[0] if isinstance(fault, tuple) else fault
            with self._lock:
                self.injected[kind] += 1
            return _FaultyShard(payload, fault)

        return ticket.wrapped(inject)

    def submit(self, idxs, docs) -> ExpertTicket:
        """Submit through the inner expert, then overlay faults."""
        return self._wrap(self.inner.submit(idxs, docs))

    def submit_many(self, idxs, docs) -> ExpertTicket:
        """Sharded submit through the inner expert, faults overlaid."""
        return self._wrap(self.inner.submit_many(idxs, docs))

    def poll(self, ticket: ExpertTicket,
             block: bool = True) -> Optional[np.ndarray]:
        """Labels when ready, else None (non-blocking poll)."""
        return poll_ticket(ticket, block)

    def poll_partial(self, ticket: ExpertTicket):
        """Non-blocking partial poll: (ready_mask, labels-with--1)."""
        return poll_ticket_partial(ticket)

    def close(self) -> None:
        """Close the wrapped expert's pool (if it has one)."""
        close = getattr(self.inner, "close", None)
        if close is not None:
            close()


# -- process-pool worker side (module-level: must pickle under spawn) ---
_PROCESS_EXPERT: Optional[list] = None


def _process_worker_init(params, spec) -> None:
    """Pool initializer: stash (host params, spec); jit lazily per child."""
    global _PROCESS_EXPERT
    _PROCESS_EXPERT = [params, spec, None]


def _process_label_batch(idxs, docs) -> np.ndarray:
    """``ModelExpert.label_batch`` body, run inside a pool process."""
    params, spec, predict = _PROCESS_EXPERT
    if predict is None:
        predict = jax.jit(lambda p, ids: tinytf_predict(p, ids, spec))
        _PROCESS_EXPERT[2] = predict
    if len(docs) == 0:
        return np.zeros((0,), np.int32)
    ids = np.stack([hash_ids(d, spec.vocab, spec.max_len) for d in docs])
    probs = predict(params, jnp.asarray(ids))
    return np.asarray(jnp.argmax(probs, axis=-1), np.int32)


@dataclass
class ModelExpert:
    """A trained transformer classifier acting as the LLM expert.

    ``workers`` sizes the annotation pool: ``submit_many`` splits a
    batch into that many contiguous shards and runs each shard's batched
    forward on its own pool worker, so a slow annotation batch never
    serializes behind a single worker and the engine's per-lane commit
    drain can consume early shards while later ones are still in flight.
    ``workers="auto"`` hands the width to the engine's queue-depth
    autoscaler (core/batched.py).

    ``backend`` picks the pool: ``"thread"`` (default — jitted dispatch
    releases the GIL while the device executes, so threads already
    overlap) or ``"process"`` for GIL-bound annotators: a spawn-context
    ``ProcessPoolExecutor`` whose children get the host-gathered params
    at fork-free init and jit their own forward (spawn, never fork —
    XLA's runtime threads don't survive forking).  The executor is
    sized to ``max(workers, max_workers)`` so autoscaling up never
    needs a pool rebuild; a broken process pool (a child died) is
    detected and rebuilt on the next submit.
    """

    params: dict
    spec: TinyTFSpec
    name: str = "model-expert"
    cost: float = 1.0e6
    workers: Union[int, str] = 1
    backend: str = "thread"
    max_workers: Optional[int] = None
    _executor: Optional[ThreadPoolExecutor] = field(     # guarded-by: _lock
        default=None, init=False, repr=False, compare=False)
    _lock: threading.RLock = field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self):
        spec = self.spec
        if self.backend not in ("thread", "process"):
            raise ValueError(f"backend must be 'thread' or 'process', "
                             f"got {self.backend!r}")
        self.auto_workers = self.workers == "auto"
        self.workers = 1 if self.auto_workers else max(int(self.workers), 1)
        self._lock = threading.RLock()
        self._predict = jax.jit(_san.trace_probe(
            "expert.predict", lambda p, ids: tinytf_predict(p, ids, spec)))

    def label(self, idx: int, doc: np.ndarray) -> int:
        """Annotate one stream item with a single model forward."""
        ids = hash_ids(doc, self.spec.vocab, self.spec.max_len)[None]
        probs = self._predict(self.params, jnp.asarray(ids))
        return int(jnp.argmax(probs[0]))

    def label_batch(self, idxs, docs) -> np.ndarray:
        """One batched forward for a tick's whole deferred subset."""
        if len(docs) == 0:
            return np.zeros((0,), np.int32)
        ids = np.stack([hash_ids(d, self.spec.vocab, self.spec.max_len)
                        for d in docs])
        probs = self._predict(self.params, jnp.asarray(ids))
        return np.asarray(jnp.argmax(probs, axis=-1), np.int32)

    # -- async interface: shard forwards run on pool threads, so the
    #    expert's host+device time overlaps the engine's next-tick
    #    student compute (jitted dispatch releases the GIL while the
    #    device executes; shard layout is deterministic — shard_bounds)
    def _pool_width(self) -> int:
        return max(self.workers,
                   self.max_workers if self.max_workers else 1)

    def _pool(self):
        with self._lock:
            ex = self._executor
            if ex is not None and getattr(ex, "_broken", False):
                # a dead child poisons the whole ProcessPoolExecutor;
                # rebuild so requeued shards land on fresh workers
                ex.shutdown(wait=False, cancel_futures=True)
                ex = self._executor = None
            if ex is None:
                if self.backend == "process":
                    import multiprocessing as mp
                    host_params = jax.device_get(self.params)
                    self._executor = ProcessPoolExecutor(
                        max_workers=self._pool_width(),
                        mp_context=mp.get_context("spawn"),
                        initializer=_process_worker_init,
                        initargs=(host_params, self.spec))
                else:
                    self._executor = ThreadPoolExecutor(
                        max_workers=self._pool_width(),
                        thread_name_prefix=self.name)
            return self._executor

    def _task(self):
        # process children can't pickle the jitted bound method; they
        # run the module-level twin against their initializer state
        return (_process_label_batch if self.backend == "process"
                else self.label_batch)

    def submit(self, idxs, docs) -> ExpertTicket:
        """Enqueue a batch annotation as ONE pool request (kept for the
        per-tick commit path, where only whole-batch completion
        matters)."""
        return ExpertTicket(
            future=self._pool().submit(self._task(), list(idxs),
                                       list(docs)))

    def submit_many(self, idxs, docs) -> ExpertTicket:
        """Enqueue a batch sharded over the worker pool; the returned
        ticket completes per item as each shard's forward lands."""
        idxs = list(idxs)
        docs = list(docs)
        pool = self._pool()
        task = self._task()
        shards = [
            (lo, hi, pool.submit(task, idxs[lo:hi], docs[lo:hi]))
            for lo, hi in shard_bounds(len(idxs), self.workers)]
        return ExpertTicket(shards=shards)

    def poll(self, ticket: ExpertTicket,
             block: bool = True) -> Optional[np.ndarray]:
        """Labels when ready, else None (non-blocking poll)."""
        return poll_ticket(ticket, block)

    def poll_partial(self, ticket: ExpertTicket):
        """Non-blocking partial poll: (ready_mask, labels-with--1)."""
        return poll_ticket_partial(ticket)

    def close(self) -> None:
        """Reap the pool threads (long-lived processes that cycle
        through many experts should call this; idempotent)."""
        with self._lock:
            if self._executor is not None:
                self._executor.shutdown(wait=True)
                self._executor = None

    def __del__(self):  # best-effort: don't leak the workers at GC
        try:
            self.close()
        except Exception:
            pass


def train_model_expert(stream: Stream, n_classes: int,
                       d_model: int = 256, n_layers: int = 4,
                       epochs: int = 3, batch: int = 32,
                       lr: float = 1e-3, seed: int = 0,
                       max_samples: Optional[int] = None,
                       cost: float = 1.0e6,
                       workers: Union[int, str] = 1,
                       backend: str = "thread") -> ModelExpert:
    """Train the stand-in LLM on ground truth (offline, before serving)."""
    spec = TinyTFSpec(d_model=d_model, n_layers=n_layers, d_ff=4 * d_model,
                      n_classes=n_classes)
    params = tinytf_init(jax.random.PRNGKey(seed), spec)
    opt = adam(lr)
    state = opt.init(params)
    n = len(stream) if max_samples is None else min(max_samples, len(stream))
    ids = np.stack([hash_ids(stream.docs[i], spec.vocab, spec.max_len)
                    for i in range(n)])
    labels = stream.labels[:n]

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: tinytf_loss(p, xb, yb, spec))(params)
        params, state = opt.step(params, grads, state)
        return params, state, loss

    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - batch + 1, batch):
            sel = order[s:s + batch]
            params, state, _ = step(params, state,
                                    jnp.asarray(ids[sel]),
                                    jnp.asarray(labels[sel]))
    return ModelExpert(params=params, spec=spec, cost=cost, workers=workers,
                       backend=backend)
