"""Per-tick RNG discipline shared by OnlineCascade and BatchedCascadeEngine.

Algorithm 1 consumes randomness at three points per stream item: the
per-level DAgger jump draws, the (optional) sampled deferral actions, and
the per-level cache mini-batch sampling for the student updates.  To make
the sequential reference and the batched engine *provably equivalent on a
1-stream batch*, both derive every draw from keys pre-split per tick:

    SeedSequence((seed, stream_id, t))  ->  spawn one child per purpose

Each purpose gets its own independent child generator, so an engine that
pre-draws vectors (the batched engine draws all jump uniforms at once)
consumes exactly the same values as one that draws lazily inside the level
walk (the reference short-circuits after the exit level).  Unused draws
never shift later ones — there is no shared sequential stream to desync.

``stream_id`` is the lane index: the reference implementation is lane 0,
and lane s of a batched engine uses ``(seed, s, t)``.  Cache sampling is a
per-cascade (not per-lane) purpose; the batched engine draws it from the
lane-0 tick keys, which is what makes its single update per tick coincide
with the reference's per-item update when S == 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class TickRngs:
    """Independent generators for one (stream, tick) pair."""
    jump: np.random.Generator      # DAgger jump uniforms, one per level
    action: np.random.Generator    # sampled-action uniforms, one per level
    cache: List[np.random.Generator]   # per-level cache mini-batch sampling


def tick_rngs(seed: int, stream_id: int, t: int, n_levels: int) -> TickRngs:
    """Pre-split keys for tick ``t`` (1-based) of stream ``stream_id``."""
    ss = np.random.SeedSequence((seed & 0x7FFFFFFF, stream_id, t))
    children = ss.spawn(2 + n_levels)
    return TickRngs(
        jump=np.random.default_rng(children[0]),
        action=np.random.default_rng(children[1]),
        cache=[np.random.default_rng(c) for c in children[2:]],
    )


def generator_state(rng: np.random.Generator) -> dict:
    """JSON-able snapshot of a generator mid-stream (checkpointing).

    The per-tick discipline makes most randomness reconstructible from
    (seed, stream_id, t) alone, but a pending tick's cache generators may
    have consumed draws (a partially committed per-lane record) — their
    exact bit-generator state is what makes a resume-from-checkpoint run
    bitwise identical to the uninterrupted one (checkpoint/ckpt.py)."""
    return rng.bit_generator.state


def generator_from_state(state: dict) -> np.random.Generator:
    """Rebuild a generator from a ``generator_state`` snapshot."""
    bg = getattr(np.random, state["bit_generator"])()
    bg.state = state
    return np.random.Generator(bg)


def sample_cache_indices(rng: np.random.Generator, cache_n: int,
                         batch_size: int) -> np.ndarray:
    """Mini-batch indices over a cache holding ``cache_n`` items.

    With replacement while the cache is filling, without once it can cover
    the batch — the reference FIFO-cache sampling rule, factored out so the
    vectorized ring buffer draws identical indices.
    """
    if cache_n < batch_size:
        return rng.integers(0, cache_n, size=batch_size)
    return rng.choice(cache_n, size=batch_size, replace=False)
