"""Online cascade learning — the paper's Algorithm 1.

A cascade of students (logistic regression, tiny transformer) topped by an
LLM expert, with learned deferral MLPs between levels.  Everything is
updated *online*, per stream item, from expert demonstrations only:

  for x_t in stream:
      for m_i in m_1 .. m_N:
          at probability beta_i:  jump to m_N           (DAgger)
          pred_i = m_i(x_t)
          defer  = f_i(pred_i)                          (learned MLP)
          if m_i is m_N or not defer:
              y_hat = argmax(pred_i); cache x_t if expert labeled; break
      update m_1..m_{N-1} on caches via OGD             (imitation)
      update f_1..f_{N-1} from Eq.(1)/Eq.(5) gradients
      decay beta

Per-level hyperparameters follow the paper's App. B.3 tables: model cost,
cache size, batch size, deferral (MLP) learning rate, decaying factor and
calibration factor.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize as _san
from repro.core.deferral import (
    DeferralSpec, deferral_grads_weighted, deferral_init,
    deferral_prob, deferral_update_terms, reexploration_floor)
from repro.core.rng import sample_cache_indices, tick_rngs
from repro.data.features import hash_bow, hash_ids
from repro.models.kernel_students import (
    SSMStudentSpec, TinyTFFlashSpec, ssm_student_init,
    ssm_student_loss_weighted, ssm_student_predict, tinytf_flash_init,
    tinytf_flash_loss_weighted, tinytf_flash_predict)
from repro.models.students import (
    LRSpec, MLPSpec, TinyTFSpec, lr_init, lr_loss_weighted, lr_predict,
    mlp_init, mlp_loss_weighted, mlp_predict,
    tinytf_init, tinytf_loss_weighted, tinytf_predict)
from repro.optim import adam, ogd_sqrt_t


@dataclass(frozen=True)
class LevelSpec:
    """Per-level hyperparameters (paper App. B.3 Tables 3/4 columns)."""

    kind: str                     # 'lr' | 'mlp' | 'tinytf' | 'tinytf_large'
                                  # | 'tinytf_flash' | 'ssm' (kernel path;
                                  # docs/MODELS.md has the level zoo)
    cost: float                   # c_i (model cost units, LR = 1)
    cache_size: int = 8
    batch_size: int = 8
    student_lr: float = 0.5       # OGD eta0 (lr) / adam lr (tinytf)
    deferral_lr: float = 7e-4     # paper Tables 3/4 "Learning Rate"
    beta_decay: float = 0.97      # paper "Decaying Factor"
    beta_floor: float = 0.05      # re-exploration floor0 (core.deferral);
                                  # 0 disables the trickle
    calibration_factor: float = 0.4


@dataclass(frozen=True)
class CascadeConfig:
    """Whole-cascade configuration: levels, cost model, and RNG seed."""

    levels: Tuple[LevelSpec, ...]
    n_classes: int
    expert_cost: float            # c_N in model cost units
    mu: float = 2e-6              # cost weighting factor (user budget knob)
    beta0: float = 1.0            # initial DAgger jump probability
    n_features: int = 2048        # hashed BoW dim for LR / MLP
    tf_spec: Optional[TinyTFSpec] = None
    mlp_spec: Optional[MLPSpec] = None
    tf_flash_spec: Optional[TinyTFFlashSpec] = None
    ssm_spec: Optional[SSMStudentSpec] = None
    sample_actions: bool = False  # paper samples action_i ~ f_i; default
                                  # thresholded at 0.5 (§3 calibration)
    hard_budget: Optional[int] = None  # max expert calls (None = mu-driven)
    seed: int = 0


def default_cascade_config(n_classes: int, mu: float = 2e-6,
                           expert_cost: float = 1.0e6,
                           beta0: float = 1.0,
                           large: bool = False,
                           seed: int = 0) -> CascadeConfig:
    """The paper's small cascade (LR -> BERT-ish -> LLM); ``large=True``
    adds a second, bigger transformer level (the BERT-large analogue)."""
    levels = [
        LevelSpec(kind="lr", cost=1.0, cache_size=8, batch_size=8,
                  student_lr=0.5, beta_decay=0.97, calibration_factor=0.4),
        LevelSpec(kind="tinytf", cost=550.0, cache_size=16, batch_size=8,
                  student_lr=1e-3, beta_decay=0.95, calibration_factor=0.3),
    ]
    tf_spec = TinyTFSpec(n_classes=n_classes)
    if large:
        levels.append(LevelSpec(kind="tinytf_large", cost=2200.0,
                                cache_size=32, batch_size=16,
                                student_lr=7e-4, beta_decay=0.95,
                                calibration_factor=0.4))
    return CascadeConfig(levels=tuple(levels), n_classes=n_classes,
                         expert_cost=expert_cost, mu=mu, beta0=beta0,
                         tf_spec=tf_spec, seed=seed)


def kernel_cascade_config(n_classes: int, mu: float = 2e-6,
                          expert_cost: float = 1.0e6,
                          beta0: float = 1.0, seed: int = 0,
                          tf_flash_spec: Optional[TinyTFFlashSpec] = None,
                          ssm_spec: Optional[SSMStudentSpec] = None
                          ) -> CascadeConfig:
    """The kernel-path ladder: LR -> tinytf_flash -> ssm (-> expert).

    Both upper levels route their batched forwards through the Pallas
    kernels (flash/decode attention, SSD scan — models/kernel_students),
    and their c_i deferral penalties are recomputed from the analytic
    FLOP model (metrics.costs) so cost ordering stays honest when specs
    are overridden.  ``serve.py --ladder kernel`` serves this config."""
    from dataclasses import replace

    from repro.metrics.costs import (
        lr_flops, ssm_student_flops, tinytf_flash_flops)
    tf_spec = replace(tf_flash_spec or TinyTFFlashSpec(),
                      n_classes=n_classes)
    ssm_sp = replace(ssm_spec or SSMStudentSpec(), n_classes=n_classes)
    base = lr_flops(LRSpec(n_classes=n_classes))
    cost_tf = tinytf_flash_flops(tf_spec) / base
    cost_ssm = ssm_student_flops(ssm_sp) / base
    levels = (
        LevelSpec(kind="lr", cost=1.0, cache_size=8, batch_size=8,
                  student_lr=0.5, beta_decay=0.97, calibration_factor=0.4),
        LevelSpec(kind="tinytf_flash", cost=cost_tf, cache_size=16,
                  batch_size=8, student_lr=1e-3, beta_decay=0.95,
                  calibration_factor=0.3),
        LevelSpec(kind="ssm", cost=cost_ssm, cache_size=32, batch_size=16,
                  student_lr=7e-4, beta_decay=0.95,
                  calibration_factor=0.4),
    )
    return CascadeConfig(levels=levels, n_classes=n_classes,
                         expert_cost=expert_cost, mu=mu, beta0=beta0,
                         tf_flash_spec=tf_spec, ssm_spec=ssm_sp, seed=seed)


# The four per-level state trees that define a cascade's learned state.
# Every parity contract in tests/ (and the shared tests/harness.py) compares
# engines leaf-by-leaf over exactly these attributes of each _Level.
STATE_ATTRS = ("params", "opt_state", "dparams", "dopt_state")

_HISTORY_KEYS = ("level", "pred", "expert_called", "cost", "J")


def make_history(limit: Optional[int]) -> Optional[Dict[str, list]]:
    """Per-item diagnostic buffers for a serving engine.

    ``None`` keeps full unbounded lists (analysis/benchmark runs);
    ``k > 0`` keeps the most recent k entries (deque, O(k) memory on
    multi-million-item streams); ``0`` disables history entirely (the
    production serving loops — aggregates in ``level_counts`` etc. are
    unaffected)."""
    if limit is None:
        return {k: [] for k in _HISTORY_KEYS}
    if limit < 0:
        raise ValueError(f"history_limit must be >= 0 or None, got {limit}")
    if limit == 0:
        return None
    return {k: deque(maxlen=limit) for k in _HISTORY_KEYS}


class _Level:
    """Runtime state for one cascade level (student + deferral + cache)."""

    def __init__(self, spec: LevelSpec, cfg: CascadeConfig, key,
                 defer_cost: Optional[float] = None):
        self.spec = spec
        self.cfg = cfg
        # mu * c_{i+1}: the penalty this level pays per deferral (Eq. 1).
        self.mu_defer_cost = cfg.mu * (cfg.expert_cost if defer_cost is None
                                       else defer_cost)
        k1, k2 = jax.random.split(key)
        C = cfg.n_classes
        if spec.kind == "lr":
            self.sspec = LRSpec(n_features=cfg.n_features, n_classes=C)
            self.params = lr_init(k1, self.sspec)
            self.opt = ogd_sqrt_t(spec.student_lr)
            feat_shape = (cfg.n_features,)
            feat_dtype = np.float32
        elif spec.kind == "mlp":
            from dataclasses import replace
            base = cfg.mlp_spec or MLPSpec()
            self.sspec = replace(base, n_features=cfg.n_features,
                                 n_classes=C)
            self.params = mlp_init(k1, self.sspec)
            self.opt = adam(spec.student_lr)
            feat_shape = (cfg.n_features,)
            feat_dtype = np.float32
        elif spec.kind == "tinytf_flash":
            from dataclasses import replace
            base = cfg.tf_flash_spec or TinyTFFlashSpec()
            self.sspec = replace(base, n_classes=C)
            self.params = tinytf_flash_init(k1, self.sspec)
            self.opt = adam(spec.student_lr)
            feat_shape = (self.sspec.max_len,)
            feat_dtype = np.int32
        elif spec.kind == "ssm":
            from dataclasses import replace
            base = cfg.ssm_spec or SSMStudentSpec()
            self.sspec = replace(base, n_classes=C)
            self.params = ssm_student_init(k1, self.sspec)
            self.opt = adam(spec.student_lr)
            feat_shape = (self.sspec.max_len,)
            feat_dtype = np.int32
        else:
            base = cfg.tf_spec or TinyTFSpec(n_classes=C)
            if spec.kind == "tinytf_large":
                from dataclasses import replace
                base = replace(base, d_model=base.d_model * 2,
                               n_layers=base.n_layers + 2,
                               d_ff=base.d_ff * 2)
            from dataclasses import replace
            self.sspec = replace(base, n_classes=C)
            self.params = tinytf_init(k1, self.sspec)
            self.opt = adam(spec.student_lr)
            feat_shape = (self.sspec.max_len,)
            feat_dtype = np.int32
        self.opt_state = self.opt.init(self.params)

        self.dspec = DeferralSpec(n_classes=C)
        self.dparams = deferral_init(k2, self.dspec)
        # The deferral MLP uses Adam at the paper's per-level learning rate
        # (App. B.3 "Learning Rate" column): with raw OGD at 7e-4/sqrt(t)
        # the +2.0 open-gate init logit cannot move within a stream's
        # lifetime.  Adam's scale-invariant steps preserve the no-regret
        # OGD analysis in practice (Li & Orabona 2019, cited by the paper).
        self.dopt = adam(spec.deferral_lr * 20)
        self.dopt_state = self.dopt.init(self.dparams)

        self.beta = cfg.beta0
        # FIFO cache D of expert-labeled items
        self.cache_x = np.zeros((spec.cache_size,) + feat_shape, feat_dtype)
        self.cache_y = np.zeros((spec.cache_size,), np.int32)
        self.cache_n = 0
        self.cache_ptr = 0
        # immutable initial state, for reset() (jax arrays are immutable,
        # so keeping the references is enough)
        self._init_state = (self.params, self.opt_state,
                            self.dparams, self.dopt_state)
        self._build_jits()

    def reset(self):
        """Restore the freshly-initialized state, keeping compiled jits —
        lets a warmed engine be reused across streams (and benchmarks
        measure the algorithm, not XLA compilation)."""
        (self.params, self.opt_state,
         self.dparams, self.dopt_state) = self._init_state
        self.beta = self.cfg.beta0
        self.cache_x[:] = 0
        self.cache_y[:] = 0
        self.cache_n = 0
        self.cache_ptr = 0

    # -- checkpointing (checkpoint/ckpt.py via the engines' save_state) --
    def state_tree(self) -> dict:
        """The level's learned state as one checkpointable pytree
        (STATE_ATTRS order: student params + opt state, deferral MLP
        params + opt state)."""
        return {a: getattr(self, a) for a in STATE_ATTRS}

    def load_state_tree(self, tree: dict, put=None) -> None:
        """Install a ``state_tree`` snapshot.  The restored containers
        are rebuilt against the CURRENT attribute's treedef (optimizer
        states may be tuples/namedtuples, which the npz round-trip
        stores as lists); ``put`` re-places leaves on device (mesh
        engines pass their replicated placement)."""
        put = jnp.asarray if put is None else put
        for a in STATE_ATTRS:
            cur = getattr(self, a)
            leaves = jax.tree_util.tree_leaves(tree[a])
            treedef = jax.tree_util.tree_structure(cur)
            setattr(self, a, jax.tree_util.tree_unflatten(
                treedef, [put(np.asarray(x)) for x in leaves]))

    def _build_jits(self):
        spec, sspec, opt, dopt = self.spec, self.sspec, self.opt, self.dopt

        if self.spec.kind == "lr":
            def predict(params, x):
                return lr_predict(params, x[None])[0]

            def student_loss(p, xb, yb, w):
                return lr_loss_weighted(p, xb, yb, w)
        elif self.spec.kind == "mlp":
            def predict(params, x):
                return mlp_predict(params, x[None])[0]

            def student_loss(p, xb, yb, w):
                return mlp_loss_weighted(p, xb, yb, w)
        elif self.spec.kind == "tinytf_flash":
            # kernel-path predict (flash + decode attention), ref-path
            # loss (pallas_call has no VJP; the paths are tolerance-
            # pinned equal — models/kernel_students, docs/MODELS.md)
            def predict(params, x):
                return tinytf_flash_predict(params, x[None], sspec)[0]

            def student_loss(p, xb, yb, w):
                return tinytf_flash_loss_weighted(p, xb, yb, w, sspec)
        elif self.spec.kind == "ssm":
            def predict(params, x):
                return ssm_student_predict(params, x[None], sspec)[0]

            def student_loss(p, xb, yb, w):
                return ssm_student_loss_weighted(p, xb, yb, w, sspec)
        else:
            def predict(params, x):
                return tinytf_predict(params, x[None], sspec)[0]

            def student_loss(p, xb, yb, w):
                return tinytf_loss_weighted(p, xb, yb, w, sspec)

        def student_step(params, opt_state, xb, yb, w):
            grads = jax.grad(student_loss)(params, xb, yb, w)
            return opt.step(params, grads, opt_state)

        def student_step_k(params, opt_state, xb, yb, w, k):
            """One lr-scaled step standing in for k per-item steps (the
            batched engine's updates_per_tick="scaled" mode)."""
            grads = jax.grad(student_loss)(params, xb, yb, w)
            return opt.step_k(params, grads, opt_state, k)

        cf = spec.calibration_factor
        mu_dc = self.mu_defer_cost

        def deferral_step(dparams, dstate, probs, y, reach, w):
            """probs: (B, C); y: (B,) expert labels; reach, w: (B,).
            z and mu*c - L are derived in-graph (deferral_update_terms) so
            the batched engine's weighted update is bit-identical."""
            z, mcl = deferral_update_terms(probs, y, mu_dc)
            grads = deferral_grads_weighted(dparams, probs, z, reach, mcl,
                                            w, cf)
            return dopt.step(dparams, grads, dstate)

        def deferral_step_k(dparams, dstate, probs, y, reach, w, k):
            z, mcl = deferral_update_terms(probs, y, mu_dc)
            grads = deferral_grads_weighted(dparams, probs, z, reach, mcl,
                                            w, cf)
            return dopt.step_k(dparams, grads, dstate, k)

        if spec.kind == "lr":
            self._predict_batch = lambda p, xb: lr_predict(p, xb)
        elif spec.kind == "mlp":
            self._predict_batch = lambda p, xb: mlp_predict(p, xb)
        elif spec.kind == "tinytf_flash":
            self._predict_batch = \
                lambda p, xb: tinytf_flash_predict(p, xb, sspec)
        elif spec.kind == "ssm":
            self._predict_batch = \
                lambda p, xb: ssm_student_predict(p, xb, sspec)
        else:
            self._predict_batch = lambda p, xb: tinytf_predict(p, xb, sspec)

        # Route pass, split for pipelining (core/batched.py): the body is
        # exposed unjitted so the batched engines can jit it with their
        # own placement/donation annotations (sharding.jit_route_pass),
        # DISPATCH it asynchronously against a tick's gathered lane
        # subset, and only later block on the handles — ``np.asarray`` on
        # the returned pair is the sole device->host sync point of a
        # route pass.  At a (1, ...) batch this is the reference's
        # ``predict_and_defer`` computation exactly.
        predict_batch = self._predict_batch

        def route_pass(params, dparams, xb):
            probs = predict_batch(params, xb)
            return probs, deferral_prob(dparams, probs)

        self.route_pass = route_pass

        def predict_and_defer(params, dparams, x):
            probs = predict(params, x)
            return probs, deferral_prob(dparams, probs[None])[0]

        # every staged function goes through the retrace-sanitizer probe
        # (a no-op returning the function unchanged unless the retrace
        # sanitizer was enabled before the level was built); counters are
        # keyed by student kind + step name, so levels sharing a kind
        # aggregate into one counter
        probe = _san.trace_probe
        kind = spec.kind
        self._predict = jax.jit(probe(f"{kind}.predict", predict))
        self._predict_and_defer = jax.jit(
            probe(f"{kind}.predict_and_defer", predict_and_defer))
        self._student_step = jax.jit(
            probe(f"{kind}.student_step", student_step))
        self._student_step_k = jax.jit(
            probe(f"{kind}.student_step_k", student_step_k))
        self._deferral_step = jax.jit(
            probe(f"{kind}.deferral_step", deferral_step))
        self._deferral_step_k = jax.jit(
            probe(f"{kind}.deferral_step_k", deferral_step_k))
        self._dprob = jax.jit(probe(
            f"{kind}.dprob",
            lambda dp, probs: deferral_prob(dp, probs[None])[0]))

    # -- cache ---------------------------------------------------------
    def cache_add(self, x: np.ndarray, y: int):
        """FIFO-insert one expert demonstration into the level's cache."""
        self.cache_x[self.cache_ptr] = x
        self.cache_y[self.cache_ptr] = y
        self.cache_ptr = (self.cache_ptr + 1) % self.spec.cache_size
        self.cache_n = min(self.cache_n + 1, self.spec.cache_size)

    def student_update(self, rng: np.random.Generator):
        """One imitation step on a cache mini-batch drawn from ``rng``."""
        if self.cache_n == 0:
            return
        bs = min(self.spec.batch_size, self.spec.cache_size)
        idx = sample_cache_indices(rng, self.cache_n, bs)
        xb = jnp.asarray(self.cache_x[idx])
        yb = jnp.asarray(self.cache_y[idx])
        w = jnp.ones((bs,), jnp.float32)
        self.apply_student_update(xb, yb, w)

    # -- shared update application (both engines commit through these, so
    #    the route/commit split of the async batched engine and the inline
    #    sequential walk evolve state through identical compiled steps) ---
    def apply_student_update(self, xb, yb, w, k=None):
        """One weighted imitation step; ``k`` (a float32 scalar) selects
        the lr-scaled variant standing in for k per-item steps."""
        if k is None:
            self.params, self.opt_state = self._student_step(
                self.params, self.opt_state, xb, yb, w)
        else:
            self.params, self.opt_state = self._student_step_k(
                self.params, self.opt_state, xb, yb, w, k)

    def apply_deferral_update(self, probs, y, reach, w, k=None):
        """One weighted deferral-gate step from Eq. (1)/Eq. (5) terms."""
        if k is None:
            self.dparams, self.dopt_state = self._deferral_step(
                self.dparams, self.dopt_state, probs, y, reach, w)
        else:
            self.dparams, self.dopt_state = self._deferral_step_k(
                self.dparams, self.dopt_state, probs, y, reach, w, k)

    def featurize(self, doc: np.ndarray) -> np.ndarray:
        """Map a raw doc to this level's input (hashed BoW or token ids)."""
        if self.spec.kind in ("lr", "mlp"):
            return hash_bow(doc, self.cfg.n_features)
        return hash_ids(doc, self.sspec.vocab, self.sspec.max_len)


class OnlineCascade:
    """Algorithm 1 driver.  ``process(idx, doc)`` handles one stream item."""

    def __init__(self, config: CascadeConfig, expert,
                 history_limit: Optional[int] = None):
        self.cfg = config
        self.expert = expert
        keys = jax.random.split(jax.random.PRNGKey(config.seed),
                                len(config.levels))
        self.levels: List[_Level] = [
            _Level(spec, config, k,
                   defer_cost=(config.levels[i + 1].cost
                               if i + 1 < len(config.levels)
                               else config.expert_cost))
            for i, (spec, k) in enumerate(zip(config.levels, keys))]
        # Lane id in the shared per-tick RNG discipline (core.rng): the
        # sequential reference is lane 0 of a batched engine.
        self.stream_id = 0
        self.t = 0
        # accounting
        self.expert_calls = 0
        self.total_cost = 0.0
        self.level_counts = np.zeros(len(config.levels) + 1, np.int64)
        self.J_cum = 0.0
        self.history = make_history(history_limit)

    def reset(self):
        """Back to item 0 of a fresh stream; compiled jits are kept."""
        for lvl in self.levels:
            lvl.reset()
        self.t = 0
        self.expert_calls = 0
        self.total_cost = 0.0
        self.level_counts[:] = 0
        self.J_cum = 0.0
        if self.history is not None:
            for v in self.history.values():
                v.clear()
        # a recorded determinism-sanitizer trace belongs to the old
        # stream too — a reused engine starts a fresh, comparable trace
        _san.drop_trace(self)

    def close(self) -> None:
        """Shut down the expert's worker pool, if it has one."""
        close = getattr(self.expert, "close", None)
        if close is not None:
            close()

    # -- live-state checkpointing (mirrors BatchedCascadeEngine's) ------
    def _fingerprint(self) -> dict:
        return {"engine": "sequential", "n_levels": len(self.levels),
                "seed": self.cfg.seed, "n_classes": self.cfg.n_classes}

    def save_state(self, path: str) -> str:
        """Checkpoint learned + accounting state mid-stream.  The
        sequential loop has no in-flight queue, so the snapshot is just
        levels (STATE_ATTRS + beta + FIFO cache) and scalars; resuming
        at item ``t`` replays the uninterrupted run bitwise (the
        per-item RNG is a pure function of (seed, stream_id, t))."""
        from repro.checkpoint import save_checkpoint
        tree = {
            "levels": [lvl.state_tree() for lvl in self.levels],
            "cache_x": [lvl.cache_x.copy() for lvl in self.levels],
            "cache_y": [lvl.cache_y.copy() for lvl in self.levels],
            "level_counts": self.level_counts,
        }
        meta = {
            **self._fingerprint(),
            "t": self.t, "stream_id": self.stream_id,
            "beta": [float(lvl.beta) for lvl in self.levels],
            "cache_n": [lvl.cache_n for lvl in self.levels],
            "cache_ptr": [lvl.cache_ptr for lvl in self.levels],
            "expert_calls": self.expert_calls,
            "total_cost": self.total_cost,
            "J_cum": self.J_cum,
        }
        return save_checkpoint(path, tree, meta)

    def restore_state(self, path: str) -> None:
        """Restore a ``save_state`` checkpoint into this (same-config)
        cascade; raises ``CheckpointError`` on a config mismatch."""
        from repro.checkpoint import CheckpointError, restore_checkpoint
        tree, meta = restore_checkpoint(path)
        for key, val in self._fingerprint().items():
            if meta.get(key) != val:
                raise CheckpointError(
                    f"checkpoint/engine mismatch on {key}: checkpoint "
                    f"has {meta.get(key)!r}, engine has {val!r}")
        for i, lvl in enumerate(self.levels):
            lvl.load_state_tree(tree["levels"][i])
            lvl.beta = float(meta["beta"][i])
            lvl.cache_x[:] = np.asarray(tree["cache_x"][i])
            lvl.cache_y[:] = np.asarray(tree["cache_y"][i])
            lvl.cache_n = int(meta["cache_n"][i])
            lvl.cache_ptr = int(meta["cache_ptr"][i])
        self.level_counts[:] = np.asarray(tree["level_counts"])
        self.t = int(meta["t"])
        self.stream_id = int(meta["stream_id"])
        self.expert_calls = int(meta["expert_calls"])
        self.total_cost = float(meta["total_cost"])
        self.J_cum = float(meta["J_cum"])

    # -- cost of deferring FROM level i (to i+1) -----------------------
    def _defer_cost(self, i: int) -> float:
        if i + 1 < len(self.levels):
            return self.levels[i + 1].spec.cost
        return self.cfg.expert_cost

    def _budget_exhausted(self) -> bool:
        hb = self.cfg.hard_budget
        return hb is not None and self.expert_calls >= hb

    def process(self, idx: int, doc: np.ndarray) -> dict:
        """Run one episode of the MDP; returns prediction + diagnostics."""
        cfg = self.cfg
        self.t += 1
        n_levels = len(self.levels)
        rngs = tick_rngs(cfg.seed, self.stream_id, self.t, n_levels)
        u_jump = rngs.jump.random(n_levels)
        # the action draws also feed the determinism-sanitizer trace (the
        # batched engine always draws them); the extra draw consumes only
        # the tick's throwaway `action` generator, never jump/cache state
        u_act = (rngs.action.random(n_levels)
                 if cfg.sample_actions or _san.determinism_on() else None)
        feat_cache: Dict[int, np.ndarray] = {}

        def feat(i):
            if i not in feat_cache:
                feat_cache[i] = self.levels[i].featurize(doc)
            return feat_cache[i]

        probs_list, dprob_list = [], []
        prediction = None
        chosen_level = None
        expert_called = False
        episode_cost_units = 0.0

        for i, lvl in enumerate(self.levels):
            # DAgger jump: at probability beta_i, query the expert directly.
            if not self._budget_exhausted() and u_jump[i] < lvl.beta:
                chosen_level = len(self.levels)
                expert_called = True
                break
            x = feat(i)
            probs_j, dprob_j = lvl._predict_and_defer(
                lvl.params, lvl.dparams, jnp.asarray(x))
            probs = np.asarray(probs_j)
            dprob = float(dprob_j)
            probs_list.append(probs)
            dprob_list.append(dprob)
            episode_cost_units += lvl.spec.cost
            if cfg.sample_actions:
                # compare at float32 like the batched engine's in-graph
                # sampling; both operands are exact in either precision
                defer = float(np.float32(u_act[i])) < dprob
            else:
                defer = dprob > 0.5
            if self._budget_exhausted() and i == len(self.levels) - 1:
                defer = False          # budget gate: cannot reach expert
            if not defer:
                prediction = int(np.argmax(probs))
                chosen_level = i
                break
        else:
            chosen_level = len(self.levels)
            expert_called = True

        if expert_called and self._budget_exhausted():
            # fall back to the last student instead of the expert; the
            # fallback forward is real compute and is costed like any
            # other evaluation of that level (the batched engine's
            # overflow path costs it identically — S=1 parity)
            lvl = self.levels[-1]
            x = feat(len(self.levels) - 1)
            probs = np.asarray(lvl._predict(lvl.params, jnp.asarray(x)))
            prediction = int(np.argmax(probs))
            chosen_level = len(self.levels) - 1
            expert_called = False
            episode_cost_units += lvl.spec.cost

        y_expert = None
        if expert_called:
            y_expert = self.expert.label(idx, doc)
            prediction = y_expert
            self.expert_calls += 1
            episode_cost_units += self.cfg.expert_cost
            # every annotated item calibrates EVERY gate (core.deferral):
            # levels the walk never consulted (DAgger jumps short-circuit
            # before the predict) get their probs/dprob computed here,
            # against the pre-update student — a training-side forward,
            # not costed as serving compute
            for i in range(len(probs_list), n_levels):
                lvl = self.levels[i]
                probs_j, dprob_j = lvl._predict_and_defer(
                    lvl.params, lvl.dparams, jnp.asarray(feat(i)))
                probs_list.append(np.asarray(probs_j))
                dprob_list.append(float(dprob_j))
            # aggregate demonstration into every level's cache
            for i, lvl in enumerate(self.levels):
                lvl.cache_add(feat(i), y_expert)
            # imitation updates (OGD on cached demonstrations)
            for i, lvl in enumerate(self.levels):
                lvl.student_update(rngs.cache[i])
            # deferral updates from Eq. (1) + Eq. (5), only when the
            # expert annotation is available (paper §3); z and mu*c - L
            # are computed inside the jitted step (float32, shared with
            # the batched engine)
            y_arr = jnp.asarray([y_expert], jnp.int32)
            w_one = jnp.ones((1,), jnp.float32)
            reach = np.float32(1.0)
            for i, (lvl, probs, dp) in enumerate(
                    zip(self.levels, probs_list, dprob_list)):
                lvl.apply_deferral_update(
                    jnp.asarray(probs)[None], y_arr,
                    jnp.asarray([reach], jnp.float32), w_one)
                reach = np.float32(reach * np.float32(dp))

        # J(pi, t) bookkeeping (Eq. 1): use observed branch costs
        J_t = cfg.mu * episode_cost_units
        self.J_cum += J_t

        # decay beta (per level), floored by the re-exploration schedule
        for lvl in self.levels:
            lvl.beta = max(lvl.beta * lvl.spec.beta_decay,
                           reexploration_floor(lvl.spec.beta_floor, self.t))

        self.total_cost += episode_cost_units
        self.level_counts[chosen_level if not expert_called
                          else len(self.levels)] += 1
        if self.history is not None:
            self.history["level"].append(
                len(self.levels) if expert_called else chosen_level)
            self.history["pred"].append(prediction)
            self.history["expert_called"].append(expert_called)
            self.history["cost"].append(episode_cost_units)
            self.history["J"].append(J_t)
        if _san.determinism_on():
            # one 1-lane record per item: the sequential reference is
            # lane 0 of a batched engine, and its trace aligns with a
            # batched n_streams=1 trace tick-for-tick
            _san.record_tick(
                self, t=self.t,
                level=[len(self.levels) if expert_called
                       else chosen_level],
                called=[expert_called], pred=[prediction],
                u_jump=u_jump.reshape(n_levels, 1),
                u_act=u_act.reshape(n_levels, 1),
                cache_n=[lvl.cache_n for lvl in self.levels],
                cache_ptr=[lvl.cache_ptr for lvl in self.levels],
                levels=self.levels)
        return {
            "prediction": prediction,
            "level": chosen_level,
            "expert_called": expert_called,
            "cost_units": episode_cost_units,
            "expert_label": y_expert,
        }

    # -- conveniences ---------------------------------------------------
    def run(self, stream, log_every: int = 0) -> dict:
        """Process an entire stream; returns summary metrics."""
        preds = np.zeros(len(stream), np.int32)
        for i, doc in enumerate(stream.docs):
            out = self.process(i, doc)
            preds[i] = out["prediction"]
            if log_every and (i + 1) % log_every == 0:
                acc = float(np.mean(preds[:i + 1] == stream.labels[:i + 1]))
                print(f"[{i+1}/{len(stream)}] acc={acc:.4f} "
                      f"expert_calls={self.expert_calls}")
        labels = stream.labels
        acc = float(np.mean(preds == labels))
        metrics = {"accuracy": acc, "expert_calls": self.expert_calls,
                   "total_cost_units": self.total_cost,
                   "level_fractions": (self.level_counts
                                       / max(len(stream), 1)).tolist(),
                   "predictions": preds}
        if stream.spec.n_classes == 2:
            pos = labels == 1
            tp = float(np.sum((preds == 1) & pos))
            metrics["recall"] = tp / max(float(np.sum(pos)), 1.0)
            pp = float(np.sum(preds == 1))
            metrics["precision"] = tp / max(pp, 1.0)
            metrics["f1"] = (2 * metrics["precision"] * metrics["recall"]
                             / max(metrics["precision"] + metrics["recall"],
                                   1e-9))
        return metrics
