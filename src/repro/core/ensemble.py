"""Online ensemble learning — the paper's ablation baseline (§4).

All models run as a linear ensemble with *input-independent* operating
probabilities w_i (learned online, but no per-input deferral policy).
Students are continuously updated from LLM annotations, exactly as in the
cascade; the expert is consulted at a decaying probability (the annotation
budget knob).  This isolates the value of the learned deferral policy.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeConfig, _Level


class OnlineEnsemble:
    """Paper §4 baseline: weighted-majority ensemble, no cascade."""

    def __init__(self, config: CascadeConfig, expert,
                 expert_prob_decay: float = 0.9995,
                 min_expert_prob: float = 0.0):
        self.cfg = config
        self.expert = expert
        keys = jax.random.split(jax.random.PRNGKey(config.seed),
                                len(config.levels))
        self.levels = [_Level(spec, config, k)
                       for spec, k in zip(config.levels, keys)]
        self.rng = np.random.default_rng(config.seed + 2)
        self.theta = np.zeros(len(self.levels), np.float32)
        self.expert_prob = 1.0
        self.decay = expert_prob_decay
        self.min_expert_prob = min_expert_prob
        self.expert_calls = 0
        self.total_cost = 0.0
        self.t = 0

        def theta_grad(theta, probs_stack, y):
            w = jax.nn.softmax(theta)
            mix = jnp.einsum("i,ic->c", w, probs_stack)
            return -jnp.log(jnp.maximum(mix[y], 1e-9))

        self._theta_grad = jax.jit(jax.grad(theta_grad))

    def _budget_left(self, hard_budget: Optional[int]) -> bool:
        return hard_budget is None or self.expert_calls < hard_budget

    def process(self, idx: int, doc: np.ndarray,
                hard_budget: Optional[int] = None) -> dict:
        """Serve one item: expert w.p. p_t, else weighted majority."""
        self.t += 1
        feats = [lvl.featurize(doc) for lvl in self.levels]
        probs = np.stack([
            np.asarray(lvl._predict(lvl.params, jnp.asarray(x)))
            for lvl, x in zip(self.levels, feats)])
        w = np.asarray(jax.nn.softmax(jnp.asarray(self.theta)))
        mix = w @ probs
        # every ensemble member runs on every input (no deferral)
        cost = sum(lvl.spec.cost for lvl in self.levels)
        expert_called = (self.rng.random() < self.expert_prob
                         and self._budget_left(hard_budget))
        if expert_called:
            y = self.expert.label(idx, doc)
            prediction = y
            self.expert_calls += 1
            cost += self.cfg.expert_cost
            for lvl, x in zip(self.levels, feats):
                lvl.cache_add(x, y)
                lvl.student_update(self.rng)
            g = np.asarray(self._theta_grad(
                jnp.asarray(self.theta), jnp.asarray(probs), y))
            eta = 0.5 / np.sqrt(self.t)
            self.theta = self.theta - eta * g
        else:
            prediction = int(np.argmax(mix))
        self.expert_prob = max(self.expert_prob * self.decay,
                               self.min_expert_prob)
        self.total_cost += cost
        return {"prediction": prediction, "expert_called": expert_called}

    def run(self, stream, hard_budget: Optional[int] = None) -> dict:
        """Serve a whole stream; returns accuracy + expert-call count."""
        preds = np.zeros(len(stream), np.int32)
        for i, doc in enumerate(stream.docs):
            preds[i] = self.process(i, doc, hard_budget)["prediction"]
        labels = stream.labels
        acc = float(np.mean(preds == labels))
        out = {"accuracy": acc, "expert_calls": self.expert_calls,
               "total_cost_units": self.total_cost, "predictions": preds}
        if stream.spec.n_classes == 2:
            pos = labels == 1
            tp = float(np.sum((preds == 1) & pos))
            out["recall"] = tp / max(float(np.sum(pos)), 1.0)
        return out
