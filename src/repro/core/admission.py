"""Continuous-batching admission over the batched cascade engine.

The paper's setting is a *stream*: queries arrive over time, each with
its own length, and the cascade answers them as they come.  The engines
of PRs 1-8 serve a fixed lockstep batch — S lanes that all start at
tick 0 and end together — which models a benchmark, not traffic.  This
module adds the serving front-end: requests arrive on a seeded schedule
(data/streams.py ``Request``), claim a free lane from the engine's
fixed-capacity lane pool, run to completion at their own pace, and
retire, recycling the lane for the next arrival.  Shapes stay static —
occupancy is expressed through the engine's existing partial-tick
masking (``lanes=`` names which physical lanes a tick's positions
occupy), so lane recycling never recompiles anything.

The lane lifecycle, one tick of ``step()``:

1. **retire** — streams whose last item routed on an earlier tick free
   their lanes (a lane serves its stream's final item at tick u and is
   reusable from tick u+1);
2. **admit** — queued requests claim free lanes, FCFS in arrival order,
   lowest free lane first.  Admission depends only on the schedule and
   the lane budget — never on engine outputs — so the admission log is
   deterministic across workers, pipeline depth, delay and mesh;
3. **serve** — the occupied lanes' next items form the tick, submitted
   with ``lanes=`` (physical lanes), ``stream_ids=`` (each stream's own
   rid) and ``stream_ticks=`` (each stream's own 1-based item counter).
   The RNG rekeying is the bitwise heart of the design: stream r's j-th
   item draws ``tick_rngs(seed, r, j)`` no matter which lane or global
   tick serves it, so its per-item randomness is exactly what a
   dedicated lane (or the sequential reference with ``stream_id = r``)
   would have drawn;
4. **idle** — a tick with arrivals pending but no occupants still calls
   the engine with an EMPTY tick, which advances the engine clock and
   the D-tick commit deadlines: one clock covers busy and idle time, so
   the async queue's bounded-delay contract is unchanged by admission.

Overload policy: ``admission="queue"`` queues arrivals without bound;
``admission="shed"`` drops an arrival (recorded, never served) when
every lane is busy or spoken for and the wait queue already holds
``queue_limit`` requests.

Under online learning, co-scheduled streams still share the cascade —
that is the paper's point — so a staggered run matches its dedicated
lane run only in the draws, not the params.  The frozen regime
(``hard_budget=0``: no jumps, no expert calls, no updates) removes the
coupling, and there the per-stream trajectory is bitwise the sequential
reference's (tests/test_admission.py pins both this and the lockstep
all-at-t=0 schedule, which is bitwise the classic run even while
learning).
"""
from __future__ import annotations

import json
import time
from bisect import bisect_right
from collections import deque
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass
class StreamRecord:
    """Per-stream serving record (admit tick, answers, time-to-answer).

    Ticks are engine ticks (1-based; idle ticks count).  ``commit_ticks``
    are the engine ticks this stream's expert annotations committed at,
    recovered from the engine's ``commit_log`` through the lane-occupancy
    history."""
    rid: int
    arrival: int                  # tick the request became admissible
    n_items: int
    admit: int = -1               # tick of first served item (-1: never)
    lane: int = -1                # physical lane served on (-1: never)
    done: int = -1                # tick the final item routed
    retired: int = -1             # tick the lane was freed again
    shed: bool = False
    items_done: int = 0           # outputs consumed so far
    expert_calls: int = 0
    cost_units: float = 0.0
    predictions: List[int] = field(default_factory=list)
    levels: List[int] = field(default_factory=list)
    commit_ticks: List[int] = field(default_factory=list)
    arrival_wall: float = 0.0     # load-harness wall clocks (0 = unset)
    answer_wall: float = 0.0

    @property
    def answered(self) -> bool:
        return self.items_done == self.n_items and self.n_items > 0

    def time_to_answer(self) -> int:
        """Ticks from (effective) arrival to the final item's route,
        inclusive; -1 while unanswered.  Queueing delay included."""
        if self.done < 0:
            return -1
        return self.done - max(self.arrival, 1) + 1

    def queue_delay(self) -> int:
        """Ticks spent waiting for a lane; -1 if never admitted."""
        if self.admit < 0:
            return -1
        return self.admit - max(self.arrival, 1)


class CascadeFrontEnd:
    """Dynamic lane admission/retirement over a ``BatchedCascadeEngine``.

    The engine's ``n_streams`` is the lane budget.  The front-end owns
    the clock: every ``step()`` is one engine tick (idle ticks submit an
    empty tick so commit deadlines keep counting).  It drives the
    pipelined path (``submit_tick``/``drain``) when the engine has
    ``pipeline_depth > 0`` and maps late-resolving outputs back through
    each output's tick number, so records are identical for any P.
    """

    def __init__(self, engine, stream, *, admission: str = "queue",
                 queue_limit: int = 0):
        if admission not in ("queue", "shed"):
            raise ValueError(
                f"admission must be 'queue' or 'shed', got {admission!r}")
        if queue_limit < 0:
            raise ValueError("queue_limit must be >= 0")
        self.engine = engine
        self.stream = stream
        self.admission = admission
        self.queue_limit = queue_limit
        L = engine.n_streams
        self._occupant: List[Optional[int]] = [None] * L  # lane -> rid
        self._free: List[int] = list(range(L))            # sorted
        self._queue: deque = deque()                      # waiting rids
        self._cursor: Dict[int, int] = {}                 # rid -> next item
        self._requests: Dict[int, "object"] = {}          # rid -> Request
        self.records: Dict[int, StreamRecord] = {}
        # engine tick -> (lanes, rids) of the tick's positions, kept
        # until the tick's output resolves (pipelined outputs arrive up
        # to P ticks late)
        self._tick_layout: Dict[int, tuple] = {}
        # per-lane occupancy intervals [(start_tick, end_tick, rid)] for
        # commit attribution: a commit_log entry (submit_t, lane, c)
        # belongs to whichever stream held `lane` at submit_t
        self._lane_history: List[List[tuple]] = [[] for _ in range(L)]
        self._commit_seen = 0
        self.stats = {"offered": 0, "admitted": 0, "shed": 0,
                      "retired": 0, "ticks": 0, "idle_ticks": 0,
                      "occupancy_sum": 0}
        # (rid, admit_tick, lane) in admission order — the determinism
        # pin compares this log across engine knobs
        self.admission_log: List[tuple] = []

    # -- arrivals --------------------------------------------------------
    def offer(self, request) -> bool:
        """Present one arrival; False when shed under the shed policy."""
        self.stats["offered"] += 1
        rec = StreamRecord(rid=request.rid, arrival=request.arrival,
                           n_items=len(request.items))
        self.records[request.rid] = rec
        if (self.admission == "shed"
                and len(self._queue) >= len(self._free) + self.queue_limit):
            rec.shed = True
            self.stats["shed"] += 1
            return False
        self._requests[request.rid] = request
        self._cursor[request.rid] = 0
        self._queue.append(request.rid)
        return True

    # -- lifecycle -------------------------------------------------------
    def occupied(self) -> List[int]:
        """Occupied physical lanes, ascending."""
        return [s for s, r in enumerate(self._occupant) if r is not None]

    def active(self) -> bool:
        """True while any stream is queued or holds a lane."""
        return bool(self._queue) or any(
            r is not None for r in self._occupant)

    def _retire(self, t_next: int) -> None:
        for lane, rid in enumerate(self._occupant):
            if rid is None:
                continue
            if self._cursor[rid] >= self.records[rid].n_items:
                rec = self.records[rid]
                rec.retired = t_next
                self._occupant[lane] = None
                self._lane_history[lane][-1] = (
                    self._lane_history[lane][-1][0], t_next - 1, rid)
                self._free.append(lane)
                self.stats["retired"] += 1
        self._free.sort()

    def _admit(self, t_next: int) -> None:
        while self._queue and self._free:
            rid = self._queue.popleft()
            lane = self._free.pop(0)
            self._occupant[lane] = rid
            rec = self.records[rid]
            rec.admit = t_next
            rec.lane = lane
            self._lane_history[lane].append((t_next, None, rid))
            self.admission_log.append((rid, t_next, lane))
            self.stats["admitted"] += 1

    def step(self) -> List[dict]:
        """One engine tick: retire, admit, serve (or idle).  Returns the
        outputs the engine resolved this tick (possibly older ticks')."""
        t_next = self.engine.t + 1
        self._retire(t_next)
        self._admit(t_next)
        lanes, rids, idxs, ticks = [], [], [], []
        for lane, rid in enumerate(self._occupant):
            if rid is None:
                continue
            j = self._cursor[rid]
            lanes.append(lane)
            rids.append(rid)
            idxs.append(self._requests[rid].items[j])
            ticks.append(j + 1)     # the stream's own 1-based item tick
            self._cursor[rid] = j + 1
            if j + 1 == self.records[rid].n_items:
                self.records[rid].done = t_next
        docs = [self.stream.docs[i] for i in idxs]
        self.stats["ticks"] += 1
        self.stats["occupancy_sum"] += len(lanes)
        if not lanes:
            self.stats["idle_ticks"] += 1
        self._tick_layout[t_next] = (lanes, rids)
        if self.engine.pipeline_depth:
            outs = self.engine.submit_tick(
                idxs, docs, lanes=lanes, stream_ids=rids,
                stream_ticks=ticks)
        else:
            outs = [self.engine.process_tick(
                idxs, docs, lanes=lanes, stream_ids=rids,
                stream_ticks=ticks)]
        for out in outs:
            self._consume(out)
        self._consume_commits()
        return outs

    def _consume(self, out: dict) -> None:
        _, rids = self._tick_layout.pop(out["tick"])
        now = time.time()
        for pos, rid in enumerate(rids):
            rec = self.records[rid]
            rec.predictions.append(int(out["predictions"][pos]))
            rec.levels.append(int(out["levels"][pos]))
            rec.expert_calls += int(out["expert_called"][pos])
            rec.cost_units += float(out["cost_units"][pos])
            rec.items_done += 1
            if rec.items_done == rec.n_items:
                rec.answer_wall = now

    def _consume_commits(self) -> None:
        log = self.engine.commit_log
        if log is None:
            return
        for sub_t, lane, commit_t in log[self._commit_seen:]:
            spans = self._lane_history[lane]
            # rightmost span starting at/before sub_t holds the occupant
            k = bisect_right([sp[0] for sp in spans], sub_t) - 1
            if k >= 0:
                self.records[spans[k][2]].commit_ticks.append(commit_t)
        self._commit_seen = len(log)

    def finish(self) -> None:
        """Stream end: drain the route ring, flush pending annotations,
        attribute the late commits, retire the survivors."""
        for out in self.engine.drain():
            self._consume(out)
        self.engine.flush()
        self._consume_commits()
        self._retire(self.engine.t + 1)

    def serve(self, requests: Sequence, max_ticks: Optional[int] = None,
              finalize: bool = True) -> Dict[int, StreamRecord]:
        """Tick-driven serve loop over a full schedule: offer each
        request at its arrival tick, step until everything retired (or
        ``max_ticks``), then ``finish()``.  Deterministic in the
        schedule — nothing here reads an engine output.

        ``finalize=False`` skips ``finish()`` on a ``max_ticks`` break,
        leaving the front-end mid-stream for ``save_state()``; calling
        ``serve()`` again (same schedule) resumes — requests already
        offered before the break (present in ``records``) are skipped.
        """
        pending = deque(sorted(
            (r for r in requests if r.rid not in self.records),
            key=lambda r: (max(r.arrival, 1), r.rid)))
        while pending or self.active():
            if max_ticks is not None and self.engine.t >= max_ticks:
                break
            t_next = self.engine.t + 1
            # retire BEFORE offering so a shed decision sees the lanes
            # this tick actually frees (step()'s own retire is then a
            # no-op); idle ticks — arrivals pending, nothing occupied —
            # still step, keeping the clock and commit deadlines moving
            self._retire(t_next)
            while pending and max(pending[0].arrival, 1) <= t_next:
                self.offer(pending.popleft())
            self.step()
        if finalize:
            self.finish()
        return self.records

    # -- live-state checkpointing ----------------------------------------
    def save_state(self, path: str) -> None:
        """Checkpoint the front-end mid-schedule: drain the engine's
        route ring (consuming the late outputs so ``_tick_layout`` is
        empty), save the engine's live state under ``path``, and write
        the admission bookkeeping to ``path + '.frontend.json'``.

        Wall-clock fields (``arrival_wall``/``answer_wall``) survive as
        recorded values; tick bookkeeping is exact."""
        for out in self.engine.drain():
            self._consume(out)
        self._consume_commits()
        self.engine.save_state(path)
        state = {
            "occupant": [-1 if r is None else int(r)
                         for r in self._occupant],
            "free": [int(s) for s in self._free],
            "queue": [int(r) for r in self._queue],
            "cursor": {str(k): int(v) for k, v in self._cursor.items()},
            "records": {str(k): asdict(v)
                        for k, v in self.records.items()},
            "lane_history": [[list(sp) for sp in spans]
                             for spans in self._lane_history],
            "commit_seen": int(self._commit_seen),
            "stats": dict(self.stats),
            "admission_log": [list(e) for e in self.admission_log],
            "admission": self.admission,
            "queue_limit": int(self.queue_limit),
        }
        with open(path + ".frontend.json", "w") as fh:
            json.dump(state, fh)

    def restore_state(self, path: str, requests: Sequence) -> None:
        """Resume a checkpointed front-end: restore the engine's live
        state, rebuild the admission bookkeeping, and re-bind the
        ``Request`` objects (matched by rid) for the streams that were
        queued or mid-flight at save time."""
        self.engine.restore_state(path)
        with open(path + ".frontend.json") as fh:
            state = json.load(fh)
        if (state["admission"] != self.admission
                or state["queue_limit"] != self.queue_limit):
            raise ValueError(
                "checkpoint admission policy mismatch: saved "
                f"({state['admission']!r}, {state['queue_limit']}) vs "
                f"({self.admission!r}, {self.queue_limit})")
        by_rid = {r.rid: r for r in requests}
        self._occupant = [None if r < 0 else r for r in state["occupant"]]
        self._free = list(state["free"])
        self._queue = deque(state["queue"])
        self._cursor = {int(k): v for k, v in state["cursor"].items()}
        self.records = {int(k): StreamRecord(**v)
                        for k, v in state["records"].items()}
        self._requests = {rid: by_rid[rid] for rid in self._cursor
                          if rid in by_rid}
        missing = set(self._cursor) - set(self._requests)
        if missing:
            raise ValueError(
                f"restore_state: rids {sorted(missing)} in the "
                "checkpoint are absent from the given schedule")
        self._tick_layout = {}
        self._lane_history = [[tuple(sp) for sp in spans]
                              for spans in state["lane_history"]]
        self._commit_seen = state["commit_seen"]
        self.stats = dict(state["stats"])
        self.admission_log = [tuple(e) for e in state["admission_log"]]

    # -- metrics ---------------------------------------------------------
    def metrics(self) -> dict:
        """Serving summary: answered counts, tick-latency percentiles,
        occupancy, plus a base-corpus prediction array (-1 where an item
        was shed/unserved) for parity checks against lockstep runs."""
        recs = list(self.records.values())
        answered = [r for r in recs if r.answered]
        ttas = np.array([r.time_to_answer() for r in answered], np.int64)
        delays = np.array([r.queue_delay() for r in answered], np.int64)
        preds = np.full(len(self.stream), -1, np.int64)
        for rid, rec in self.records.items():
            if rec.shed:
                continue
            items = self._requests[rid].items
            for j, p in enumerate(rec.predictions):
                preds[items[j]] = p
        ticks = max(self.stats["ticks"], 1)
        return {
            "requests": len(recs),
            "answered": len(answered),
            "shed": self.stats["shed"],
            "items_done": int(sum(r.items_done for r in recs)),
            "tta_p50": float(np.percentile(ttas, 50)) if ttas.size else 0.0,
            "tta_p99": float(np.percentile(ttas, 99)) if ttas.size else 0.0,
            "queue_delay_mean": (float(delays.mean())
                                 if delays.size else 0.0),
            "occupancy_mean": self.stats["occupancy_sum"] / ticks,
            "idle_ticks": self.stats["idle_ticks"],
            "ticks": self.stats["ticks"],
            "predictions": preds,
        }


def serve_requests(engine, stream, requests, *, admission: str = "queue",
                   queue_limit: int = 0) -> "CascadeFrontEnd":
    """One-call convenience: build the front-end, serve the schedule to
    completion, return the front-end (records + metrics inside)."""
    fe = CascadeFrontEnd(engine, stream, admission=admission,
                         queue_limit=queue_limit)
    fe.serve(requests)
    return fe
