"""Batched multi-stream cascade engine (the serving-scale form of Alg. 1).

``OnlineCascade.process`` is a host-side Python loop: one tiny jitted call
per level per item, plus four more per expert-labeled item for the student
and deferral updates.  At serving scale that is dispatch-bound, not
FLOP-bound.  ``BatchedCascadeEngine`` runs S concurrent stream lanes in
lockstep and replaces the per-item walk with two fused, jitted calls per
tick:

  route pass (read-only, one jitted call per *level*, not per item)
    * the cascade walk is vectorized: per-item control flow becomes
      boolean lane masks (jumped / alive / took) combined with
      ``where``/``argmax`` logic instead of Python ``break``s;
    * each level's predict + defer runs once, batched over the gathered
      subset of lanes still alive at that level — dead lanes (already
      exited, or DAgger-jumped straight to the expert) cost nothing,
      preserving the cascade's compute savings that a naive
      all-levels-times-all-lanes batch would squander.  Subsets are
      padded to bucketed sizes (powers of two up to S) so the number of
      compiled shapes stays bounded;
    * the student models and deferral MLPs are natively batched — this is
      the ``vmap`` of the reference's per-example functions collapsed
      into one dot per level.

  expert call
    * the deferred subset is gathered once and sent to the expert as a
      single batched forward (``label_batch``).

  update pass (per tick, not per item)
    * expert demonstrations are scattered into a vectorized ring buffer
      per level (the FIFO cache of the reference, as one masked scatter
      in a jitted step with ``donate_argnums`` so the buffers mutate in
      place instead of copying);
    * one weighted student OGD/Adam step per level per tick, sampled from
      the post-insert ring buffer;
    * one weighted deferral-MLP step per level per tick, with per-item
      weights w[s] = 1[expert labeled s and s reached this level], and
      skipped entirely when no lane has mass — exactly when the reference
      would not step.

    The update steps are the *same jitted callables* the reference uses
    (they are batched and weighted by design), invoked once per tick with
    the whole lane batch instead of once per item.  Reusing the identical
    compiled program — rather than re-fusing the update math into one
    mega-graph — is what makes the S == 1 state evolution bit-identical
    instead of merely close (XLA re-fusion reassociates reductions at the
    ~1 ulp level).

RNG / equivalence contract
--------------------------
All randomness follows the pre-split per-tick key discipline of
``repro.core.rng``: lane s at tick t draws from independent child
generators of ``SeedSequence((seed, s, t))``; cache mini-batch sampling
uses the lane-0 children (it is a per-cascade purpose).  The sequential
``OnlineCascade`` is lane 0 of this scheme, and all floating-point update
math lives in functions shared verbatim with the reference
(``*_loss_weighted``, ``deferral_update_terms``), computed in float32 on
device by both engines.  Consequence: **with n_streams == 1 this engine
is bit-for-bit equivalent to ``OnlineCascade`` on the same stream and
seed** — identical predictions, chosen levels, expert calls, parameters,
and optimizer state (tests/test_batched.py asserts this exactly).

Deviations at S > 1 (documented, inherent to batching):
  * students/deferral MLPs take ONE weighted step per tick instead of one
    step per expert-labeled item — k demonstrations within a tick are
    aggregated, which is how batch-serving cascades amortize update cost
    (cf. cascade-aware training; PAPERS.md).  With
    ``updates_per_tick="scaled"`` that single step is lr-scaled to stand
    in for the tick's k per-item steps (``Optimizer.step_k``: EMA decays
    raised to k, schedule counters advanced by k), which pins the
    batched engine's expert-call counts to within ~1.5x of the
    sequential reference on streams where the gates close early
    (tests/test_batched.py pins this);
  * DAgger's beta decays per consumed item (``decay ** S`` per tick, all
    lanes sharing one beta): the students are shared, so the exploration
    budget tracks demonstrations seen, not wall-clock ticks.  The
    re-exploration floor (core.deferral) is applied once per tick at the
    post-tick item count;
  * the hard expert budget is enforced at tick granularity: the first
    ``remaining`` deferred lanes (in lane order) get the expert, the rest
    fall back to the last student's prediction;
  * expert annotations land in the shared ring buffer in lane order
    within the tick.

Async expert queue (``max_delay=``)
-----------------------------------
The tick loop is a route/commit pair around a double-buffered deferred-
lane queue, so the host-side expert forward no longer serializes with
student compute:

  route (tick t)
    * the vectorized cascade walk runs as before; the tick's deferred
      subset is *submitted* to the expert (``expert.submit`` — thread-
      backed for ``ModelExpert``, resolved inline for
      ``SimulatedExpert``) instead of being waited on;
    * deferred lanes emit the LAST student's prediction provisionally
      (its probs are already in hand: every annotated lane calibrates
      every gate, and those calibration forwards run at route time
      against the tick's pre-update students — training-side compute,
      not costed, exactly the values the synchronous engine computes
      after its expert call);
    * expert-call accounting (budget, cost, ``expert_calls``) happens at
      submit time — annotation *latency* never changes which lanes get
      the expert.

  commit (tick t + max_delay, end of tick)
    * the tick's ticket is resolved (blocking if the expert is slower
      than ``max_delay`` ticks of student compute — that is the bound),
      and the annotations are applied exactly as the synchronous engine
      would have: ring-buffer scatter, per-tick weighted student and
      deferral/gate-calibration updates, in FIFO tick order with the
      tick's own cache-sampling RNG.  Commit order is deterministic for
      any expert latency — results never depend on thread timing.

``max_delay=0`` degenerates to the synchronous engine: route submits and
immediately commits inside the same ``process_tick``, executing the
identical op sequence — the S == 1 and lane-sharded parity contracts
hold **bitwise** at ``max_delay=0``.  With ``max_delay=D >= 1`` the
update stream lags the route stream by exactly D ticks (bounded
annotation delay): a tick's route sees parameters that have consumed all
demonstrations up to D+1 ticks back.  Beta still decays per consumed
item per tick at route time, and the demonstrations-seen re-exploration
floor is unchanged — delay shifts *when* updates land, never *which*
draws or annotations occur.  ``flush()`` (called by ``run`` at stream
end and available to servers) drains the queue.  Predictions already
emitted stay provisional — the accuracy cost of the delay is measured,
not hidden (tests/test_async.py pins the bounded-delay regression;
benchmarks/async_throughput.py measures the expert/student overlap win).

Per-lane commit granularity + expert pool (``per_lane=``)
---------------------------------------------------------
The per-tick drain above commits a routed tick's annotations as ONE
block at age exactly D: every deferred lane waits for the whole tick's
ticket, one update aggregates the tick's k demonstrations, and a single
slow annotation batch delays every lane behind it.  ``per_lane=True``
upgrades the queue to per-lane granularity:

  * the deferred subset is submitted through ``expert.submit_many``
    (core/experts.py): the batch is split into ``min(workers, k)``
    contiguous shards annotated by W concurrent workers, and the ticket
    completes *per item* — ``result_slice`` blocks on exactly the
    shards a commit needs, so expert throughput scales with the pool
    instead of serializing behind one worker;
  * each lane commits individually — ring-buffer scatter of its one
    demonstration, a per-item student step sampled with the LANE'S OWN
    tick cache RNG, and a single-item deferral/gate update — i.e. the
    sequential reference's per-item update schedule, recovered inside
    the batched engine (at S == 1 this is bitwise the reference, and
    ``updates_per_tick="scaled"`` becomes a no-op: the per-item steps
    ARE the schedule it approximates);
  * lanes drain on a deterministic sub-deadline schedule (``lanes_due``)
    that spreads a tick's k lanes over the D tick boundaries inside the
    delay window (cumulative ``floor(age * k / D)``, everything due at
    age D) — mean annotation-commit age drops from D to ~(D+1)/2 at
    D >= 2 while the <= D bound is untouched;
  * updates stay in strict (submit-tick, lane) order: the drain only
    advances past a tick's queue head once it is fully committed, and
    blocking on a not-yet-ready shard (never skipping it) is what keeps
    the schedule — and therefore predictions, params, and optimizer
    state — BITWISE IDENTICAL for any worker count and any worker
    latency interleaving.  Worker timing moves wall-clock blocking,
    never semantics (tests/test_pool.py pins W in {1,2,4} and
    adversarial latency schedules).

``per_lane=False`` (default) with ``workers=1`` executes the exact
PR-3 per-tick path.  ``commit_stats`` aggregates per-lane commit age
(ticks) and wall latency (seconds) for both modes;
benchmarks/pool_throughput.py measures the latency and W-scaling wins.

Lane sharding (``mesh=``)
-------------------------
Passing a ``jax.sharding.Mesh`` shards the engine's lane-major arrays —
feature batches, per-lane probs/deferral outputs, called masks, expert
labels, per-item weights — over the mesh's ('pod','data') axes with
``NamedSharding`` (sharding.specs lane rules).  The cascade itself is
ONE shared policy serving S lanes, so students, deferral MLPs, optimizer
state and the demonstration ring buffers live replicated on the mesh;
the per-level gathered predict+defer partitions into N independent
per-device programs (no collectives in the serving path), while the
per-tick weighted update steps and the ring-buffer scatter reduce over
the sharded lane dim through the collectives GSPMD inserts.  The expert
gather stays host-side (the expert is a host object).  ``n_streams``
must divide by the lane-device count; bucketed subset sizes then divide
too (``_bucket`` floors at the device count), except on a partial final
tick, which falls back to replicated placement.  Routing is
host-deterministic, so the sharded engine matches the unsharded engine
on identical tick keys — identical predictions, levels, and expert
calls; parameters agree to float tolerance (SPMD reassociates the
weighted-update reductions).  tests/test_sharded.py asserts this on an
8-virtual-device mesh; benchmarks/sharded_throughput.py measures it.

Pipelined route passes (``pipeline_depth=``)
--------------------------------------------
Even with the expert off the critical path (``max_delay``), the route
pass itself still syncs per level per tick: host routing needs ``dprob``
back from the device before it knows which lanes survive to the next
level, so the host blocks on every tick's first forward while the device
idles through every tick's featurization.  ``pipeline_depth=P >= 1``
overlaps them with a P-deep ring of in-flight ticks:

  dispatch (stage A, ``submit_tick``)
    * tick t+1's jump draws, masks, and level-0 featurization run on the
      host, and its level-0 batched forward (featurize -> ``put_lanes``
      -> jitted predict+defer) is *dispatched* — JAX async dispatch
      returns device futures without blocking — while tick t's dprob
      device->host transfer and host routing are still resolving.
      ``sharding.host_prefetch`` enqueues the D2H copy of the in-flight
      (probs, dprob) pair behind its producing computation, so by the
      time the ring resolves a tick its route outputs are already on the
      host.  Only level 0 can be pre-dispatched: deeper levels' gather
      masks depend on the tick's own earlier dprobs (the cascade's
      sequential structure), but in the converged single-exit regime
      level 0 is the whole tick — exactly where the sync hurt.
  resolve (stage B, FIFO)
    * the oldest in-flight tick blocks on its level-0 handles, walks the
      remaining levels (dispatch+sync per level, as before), submits
      deferred lanes to the expert, and commits due annotations — the
      identical op sequence as the unpipelined engine, in tick order.

Speculation discipline (what makes P > 0 *exact*, not approximate):

  * jump draws, sampled actions, and cache RNG are pre-split per tick
    (core.rng) — dispatch order cannot shift them;
  * beta decay is deterministic in items-seen, so stage A advances a
    route-time beta copy (``_route_beta``) through the identical
    recurrence the resolve-time state follows;
  * **update ticks fence the pipeline**: a dispatched forward reads the
    params live at dispatch.  If a commit is already known to be due
    while the ring drains (the pending queue holds a tick whose D-tick
    delay expires before the newly submitted tick routes), ``submit_tick``
    resolves past it first (``pipeline_stats["update_fences"]``).  A
    commit that only becomes known later — an in-flight tick turns out
    to call the expert at ``max_delay=0`` — is caught at resolve by a
    state-version check and the level-0 forward is *refetched* against
    the committed params (``pipeline_stats["refetches"]``; the
    featurization, which is parameter-independent, is reused).  Hard
    budgets quench speculation only inside the ambiguous window
    (``pipeline_stats["budget_fences"]``): far from the budget edge the
    jump gate's budget bit is provably stable.

Consequence: any ``pipeline_depth`` produces identical predictions,
chosen levels, expert-call decisions, parameters and optimizer state on
identical tick keys — only wall-clock differs (tests/test_pipelined.py
pins this, including composition with ``max_delay`` and the mesh).
``pipeline_depth=0`` (default) keeps today's one-tick-at-a-time
``process_tick`` path bit-for-bit.  In the learning regime every tick
commits, so the pipeline degenerates to the synchronous engine (fence
per tick) — the speedup lives in the converged regime, which is where
serving spends its life (benchmarks/pipelined_throughput.py measures
both honestly).  Pipelined serving is driven through
``submit_tick``/``resolve_tick``/``drain`` (``run`` does); a tick's
results return when it resolves, at most P ticks after submission.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize as _san
from repro.core.cascade import CascadeConfig, _Level, make_history
from repro.core.deferral import reexploration_floor
from repro.core.experts import (ExpertShardError, ExpertShardTimeout,
                                ExpertTicket)
from repro.core.rng import (generator_from_state, generator_state,
                            sample_cache_indices, tick_rngs)
from repro.sharding import host_prefetch, jit_cache_scatter, jit_route_pass

# autoscale unit: target one worker per this many uncommitted deferred
# items (clipped into the configured [lo, hi] fleet bounds)
_AUTOSCALE_ITEMS_PER_WORKER = 4

# checkpoint schema version (save_state/restore_state)
_CKPT_VERSION = 1


def lanes_due(k: int, age: int, max_delay: int, per_lane: bool) -> int:
    """Cumulative count of a routed tick's k annotated lanes whose
    commit deadline has passed ``age`` ticks after routing.

    Per-tick mode: all k at age ``max_delay``, none before.  Per-lane
    mode: the k lanes spread over the D tick boundaries inside the delay
    window — ``floor(age * k / max_delay)`` due by age, everything due
    at ``age >= max_delay`` (the <= D bound).  A pure function of
    (k, age, max_delay, per_lane): the commit schedule never depends on
    worker timing, which is what makes engine results bitwise invariant
    to pool size and annotation latency (tests/test_properties.py pins
    the monotonicity/bound/exactly-once invariants).
    """
    if age >= max_delay:
        return k
    if not per_lane or age <= 0:
        return 0
    return (age * k) // max_delay


@dataclass
class _PendingTick:
    """One routed tick whose expert annotations are still in flight.

    Holds exactly what the commit needs to replay the synchronous
    engine's update block once the labels land: the called-lane feature
    rows per level, the route-time probs/dprob of every level at the
    called lanes (gate calibration inputs), and the tick's own
    cache-sampling generators.  ``committed`` is the per-lane drain
    cursor: lanes ``sel_c[:committed]`` have already committed (always 0
    or k in per-tick mode)."""
    ticket: ExpertTicket
    t: int                        # tick this record was routed at
    called: np.ndarray            # (S,) bool — lanes annotated this tick
    sel_c: np.ndarray             # called lane indices
    feats: List[np.ndarray]       # per-level (S, ...) host feature rows
    probs: np.ndarray             # (nlev, S, C) route-time student probs
    dprob: np.ndarray             # (nlev, S) route-time deferral probs
    cache_rngs: list              # per-level np generators (lane-0 tick)
    committed: int = 0            # lanes already committed (prefix)
    lane_cache_rngs: Optional[list] = None   # per called lane, per level
    lanes: Optional[np.ndarray] = None  # physical lane per tick position
                                        # (occupancy ticks; None = arange)
    wall: float = 0.0             # wall-clock at submit (latency stats)
    feats_dev: Optional[list] = None   # device copies of feats, uploaded
                                       # once and shared by the record's
                                       # per-lane scatters
    idxs: Optional[list] = None   # stream indices of the called lanes
                                  # (what a failed shard is requeued as)
    docs_k: Optional[list] = None  # raw docs of the called lanes (None
                                   # after restore: ticket already
                                   # resolved, requeue unreachable)
    requeues: dict = field(default_factory=dict)  # shard lo -> retries


@dataclass
class _InFlightTick:
    """One dispatched-but-unresolved tick of the route pipeline.

    Created by stage A (``_route_dispatch``): the tick's pre-split RNG
    draws, jump mask, level-0 featurization, and the level-0 forward's
    un-synced device handles.  Stage B (``_route_resolve``) turns it into
    the tick's output dict; ``version`` records the engine's commit
    counter at dispatch so a commit landing in between is detected and
    the speculated forward refetched."""

    t: int                        # tick number assigned at dispatch
    indices: List[int]            # per-lane stream indices
    docs: list                    # per-lane raw docs
    S: int                        # lanes in this tick (<= n_streams)
    jump: np.ndarray              # (nlev, S) bool DAgger jump mask
    u_act: np.ndarray             # (nlev, S) float32 sampled-action draws
    budget_ok: bool               # route-time budget gate (fence-stable)
    cache_rngs: list              # per-level cache-sampling generators
    feats_cache: list             # per-level lazily built feature rows
    sel0: np.ndarray              # lanes alive at level 0 (post-jump)
    xb0: Optional[np.ndarray]     # padded level-0 host feature batch
    handles: Optional[tuple]      # in-flight (probs, dprob) device pair
    version: int                  # engine commit counter at dispatch
    beta_after: List[float]       # per-level beta after this tick's decay
    lane_cache: Optional[list] = None   # per-lane cache rngs (per_lane)
    lanes: Optional[np.ndarray] = None  # physical lane per tick position
                                        # (occupancy ticks; None = arange)
    u_jump_raw: Optional[np.ndarray] = None  # (nlev, S) raw jump draws,
                                             # kept only under the
                                             # determinism sanitizer


class BatchedCascadeEngine:
    """Lockstep multi-stream driver for Algorithm 1.

    ``process_tick(indices, docs)`` advances every lane by one item; lane
    s of tick t handles ``docs[s]`` (its expert annotation is requested as
    ``expert.label(indices[s], docs[s])`` or the batched equivalent).
    """

    def __init__(self, config: CascadeConfig, expert, n_streams: int = 64,
                 *, updates_per_tick: str = "single", mesh=None,
                 max_delay: int = 0, pipeline_depth: int = 0,
                 per_lane: bool = False,
                 history_limit: Optional[int] = None,
                 commit_log: Optional[bool] = None,
                 expert_timeout: Optional[float] = None,
                 max_requeues: int = 2,
                 autoscale: Optional[Tuple[int, int]] = None,
                 readiness_commits: bool = False):
        if n_streams < 1:
            raise ValueError("n_streams must be >= 1")
        if updates_per_tick not in ("single", "scaled"):
            raise ValueError(
                f"updates_per_tick must be 'single' or 'scaled', "
                f"got {updates_per_tick!r}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {max_delay}")
        if pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {pipeline_depth}")
        if expert_timeout is not None and expert_timeout <= 0:
            raise ValueError(
                f"expert_timeout must be > 0 (or None), got {expert_timeout}")
        if max_requeues < 0:
            raise ValueError(f"max_requeues must be >= 0, got {max_requeues}")
        # an expert constructed with workers="auto" opts into autoscaling
        # even when the engine caller didn't pass bounds
        if autoscale is None and getattr(expert, "auto_workers", False):
            autoscale = (1, 8)
        if autoscale is True:
            autoscale = (1, 8)
        if autoscale is not None:
            lo, hi = int(autoscale[0]), int(autoscale[1])
            if not (1 <= lo <= hi):
                raise ValueError(
                    f"autoscale bounds must satisfy 1 <= lo <= hi, "
                    f"got ({lo}, {hi})")
            autoscale = (lo, hi)
            if not hasattr(expert, "workers"):
                raise ValueError(
                    "autoscale requires an expert with a mutable "
                    "`workers` fleet width")
        self.cfg = config
        self.expert = expert
        self.n_streams = n_streams
        self.updates_per_tick = updates_per_tick
        self.max_delay = int(max_delay)
        self.pipeline_depth = int(pipeline_depth)
        self.per_lane = bool(per_lane)
        self.expert_timeout = expert_timeout
        self.max_requeues = int(max_requeues)
        self.autoscale = autoscale
        self.readiness_commits = bool(readiness_commits)
        if autoscale is not None:
            expert.workers = autoscale[0]
            # pools sized once take the upper bound so scaling up never
            # needs an executor rebuild (ModelExpert._pool_width)
            if getattr(expert, "max_workers", False) is None:
                expert.max_workers = autoscale[1]
        self.mesh = mesh
        if mesh is not None:
            from repro.sharding import (lane_count, put_lanes,
                                        put_replicated,
                                        replicated_sharding)
            self._rep_sharding = replicated_sharding(mesh)
            n_lane = lane_count(mesh)
            if n_lane < 1 or n_streams % n_lane:
                raise ValueError(
                    f"n_streams={n_streams} must be a positive multiple "
                    f"of the mesh's lane-device count {n_lane}")
            self._n_lane_devices = n_lane
            self._put_lane = lambda x: put_lanes(x, mesh)
            self._put_rep = lambda x: put_replicated(x, mesh)
        else:
            self._n_lane_devices = 1
            self._put_lane = jnp.asarray
            self._put_rep = jnp.asarray
        keys = jax.random.split(jax.random.PRNGKey(config.seed),
                                len(config.levels))
        # identical construction (and PRNG keys) to OnlineCascade so the
        # initial parameters match the reference bitwise
        self.levels: List[_Level] = [
            _Level(spec, config, k,
                   defer_cost=(config.levels[i + 1].cost
                               if i + 1 < len(config.levels)
                               else config.expert_cost))
            for i, (spec, k) in enumerate(zip(config.levels, keys))]
        nlev = len(self.levels)
        if mesh is not None:
            # the cascade is SHARED across lanes: students, deferral MLPs
            # and their optimizer states live replicated on the mesh (and
            # the levels' reset() snapshots point at the replicated
            # copies, so a reset engine stays mesh-placed)
            for lvl in self.levels:
                (lvl.params, lvl.opt_state, lvl.dparams,
                 lvl.dopt_state) = jax.device_put(
                    (lvl.params, lvl.opt_state, lvl.dparams,
                     lvl.dopt_state), self._rep_sharding)
                lvl._init_state = (lvl.params, lvl.opt_state,
                                   lvl.dparams, lvl.dopt_state)
        # vectorized ring buffers (device) + host mirrors of fill/ptr
        self._cache_x = [self._put_rep(lvl.cache_x) for lvl in self.levels]
        self._cache_y = [self._put_rep(lvl.cache_y) for lvl in self.levels]
        self._cache_n = [0] * nlev
        self._cache_ptr = [0] * nlev
        self.t = 0
        # per-stream accounting (independent per lane)
        S = n_streams
        self.expert_calls = np.zeros(S, np.int64)
        self.total_cost = np.zeros(S, np.float64)
        self.level_counts = np.zeros((S, nlev + 1), np.int64)
        self.items_seen = np.zeros(S, np.int64)
        self.J_cum = np.zeros(S, np.float64)
        self.history = make_history(history_limit)
        # double-buffered deferred-lane queue: routed ticks whose expert
        # annotations are still in flight (at most max_delay + 1 deep)
        self._pending: deque = deque()
        # per-lane annotation-commit accounting: ages in ticks, latencies
        # in seconds, aggregated over every committed lane (both commit
        # modes).  commit_log records (submit_tick, lane, commit_tick)
        # per lane.  By default (commit_log=None) it follows the history
        # mode: on in the unbounded-diagnostics mode (history_limit=None),
        # off with bounded/disabled history so long-serving memory stays
        # bounded (the queue-drain invariant tests and pool_throughput
        # read it).  commit_log=True/False overrides that coupling — the
        # admission front-end needs per-lane commit ticks for its
        # per-stream records while running with history_limit=0
        # (core/admission.py consumes the log with a cursor).
        self.commit_stats = {"lanes": 0, "age_sum": 0, "age_max": 0,
                             "wall_sum": 0.0}
        if commit_log is None:
            commit_log = history_limit is None
        self.commit_log: Optional[list] = [] if commit_log else None
        # route pipeline: dispatched-but-unresolved ticks (<= pipeline_depth
        # deep), the speculative route-time beta/item counters that track
        # the resolve-time state through the identical recurrence, and the
        # commit counter the staleness check reads
        self._ring: deque = deque()
        self._route_beta: List[float] = [config.beta0] * nlev
        self._route_items = 0
        self._state_version = 0
        self.pipeline_stats = {"submitted": 0, "resolved": 0,
                               "refetches": 0, "update_fences": 0,
                               "budget_fences": 0}
        # failure-semantics + fleet accounting (ARCHITECTURE.md §10):
        # every injected/observed fault is either healed (requeues) or
        # explicitly surrendered (dropped_annotations) — never silent
        self.fault_stats = {"timeouts": 0, "worker_deaths": 0,
                            "requeues": 0, "dropped_annotations": 0,
                            "scale_ups": 0, "scale_downs": 0}
        self.fleet_log: List[Tuple[int, int]] = []   # (tick, new width)
        self._build_steps()

    def reset(self):
        """Back to tick 0 of a fresh stream; compiled jits are kept (a
        warmed engine can serve new streams with zero compile cost)."""
        for lvl in self.levels:
            lvl.reset()
        nlev = len(self.levels)
        # device ring buffers may have been donated — rebuild from the
        # levels' (zeroed) host templates, on the same mesh placement
        self._cache_x = [self._put_rep(lvl.cache_x) for lvl in self.levels]
        self._cache_y = [self._put_rep(lvl.cache_y) for lvl in self.levels]
        self._cache_n = [0] * nlev
        self._cache_ptr = [0] * nlev
        self.t = 0
        self.expert_calls[:] = 0
        self.total_cost[:] = 0
        self.level_counts[:] = 0
        self.items_seen[:] = 0
        self.J_cum[:] = 0
        if self.history is not None:
            for v in self.history.values():
                v.clear()
        # in-flight annotations and route dispatches belong to the
        # abandoned stream
        self._pending.clear()
        self._ring.clear()
        self._route_beta = [self.cfg.beta0] * len(self.levels)
        self._route_items = 0
        self._state_version += 1
        for k in self.pipeline_stats:
            self.pipeline_stats[k] = 0
        self.commit_stats = {"lanes": 0, "age_sum": 0, "age_max": 0,
                             "wall_sum": 0.0}
        if self.commit_log is not None:
            self.commit_log.clear()
        for k in self.fault_stats:
            self.fault_stats[k] = 0
        self.fleet_log.clear()
        if self.autoscale is not None:
            self.expert.workers = self.autoscale[0]
        # reap the expert's worker pool: a reset engine must not leak
        # the old stream's threads/processes (pools rebuild lazily on
        # the next submit, so a warmed engine loses no semantics)
        self.close()
        # a recorded determinism-sanitizer trace belongs to the old
        # stream too — a reused engine starts a fresh, comparable trace
        _san.drop_trace(self)

    def close(self) -> None:
        """Shut down the expert's worker pool, if it has one
        (idempotent; the pool is rebuilt lazily on the next submit)."""
        close = getattr(self.expert, "close", None)
        if close is not None:
            close()

    def __del__(self):  # best-effort: don't leak expert workers at GC
        try:
            self.close()
        except Exception:
            pass

    # -- aggregates -----------------------------------------------------
    @property
    def expert_calls_total(self) -> int:
        """Expert calls summed over lanes (resolved ticks only)."""
        return int(self.expert_calls.sum())

    def _budget_exhausted(self) -> bool:
        hb = self.cfg.hard_budget
        return hb is not None and self.expert_calls_total >= hb

    # -- jitted steps ----------------------------------------------------
    def _build_steps(self):
        levels = self.levels
        nlev = len(levels)
        bs_list = [min(lvl.spec.batch_size, lvl.spec.cache_size)
                   for lvl in levels]

        # per-level batched predict + defer over the gathered alive
        # subset (the level's ``route_pass`` body — at a (1, ...) batch
        # this is the reference's ``predict_and_defer`` computation
        # exactly).  In pipelined mode on a mesh the padded lane feature
        # buffer is donated: each in-flight tick's input is consumed
        # exactly once by its dispatch (sharding.jit_route_pass)
        donate_mesh = self.mesh if self.pipeline_depth else None
        self._predict_defer = [
            jit_route_pass(
                _san.trace_probe(f"route_pass[{i}]", lvl.route_pass),
                donate_mesh)
            for i, lvl in enumerate(levels)]

        def scatter(cx_t, cy_t, feats_t, y_full, called, ptr_arr):
            """Vectorized ring-buffer insert of a tick's demonstrations."""
            order = jnp.cumsum(called.astype(jnp.int32)) - 1
            k = jnp.sum(called.astype(jnp.int32))
            new_cx, new_cy = [], []
            for i in range(nlev):
                size = levels[i].spec.cache_size
                # called lanes take consecutive slots after ptr; if
                # k > size only the last `size` survive (the sequential
                # FIFO's overwrite order); index `size` drops the write
                keep = called & (order >= k - size)
                slot = jnp.where(keep, (ptr_arr[i] + order) % size, size)
                new_cx.append(cx_t[i].at[slot].set(feats_t[i], mode="drop"))
                new_cy.append(cy_t[i].at[slot].set(y_full, mode="drop"))
            return tuple(new_cx), tuple(new_cy)

        # ring buffers donated; with a mesh the outputs stay pinned
        # replicated so the donation chain survives the per-lane commit
        # mode's one-scatter-per-lane cadence (sharding.jit_cache_scatter)
        self._scatter = jit_cache_scatter(
            _san.trace_probe("cache_scatter", scatter), self.mesh)
        self._bs_list = bs_list

    def _bucket(self, n: int) -> int:
        """Smallest padded batch size for a subset of n lanes: the
        lane-device count doubled up to at least max(8, n), capped at
        n_streams — every bucket stays divisible by the device count
        (including non-power-of-two meshes) and each level compiles
        O(log S) shapes.  Without a mesh this reduces to the powers-of-
        two-from-8 schedule, and with n_streams == 1 it is exactly 1 —
        the reference's per-item shape, which keeps the parity contract
        bitwise."""
        b = self._n_lane_devices
        while b < max(8, n):
            b *= 2
        return min(b, self.n_streams)

    # -- expert ---------------------------------------------------------
    def _expert_label_batch(self, idxs: Sequence[int], docs) -> np.ndarray:
        lb = getattr(self.expert, "label_batch", None)
        if lb is not None:
            return np.asarray(lb(idxs, docs), np.int32)
        return np.asarray([self.expert.label(i, d)
                           for i, d in zip(idxs, docs)], np.int32)

    def _expert_submit(self, idxs: Sequence[int], docs) -> ExpertTicket:
        """Enqueue a batch annotation.  Experts with a worker pool
        (``submit_many``) get the batch sharded with per-item ticket
        completion — what the per-lane commit drain consumes; experts
        with only ``submit`` keep the PR-3 single-request path, and
        experts without the async interface resolve synchronously
        (still one batched call)."""
        sub = getattr(self.expert, "submit_many", None)
        if sub is None:
            sub = getattr(self.expert, "submit", None)
        if sub is not None:
            return sub(idxs, docs)
        return ExpertTicket(labels=self._expert_label_batch(idxs, docs))

    def _expert_poll(self, ticket: ExpertTicket) -> np.ndarray:
        poll = getattr(self.expert, "poll", None)
        if poll is not None:
            return np.asarray(poll(ticket, block=True), np.int32)
        return np.asarray(ticket.result(), np.int32)

    # -- failure semantics: requeue deadline + graceful degradation ------
    def _resolve_labels(self, rec: _PendingTick, lo: int,
                        hi: int) -> np.ndarray:
        """Labels for called items ``[lo, hi)`` of a pending record,
        surviving shard failures.

        ``expert_timeout`` bounds the wait on each shard (the D-tick
        commit bound becomes a *deadline*, not an assumption about the
        expert).  A timed-out or dead-worker shard is requeued to
        another worker; after ``max_requeues`` retries it is
        force-resolved to the ``-1`` dropped-annotation sentinel
        (counted in ``fault_stats["dropped_annotations"]``), so this
        ALWAYS returns and commits never deadlock.  Annotation labels
        are deterministic functions of the items (both expert kinds),
        so a successful requeue yields the exact labels the original
        shard would have — fault timing never changes committed state,
        only permanent drops do."""
        while True:
            try:
                return np.asarray(rec.ticket.result_slice(
                    lo, hi, timeout=self.expert_timeout), np.int32)
            except ExpertShardError as e:
                self._requeue_shard(rec, e)

    def _requeue_shard(self, rec: _PendingTick, err: ExpertShardError):
        k = rec.sel_c.size
        lo = err.lo
        hi = k if err.hi is None else err.hi
        if isinstance(err, ExpertShardTimeout):
            self.fault_stats["timeouts"] += 1
        else:
            self.fault_stats["worker_deaths"] += 1
        tries = rec.requeues.get(lo, 0)
        sub = getattr(self.expert, "submit", None)
        if tries < self.max_requeues and sub is not None \
                and rec.docs_k is not None:
            rec.requeues[lo] = tries + 1
            self.fault_stats["requeues"] += 1
            # resubmit just the failed range as one fresh shard (a new
            # submit sequence — a fresh worker, or for FlakyExpert a
            # fresh scripted fault cell); not re-counted in expert_calls:
            # the annotation was already requested and costed at route
            rec.ticket.replace(lo, hi, sub(rec.idxs[lo:hi],
                                           rec.docs_k[lo:hi]))
        else:
            # graceful degradation: the provisional student answer
            # stands; the lost demonstration is counted, never silent
            rec.ticket.force_resolve(lo, hi,
                                     np.full(hi - lo, -1, np.int32))
            self.fault_stats["dropped_annotations"] += hi - lo

    # -- fleet autoscaling ----------------------------------------------
    def _autoscale_tick(self) -> None:
        """Queue-depth worker autoscaling, decided at the deterministic
        tick boundary (dispatch time): the uncommitted deferred-item
        count is a pure function of the commit schedule under the
        default deterministic drain, so two runs of the same stream make
        identical scale decisions regardless of worker timing — traces
        stay comparable (``fleet_log`` records every decision).  Width
        only changes future shard layouts, never labels, so autoscaling
        preserves the bitwise-invariance contract."""
        lo, hi = self.autoscale
        depth = sum(r.sel_c.size - r.committed for r in self._pending)
        target = min(hi, max(lo, -(-depth // _AUTOSCALE_ITEMS_PER_WORKER)))
        cur = int(self.expert.workers)
        if target != cur:
            key = "scale_ups" if target > cur else "scale_downs"
            self.fault_stats[key] += 1
            self.expert.workers = target
            self.fleet_log.append((self.t, int(target)))

    # -- one lockstep tick ----------------------------------------------
    def process_tick(self, indices: Sequence[int], docs, *,
                     lanes=None, stream_ids=None,
                     stream_ticks=None) -> dict:
        """Advance every lane by one item.  len(docs) may be < n_streams
        on the final partial tick of a stream.

        This is the depth-0 path: dispatch and resolve run back to back,
        so the returned dict is always this tick's own result — bitwise
        the pre-pipeline engine regardless of ``pipeline_depth``.
        Pipelined serving (results returned up to P ticks late, route
        passes overlapped) is driven through ``submit_tick``/
        ``resolve_tick``/``drain`` instead; mixing the two while ticks
        are in flight is an error.

        ``lanes``/``stream_ids``/``stream_ticks`` are the occupancy
        extension used by the continuous-batching front-end
        (core/admission.py): ``lanes`` names the physical lane each tick
        position occupies (strictly increasing, defaults to
        ``arange(S)`` — the lockstep identity), and
        ``stream_ids[s]``/``stream_ticks[s]`` replace ``(s, t)`` as the
        position's RNG tick key so a dynamically-admitted stream draws
        the exact per-item randomness it would have drawn in a dedicated
        lane (see core/rng.py).  All three default to the lockstep
        behaviour bitwise.  An EMPTY tick (S == 0) is legal and advances
        the tick clock — including the D-tick commit deadlines — without
        dispatching any forward; the front-end uses it for idle ticks so
        one clock covers busy and idle time."""
        if self._ring:
            raise RuntimeError(
                "route pipeline has in-flight ticks: resolve_tick()/"
                "drain() them first, or drive the engine entirely "
                "through submit_tick()")
        return self._route_resolve(self._route_dispatch(
            indices, docs, lanes=lanes, stream_ids=stream_ids,
            stream_ticks=stream_ticks))

    # -- pipelined route driver (stage A / stage B) ----------------------
    def submit_tick(self, indices: Sequence[int], docs, *,
                    lanes=None, stream_ids=None,
                    stream_ticks=None) -> List[dict]:
        """Dispatch one tick into the route pipeline (stage A).

        Returns the output dicts of every tick the call resolved, oldest
        first: ring overflow past ``pipeline_depth``, plus any ticks
        resolved early by a fence (a due commit, or a hard budget inside
        its ambiguous window — see the module docstring).  With
        ``pipeline_depth=0`` the submitted tick itself resolves
        immediately, so exactly one dict comes back."""
        outs: List[dict] = []
        S = len(docs)
        hb = self.cfg.hard_budget
        if hb is not None and self._ring:
            resolved_calls = self.expert_calls_total
            in_flight = sum(r.S for r in self._ring)
            if resolved_calls < hb and resolved_calls + in_flight + S > hb:
                # ambiguous budget window: the new tick's jump gate can
                # no longer be proven stable against in-flight expert
                # calls — drain so it reads the exact call count
                self.pipeline_stats["budget_fences"] += 1
                while self._ring:
                    outs.append(self._route_resolve(self._ring.popleft()))
        while self._ring and self._commit_due():
            # a commit is due while the ring drains: dispatching now is
            # guaranteed stale — resolve past the commit first
            self.pipeline_stats["update_fences"] += 1
            outs.append(self._route_resolve(self._ring.popleft()))
        self._ring.append(self._route_dispatch(
            indices, docs, lanes=lanes, stream_ids=stream_ids,
            stream_ticks=stream_ticks))
        while len(self._ring) > self.pipeline_depth:
            outs.append(self._route_resolve(self._ring.popleft()))
        return outs

    def _commit_due(self) -> bool:
        """True when the pending queue's head has lanes whose deadline
        falls at/before the end of the current tick — i.e. a dispatch
        issued now is guaranteed to read pre-commit params.  Per-tick
        mode reduces to the PR-3 condition (head tick's age reached
        max_delay); per-lane mode also fires on the intermediate
        sub-deadlines of the spread schedule (``lanes_due``)."""
        if not self._pending:
            return False
        rec = self._pending[0]
        return lanes_due(rec.sel_c.size, self.t - rec.t, self.max_delay,
                         self.per_lane) > rec.committed

    def resolve_tick(self) -> Optional[dict]:
        """Resolve the oldest in-flight tick (stage B); None if empty."""
        if not self._ring:
            return None
        return self._route_resolve(self._ring.popleft())

    def drain(self) -> List[dict]:
        """Resolve every in-flight tick, oldest first (stream end /
        before checkpointing; ``run`` calls it before ``flush``)."""
        outs = []
        while self._ring:
            outs.append(self._route_resolve(self._ring.popleft()))
        return outs

    def _dispatch_level(self, i: int, fi: np.ndarray, sel: np.ndarray):
        """Pad the gathered lane subset ``fi[sel]`` to its bucket and
        dispatch the level-i route pass (async — no host sync).

        Returns ``(handles, xb)``: the in-flight (probs, dprob) device
        pair and the padded host batch (kept by stage A for refetch).
        Shared by the stage-A dispatch, the stage-B walk, and the
        every-gate calibration forwards so the pad/bucket/placement rule
        cannot drift between them."""
        lvl = self.levels[i]
        B = self._bucket(sel.size)
        xb = np.zeros((B,) + fi.shape[1:], fi.dtype)
        xb[:sel.size] = fi[sel]
        handles = self._predict_defer[i](lvl.params, lvl.dparams,
                                         self._put_lane(xb))
        return handles, xb

    def _route_dispatch(self, indices: Sequence[int], docs, *,
                        lanes=None, stream_ids=None,
                        stream_ticks=None) -> _InFlightTick:
        """Stage A: draws, masks, level-0 featurize + async dispatch.

        Everything here is either deterministic in the tick number
        (pre-split RNG, the route-time beta recurrence) or covered by a
        fence/staleness check (budget bit, level-0 params) — see the
        module docstring's speculation discipline.  The occupancy
        arguments (``lanes``/``stream_ids``/``stream_ticks``, see
        ``process_tick``) only change which physical lane each position
        accounts to and which (stream, tick) key seeds its draws — the
        route itself is position-indexed and identical."""
        cfg = self.cfg
        nlev = len(self.levels)
        S = len(docs)
        if S > self.n_streams:
            raise ValueError(f"tick of {S} items > n_streams={self.n_streams}")
        if lanes is not None:
            lanes = np.asarray(lanes, np.int64)
            if lanes.shape != (S,):
                raise ValueError(
                    f"lanes must have one entry per tick position: "
                    f"got shape {lanes.shape} for a tick of {S}")
            if S and (lanes[0] < 0 or lanes[-1] >= self.n_streams
                      or np.any(np.diff(lanes) <= 0)):
                raise ValueError(
                    "lanes must be strictly increasing physical lane ids "
                    f"in [0, n_streams={self.n_streams})")
        if stream_ids is not None and len(stream_ids) != S:
            raise ValueError("stream_ids must have one entry per position")
        if stream_ticks is not None and len(stream_ticks) != S:
            raise ValueError("stream_ticks must have one entry per position")
        self.t += 1
        t = self.t
        self.pipeline_stats["submitted"] += 1
        if self.autoscale is not None:
            self._autoscale_tick()

        # lazy per-level featurization: a level's feature batch is only
        # built if some lane actually reaches it (mirrors the reference's
        # per-item feat() cache; in a cheap-level-dominant steady state
        # the expensive levels' featurizers never run)
        feats_cache: list = [None] * nlev

        u_jump = np.empty((nlev, S))
        u_act = np.empty((nlev, S), np.float32)
        cache_rngs = None
        # per-lane commit mode samples each lane's cache mini-batch with
        # the LANE'S OWN tick generators (the sequential reference's
        # per-item rule); per-tick mode only needs the lane-0 purpose
        lane_cache = [] if self.per_lane else None
        for s in range(S):
            # a dynamically-admitted stream keeps its OWN (stream id,
            # local tick) key regardless of which lane or global tick
            # serves it — this is what makes its per-item draws identical
            # to the dedicated-lane run (tests/test_admission.py pins it)
            sid = s if stream_ids is None else int(stream_ids[s])
            lt = t if stream_ticks is None else int(stream_ticks[s])
            r = tick_rngs(cfg.seed, sid, lt, nlev)
            u_jump[:, s] = r.jump.random(nlev)
            u_act[:, s] = r.action.random(nlev).astype(np.float32)
            if lane_cache is not None:
                lane_cache.append(r.cache)
            if s == 0:
                cache_rngs = r.cache

        budget_ok = not self._budget_exhausted()
        betas = np.array(self._route_beta)[:, None]
        jump = (u_jump < betas) & budget_ok

        # level 0 is the only forward whose gather mask is known before
        # any dprob returns (lanes alive there = lanes that didn't jump);
        # dispatch it without blocking and start the D2H copy of its
        # outputs so stage B's np.asarray is a wait, not a round trip
        sel0 = np.flatnonzero(~jump[0])
        xb0 = None
        handles = None
        if sel0.size:
            fi = np.stack([self.levels[0].featurize(d) for d in docs])
            feats_cache[0] = fi
            handles, xb0 = self._dispatch_level(0, fi, sel0)
            host_prefetch(handles)

        # beta decays per consumed ITEM (decay^S per tick): the students
        # are shared across lanes, so the DAgger exploration budget is
        # measured in demonstrations seen, matching the reference's
        # schedule in item-space (identical at S == 1).  The
        # re-exploration floor (core.deferral) is applied once per tick
        # at the post-tick item count.  The recurrence is deterministic
        # in items seen, so it advances HERE, at dispatch (tick sizes
        # are known) — ``lvl.beta`` is synced to the same value when the
        # tick resolves, keeping the observable state identical to the
        # unpipelined engine without a second copy of the schedule.
        self._route_items += S
        for i, lvl in enumerate(self.levels):
            self._route_beta[i] = max(
                self._route_beta[i] * lvl.spec.beta_decay ** S,
                reexploration_floor(lvl.spec.beta_floor, self._route_items))

        return _InFlightTick(
            t=t, indices=[int(i) for i in indices], docs=list(docs), S=S,
            jump=jump, u_act=u_act, budget_ok=budget_ok,
            cache_rngs=cache_rngs, feats_cache=feats_cache, sel0=sel0,
            xb0=xb0, handles=handles, version=self._state_version,
            beta_after=list(self._route_beta), lane_cache=lane_cache,
            lanes=lanes,
            u_jump_raw=u_jump if _san.determinism_on() else None)

    def _route_resolve(self, rec: _InFlightTick) -> dict:
        """Stage B: host routing, expert submit, commits, accounting.

        Runs the unpipelined engine's op sequence for tick ``rec.t``
        exactly, in FIFO tick order; the only pipelined difference is
        that the level-0 forward was dispatched earlier (and is refetched
        here if a commit landed since)."""
        cfg = self.cfg
        nlev = len(self.levels)
        S = rec.S
        t = rec.t
        docs = rec.docs
        u_act = rec.u_act
        jump = rec.jump
        budget_ok = rec.budget_ok
        cache_rngs = rec.cache_rngs
        feats_cache = rec.feats_cache
        self.pipeline_stats["resolved"] += 1

        def feats(i):
            if feats_cache[i] is None:
                feats_cache[i] = np.stack(
                    [self.levels[i].featurize(d) for d in docs])
            return feats_cache[i]

        handles = rec.handles
        if handles is not None and rec.version != self._state_version:
            # a commit landed after this tick's dispatch: the speculated
            # level-0 forward read pre-update params.  Refetch against
            # the committed state (featurization is parameter-independent
            # and is reused; only the jitted forward re-runs)
            self.pipeline_stats["refetches"] += 1
            lvl = self.levels[0]
            handles = self._predict_defer[0](
                lvl.params, lvl.dparams, self._put_lane(rec.xb0))

        # -- vectorized cascade walk: one gathered, batched predict+defer
        #    call per level over the lanes still alive there --------------
        alive = np.ones(S, bool)            # walking, not yet exited
        jumped = np.zeros(S, bool)
        eval_mask = np.zeros((nlev, S), bool)
        dprob_h = np.zeros((nlev, S), np.float32)
        probs_h = np.zeros((nlev, S, cfg.n_classes), np.float32)
        predictions = np.zeros(S, np.int64)
        exit_level = np.full(S, nlev, np.int64)   # nlev = reached expert
        for i, lvl in enumerate(self.levels):
            jump_now = alive & jump[i]
            jumped |= jump_now
            alive &= ~jump[i]
            sel = np.flatnonzero(alive)
            if sel.size == 0:
                continue
            if i == 0:
                # pre-dispatched at stage A (sel == rec.sel0 by
                # construction: the jump mask is identical)
                probs_d, dprob_d = handles
            else:
                (probs_d, dprob_d), _ = self._dispatch_level(i, feats(i),
                                                             sel)
            probs_np = np.asarray(probs_d)[:sel.size]
            dprob_np = np.asarray(dprob_d)[:sel.size]
            eval_mask[i, sel] = True
            dprob_h[i, sel] = dprob_np
            probs_h[i, sel] = probs_np
            if cfg.sample_actions:
                defer_np = u_act[i, sel] < dprob_np
            else:
                defer_np = dprob_np > 0.5
            if not budget_ok and i == nlev - 1:
                defer_np[:] = False     # budget gate: cannot reach expert
            take = sel[~defer_np]
            predictions[take] = np.argmax(probs_np[~defer_np], axis=-1)
            exit_level[take] = i
            alive[take] = False

        want = jumped | alive               # deferred past the last level
        level_costs = np.array([lvl.spec.cost for lvl in self.levels])
        cost_h = eval_mask.T @ level_costs  # sum of evaluated level costs

        # hard budget at tick granularity: first `remaining` lanes win
        called = want.copy()
        hb = cfg.hard_budget
        if hb is not None:
            remaining = max(hb - self.expert_calls_total, 0)
            if int(called.sum()) > remaining:
                idx_want = np.flatnonzero(called)
                called[idx_want[remaining:]] = False
        overflow = want & ~called

        for s in np.flatnonzero(overflow):
            # budget overflow: fall back to the last student, like the
            # reference's exhausted-budget path (rare; never at S == 1).
            # The fallback forward is real compute and is costed as an
            # evaluation of the last level, identically to the
            # sequential reference; the lane is counted as a last-level
            # exit even if it jumped earlier
            lvl = self.levels[-1]
            probs = np.asarray(lvl._predict(
                lvl.params, jnp.asarray(feats(nlev - 1)[s])))
            predictions[s] = int(np.argmax(probs))

        levels_out = np.where(called, nlev,
                              np.where(overflow, nlev - 1, exit_level))
        cost_out = (cost_h + np.where(called, cfg.expert_cost, 0.0)
                    + np.where(overflow, self.levels[-1].spec.cost, 0.0))

        y_full = np.zeros(S, np.int32)
        resolved = False
        prec = None
        if called.any():
            sel_c = np.flatnonzero(called)

            # the update only reads the called lanes' rows (others are
            # dropped by the scatter), so for levels the route never
            # featurized, hash just those k docs instead of all S
            def scatter_feats(i):
                if feats_cache[i] is not None:
                    return feats_cache[i]
                lvl = self.levels[i]
                arr = np.zeros((S,) + lvl.cache_x.shape[1:],
                               lvl.cache_x.dtype)
                for s in sel_c:
                    arr[s] = lvl.featurize(docs[s])
                feats_cache[i] = arr
                return arr

            # every annotated lane calibrates EVERY gate (core.deferral):
            # levels the route never evaluated for a called lane (DAgger
            # jumps short-circuit the walk) get probs/dprob computed at
            # route time against the tick's pre-update students — the
            # same values the synchronous engine computes after its
            # expert call (no update can land in between), and what the
            # deferred lanes' provisional predictions read from
            for i, lvl in enumerate(self.levels):
                missing = np.flatnonzero(called & ~eval_mask[i])
                if missing.size == 0:
                    continue
                (probs_d, dprob_d), _ = self._dispatch_level(
                    i, scatter_feats(i), missing)
                probs_h[i, missing] = np.asarray(probs_d)[:missing.size]
                dprob_h[i, missing] = np.asarray(dprob_d)[:missing.size]

            idxs_c = [rec.indices[s] for s in sel_c]
            docs_c = [docs[s] for s in sel_c]
            ticket = self._expert_submit(idxs_c, docs_c)
            prec = _PendingTick(
                ticket=ticket, t=t, called=called.copy(), sel_c=sel_c,
                feats=[scatter_feats(i) for i in range(nlev)],
                probs=probs_h, dprob=dprob_h, cache_rngs=cache_rngs,
                lane_cache_rngs=(
                    [rec.lane_cache[s] for s in sel_c]
                    if self.per_lane else None),
                lanes=rec.lanes,
                wall=time.time(),
                idxs=idxs_c, docs_k=docs_c)
            if self.max_delay == 0:
                # synchronous path: resolve inline — with the identical
                # op sequence as ever (bitwise parity contract).  The
                # requeue-aware resolve means a fault here heals or
                # degrades exactly like a deferred commit would; -1
                # marks an annotation dropped past max_requeues, whose
                # lane keeps the last student's provisional answer
                y_lab = self._resolve_labels(prec, 0, sel_c.size)
                y_full[sel_c] = y_lab
                predictions[sel_c] = np.where(
                    y_lab >= 0, y_lab,
                    np.argmax(probs_h[nlev - 1, sel_c], axis=-1))
                resolved = True
            else:
                # deferred lanes emit the LAST student's prediction
                # provisionally; the annotation lands max_delay ticks
                # later.  The probs are the route-time calibration
                # forwards — no extra serving compute
                predictions[sel_c] = np.argmax(
                    probs_h[nlev - 1, sel_c], axis=-1)

        if prec is not None:
            self._pending.append(prec)
        # bounded annotation delay, measured in TICKS (not in
        # expert-calling ticks): a record routed at tick u commits at the
        # end of tick u + max_delay even if no intervening tick called
        # the expert — otherwise the converged regime's trickle
        # annotations (the PR-2 beta-floor calibration signal) could be
        # starved for arbitrarily many ticks.  Blocks on the expert if it
        # is slower than max_delay ticks of student compute —
        # deterministic for any expert latency.  Per-lane mode drains on
        # the finer lanes_due sub-deadline schedule instead of whole
        # ticks at age D (see _drain_due).
        self._drain_due(t)

        # sync the observable beta to the value the dispatch-time
        # recurrence produced for this tick (see _route_dispatch — one
        # schedule, computed once)
        for lvl, b in zip(self.levels, rec.beta_after):
            lvl.beta = b

        # per-stream accounting, at the physical lanes this tick occupied
        lanes = np.arange(S) if rec.lanes is None else rec.lanes
        J_t = cfg.mu * cost_out
        self.expert_calls[lanes] += called.astype(np.int64)
        self.total_cost[lanes] += cost_out
        self.level_counts[lanes, levels_out] += 1
        self.items_seen[lanes] += 1
        self.J_cum[lanes] += J_t
        if self.history is not None:
            self.history["level"].append(levels_out.copy())
            self.history["pred"].append(predictions.astype(np.int64))
            self.history["expert_called"].append(called.copy())
            self.history["cost"].append(cost_out.copy())
            self.history["J"].append(J_t.copy())
        if _san.determinism_on() and rec.u_jump_raw is not None:
            # determinism-sanitizer trace: one record per resolved tick,
            # after this tick's due commits — a deterministic point of
            # the schedule, so traces from any worker count / pipeline
            # depth / mesh placement are comparable tick-by-tick
            _san.record_tick(
                self, t=t, level=levels_out, called=called,
                pred=predictions, u_jump=rec.u_jump_raw, u_act=u_act,
                cache_n=self._cache_n, cache_ptr=self._cache_ptr,
                levels=self.levels)
        return {
            # which stream items this tick served (pipelined callers map
            # late-resolving outputs back to their submission)
            "indices": np.asarray(rec.indices, np.int64),
            "tick": t,
            # physical lane per position (the occupancy identity when the
            # tick was submitted without lanes=)
            "lanes": lanes.copy(),
            "predictions": predictions.astype(np.int64),
            "levels": levels_out,
            "expert_called": called,
            "cost_units": cost_out,
            # annotations still in flight (max_delay >= 1) report -1;
            # they land at commit time, never in a tick's output
            "expert_labels": (np.where(called, y_full,
                                       np.int32(-1)).astype(np.int32)
                              if resolved else np.full(S, -1, np.int32)),
        }

    # -- commit: apply routed ticks' landed annotations ------------------
    def _drain_due(self, t: int) -> None:
        """Commit every annotation whose deadline has passed by the end
        of tick ``t``, in strict (submit-tick, lane) order.

        The queue head is drained up to its ``lanes_due`` cursor; the
        drain only advances to the next record once the head is FULLY
        committed (so a younger tick's early sub-deadlines never leapfrog
        an older tick's late ones — the deterministic global order the
        per-lane exactness contract rests on).  The head at age
        ``max_delay`` always commits fully, so the bound holds for every
        record.

        ``readiness_commits=True`` additionally commits the head
        record's lanes as soon as their annotations have LANDED (before
        their ``lanes_due`` sub-deadline): per-lane mode extends the due
        cursor by the ready prefix, per-tick mode commits the whole head
        once its ticket reports done.  FIFO (tick, lane) order is
        untouched — only commit *timing* moves, so commit age drops
        while the <= D bound and the exactly-once guarantee still hold;
        the trade is that state now evolves with annotation latency
        (the opt-in documented in the module docstring; the default
        schedule stays bitwise latency-invariant)."""
        while self._pending:
            rec = self._pending[0]
            k = rec.sel_c.size
            due = lanes_due(k, t - rec.t, self.max_delay, self.per_lane)
            if self.readiness_commits and due < k:
                due = max(due, self._ready_count(rec))
            if due > rec.committed:
                if self.per_lane:
                    for j in range(rec.committed, due):
                        self._commit_lane(rec, j, t)
                else:
                    self._commit(rec, t)
            if rec.committed < k:
                break
            self._pending.popleft()

    def _ready_count(self, rec: _PendingTick) -> int:
        """Lanes of the head record committable right now because their
        annotations already landed (readiness-commit mode).  Per-lane:
        the contiguous ready prefix from the commit cursor (a later
        ready lane still waits for earlier ones — FIFO); per-tick: all
        or nothing on whole-ticket completion.  A hung (injected
        "timeout") shard simply never reports ready — its lanes fall
        back to the deadline path, which requeues or drops."""
        k = rec.sel_c.size
        if not self.per_lane:
            return k if rec.ticket.done() else 0
        j = rec.committed
        while j < k and rec.ticket.item_done(j):
            j += 1
        return j

    def _record_commit(self, rec: _PendingTick, lanes, t: int) -> None:
        """Aggregate per-lane commit age/latency stats (and the per-lane
        commit log when enabled).  ``lanes`` are tick POSITIONS; the log
        records the physical lane each position occupied at submit, so
        readers (the admission front-end's per-stream records) can map a
        commit back to the stream that was on that lane at ``rec.t``."""
        n = len(lanes)
        self.commit_stats["lanes"] += n
        self.commit_stats["age_sum"] += n * (t - rec.t)
        self.commit_stats["age_max"] = max(self.commit_stats["age_max"],
                                           t - rec.t)
        self.commit_stats["wall_sum"] += n * (time.time() - rec.wall)
        if self.commit_log is not None:
            if rec.lanes is None:
                self.commit_log.extend((rec.t, int(s), t) for s in lanes)
            else:
                self.commit_log.extend(
                    (rec.t, int(rec.lanes[int(s)]), t) for s in lanes)

    def _commit(self, rec: _PendingTick, t: Optional[int] = None) -> None:
        """Apply a routed tick's expert annotations: ring-buffer scatter
        plus the per-tick weighted student/deferral updates, exactly the
        synchronous engine's update block replayed in FIFO tick order
        with the tick's own cache-sampling generators."""
        cfg = self.cfg
        nlev = len(self.levels)
        sel_c = rec.sel_c
        k = sel_c.size
        y_sel = self._resolve_labels(rec, 0, k)
        # -1 marks annotations dropped after max_requeues: those lanes
        # contribute no demonstration — no cache insert, zero update
        # weight, no commit record (the drop was already counted in
        # fault_stats at force-resolve time).  In a fault-free run
        # ok is all-True and this block is bitwise the original path.
        ok = y_sel >= 0
        k_ok = int(ok.sum())
        if k_ok == 0:
            rec.committed = k
            return
        called_eff = rec.called
        if k_ok < k:
            called_eff = rec.called.copy()
            called_eff[sel_c[~ok]] = False
        S = rec.called.shape[0]
        y_full = np.zeros(S, np.int32)
        y_full[sel_c] = np.maximum(y_sel, 0)

        # host mirrors first: sampling sees the post-insert fill level
        ptr_pre = np.asarray(self._cache_ptr, np.int32)
        idx_t = []
        for i, lvl in enumerate(self.levels):
            size = lvl.spec.cache_size
            self._cache_n[i] = min(self._cache_n[i] + k_ok, size)
            self._cache_ptr[i] = (self._cache_ptr[i] + k_ok) % size
            idx_t.append(jnp.asarray(sample_cache_indices(
                rec.cache_rngs[i], self._cache_n[i],
                self._bs_list[i]).astype(np.int32)))

        new_cx, new_cy = self._scatter(
            tuple(self._cache_x), tuple(self._cache_y),
            tuple(self._put_lane(rec.feats[i]) for i in range(nlev)),
            self._put_lane(y_full), self._put_lane(called_eff),
            jnp.asarray(ptr_pre))
        self._cache_x = list(new_cx)
        self._cache_y = list(new_cy)
        # batched, per-item-weighted updates through the SAME jitted
        # step callables as the sequential reference (bit-identical
        # state evolution; see module docstring)
        # reach[l] = prod_{k<l} dprob[k], float32 left fold like the
        # reference's running product
        reach = np.ones((nlev, S), np.float32)
        for i in range(1, nlev):
            reach[i] = reach[i - 1] * rec.dprob[i - 1]
        k_arr = (jnp.asarray(float(k_ok), jnp.float32)
                 if self.updates_per_tick == "scaled" and k_ok > 1 else None)
        B_c = self._bucket(k)
        for i, lvl in enumerate(self.levels):
            xb = self._cache_x[i][idx_t[i]]
            yb = self._cache_y[i][idx_t[i]]
            w = jnp.ones((self._bs_list[i],), jnp.float32)
            lvl.apply_student_update(xb, yb, w, k_arr)
            probs_b = np.zeros((B_c, cfg.n_classes), np.float32)
            probs_b[:k] = rec.probs[i, sel_c]
            y_b = np.zeros(B_c, np.int32)
            y_b[:k] = np.maximum(y_sel, 0)
            reach_b = np.zeros(B_c, np.float32)
            reach_b[:k] = reach[i, sel_c]
            w_b = np.zeros(B_c, np.float32)
            w_b[:k] = ok.astype(np.float32)
            lvl.apply_deferral_update(
                self._put_lane(probs_b), self._put_lane(y_b),
                self._put_lane(reach_b), self._put_lane(w_b), k_arr)
        rec.committed = k
        self._record_commit(rec, sel_c[ok], self.t if t is None else t)
        # params/dparams changed: any route forward dispatched before
        # this commit is stale (the pipeline's resolve checks and
        # refetches against the new state)
        self._state_version += 1

    def _commit_lane(self, rec: _PendingTick, j: int, t: int) -> None:
        """Apply ONE lane's landed annotation (per-lane commit mode).

        The sequential reference's per-item update block, replayed for
        called lane ``sel_c[j]`` of the tick routed at ``rec.t``:
        single-demonstration ring-buffer scatter into every level, one
        student step on a cache mini-batch sampled with the lane's own
        tick generators, and a single-item deferral/gate update — all
        through the same jitted callables as every other path.  Blocks
        only on the ticket shard holding item ``j`` (``result_slice``);
        earlier lanes of the record have already committed (the drain
        advances ``committed`` strictly in lane order)."""
        cfg = self.cfg
        nlev = len(self.levels)
        s = int(rec.sel_c[j])
        y = self._resolve_labels(rec, j, j + 1)
        if y[0] < 0:
            # annotation dropped past max_requeues: no demonstration to
            # apply — just advance the cursor (the drop was counted in
            # fault_stats; no commit record, no state change)
            rec.committed = j + 1
            return
        S = rec.called.shape[0]
        y_full = np.zeros(S, np.int32)
        y_full[s] = y[0]
        called_one = np.zeros(S, bool)
        called_one[s] = True
        ptr_pre = np.asarray(self._cache_ptr, np.int32)
        idx_t = []
        rngs = rec.lane_cache_rngs[j]
        for i, lvl in enumerate(self.levels):
            size = lvl.spec.cache_size
            self._cache_n[i] = min(self._cache_n[i] + 1, size)
            self._cache_ptr[i] = (self._cache_ptr[i] + 1) % size
            idx_t.append(jnp.asarray(sample_cache_indices(
                rngs[i], self._cache_n[i],
                self._bs_list[i]).astype(np.int32)))
        if rec.feats_dev is None:
            # the tick's feature rows are shared by all its per-lane
            # scatters — upload once per record, not once per lane
            rec.feats_dev = [self._put_lane(rec.feats[i])
                             for i in range(nlev)]
        new_cx, new_cy = self._scatter(
            tuple(self._cache_x), tuple(self._cache_y),
            tuple(rec.feats_dev),
            self._put_lane(y_full), self._put_lane(called_one),
            jnp.asarray(ptr_pre))
        self._cache_x = list(new_cx)
        self._cache_y = list(new_cy)
        # reach[l] = prod_{k<l} dprob[k] at this lane, float32 left fold
        # like the reference's running product
        reach = np.float32(1.0)
        B_c = self._bucket(1)
        for i, lvl in enumerate(self.levels):
            xb = self._cache_x[i][idx_t[i]]
            yb = self._cache_y[i][idx_t[i]]
            w = jnp.ones((self._bs_list[i],), jnp.float32)
            lvl.apply_student_update(xb, yb, w)
            probs_b = np.zeros((B_c, cfg.n_classes), np.float32)
            probs_b[0] = rec.probs[i, s]
            y_b = np.zeros(B_c, np.int32)
            y_b[0] = y[0]
            reach_b = np.zeros(B_c, np.float32)
            reach_b[0] = reach
            w_b = np.zeros(B_c, np.float32)
            w_b[0] = 1.0
            lvl.apply_deferral_update(
                self._put_lane(probs_b), self._put_lane(y_b),
                self._put_lane(reach_b), self._put_lane(w_b))
            reach = np.float32(reach * np.float32(rec.dprob[i, s]))
        rec.committed = j + 1
        self._record_commit(rec, [s], t)
        self._state_version += 1

    def flush(self) -> int:
        """Drain the deferred-annotation queue (blocking): apply every
        routed tick's pending updates.  Called by ``run`` at stream end;
        servers should call it before checkpointing or idling.  Returns
        the number of ticks committed.

        The route ring must be empty first (``drain()`` — whose outputs
        the caller needs anyway): committing annotations while ticks are
        still in flight would land updates out of FIFO tick order and
        break the pipelined exactness contract."""
        if self._ring:
            raise RuntimeError(
                "route pipeline has in-flight ticks: drain() them "
                "(and consume their outputs) before flush()")
        n = 0
        while self._pending:
            rec = self._pending.popleft()
            if self.per_lane:
                for j in range(rec.committed, rec.sel_c.size):
                    self._commit_lane(rec, j, self.t)
            else:
                self._commit(rec, self.t)
            n += 1
        return n

    # -- live-state checkpointing (ARCHITECTURE.md §10) ------------------
    def _fingerprint(self) -> dict:
        """Config facts a checkpoint must agree on to be restorable."""
        return {
            "engine": "batched", "ckpt_version": _CKPT_VERSION,
            "n_streams": self.n_streams, "n_levels": len(self.levels),
            "max_delay": self.max_delay, "per_lane": self.per_lane,
            "updates_per_tick": self.updates_per_tick,
            "seed": self.cfg.seed, "n_classes": self.cfg.n_classes,
        }

    def save_state(self, path: str) -> str:
        """Checkpoint the engine's full live state mid-stream.

        Captures per-level STATE_ATTRS (params, optimizer state,
        deferral MLPs) and gates (betas), the demonstration ring
        buffers, per-lane accounting, the route-time beta/item
        recurrence, commit stats/log, fault + fleet stats, and the
        pending deferred-annotation queue — including each pending
        record's exact mid-consumption cache-generator states, so a
        restored engine replays the remaining commits with the very
        draws the uninterrupted run would use (the bitwise resume
        contract, tests/test_checkpoint.py).  Uncommitted annotations
        are resolved here (blocking, under the requeue/timeout
        discipline) so the checkpoint never holds an unresolvable
        ticket.  The route ring must be drained first, like ``flush``.
        """
        if self._ring:
            raise RuntimeError(
                "route pipeline has in-flight ticks: drain() them "
                "(and consume their outputs) before save_state()")
        from repro.checkpoint import save_checkpoint
        nlev = len(self.levels)
        tree = {
            "levels": [lvl.state_tree() for lvl in self.levels],
            "cache_x": [np.asarray(jax.device_get(x))
                        for x in self._cache_x],
            "cache_y": [np.asarray(jax.device_get(y))
                        for y in self._cache_y],
            "acct": {
                "expert_calls": self.expert_calls,
                "total_cost": self.total_cost,
                "level_counts": self.level_counts,
                "items_seen": self.items_seen,
                "J_cum": self.J_cum,
            },
        }
        pending_meta = []
        for r_i, rec in enumerate(list(self._pending)):
            k = rec.sel_c.size
            labels = np.full(k, -1, np.int32)
            if rec.committed < k:
                labels[rec.committed:] = self._resolve_labels(
                    rec, rec.committed, k)
            entry = {
                "called": rec.called, "sel_c": rec.sel_c,
                "labels": labels, "probs": rec.probs, "dprob": rec.dprob,
                "feats": list(rec.feats),
                "idxs": np.asarray(rec.idxs or [], np.int64),
            }
            if rec.lanes is not None:
                entry["lanes"] = rec.lanes
            tree[f"pending{r_i}"] = entry
            pending_meta.append({
                "t": rec.t, "committed": rec.committed,
                "has_lanes": rec.lanes is not None,
                "requeues": {str(lo): n
                             for lo, n in rec.requeues.items()},
                "cache_rngs": [generator_state(g)
                               for g in rec.cache_rngs],
                "lane_cache_rngs": (
                    [[generator_state(g) for g in lane]
                     for lane in rec.lane_cache_rngs]
                    if rec.lane_cache_rngs is not None else None),
            })
        meta = {
            **self._fingerprint(),
            "t": self.t,
            "beta": [float(lvl.beta) for lvl in self.levels],
            "cache_n": list(self._cache_n),
            "cache_ptr": list(self._cache_ptr),
            "route_beta": [float(b) for b in self._route_beta],
            "route_items": self._route_items,
            "commit_stats": {"lanes": self.commit_stats["lanes"],
                             "age_sum": self.commit_stats["age_sum"],
                             "age_max": self.commit_stats["age_max"],
                             "wall_sum": self.commit_stats["wall_sum"]},
            "commit_log": ([list(e) for e in self.commit_log]
                           if self.commit_log is not None else None),
            "pipeline_stats": dict(self.pipeline_stats),
            "fault_stats": dict(self.fault_stats),
            "fleet_log": [list(e) for e in self.fleet_log],
            "n_pending": len(self._pending),
            "pending": pending_meta,
        }
        assert nlev == len(tree["levels"])
        return save_checkpoint(path, tree, meta)

    def restore_state(self, path: str) -> None:
        """Restore a ``save_state`` checkpoint into this (freshly
        constructed, same-config) engine; raises ``CheckpointError`` on
        a config mismatch.  The resumed run is bitwise identical to the
        uninterrupted one from the checkpoint tick onward."""
        from repro.checkpoint import CheckpointError, restore_checkpoint
        tree, meta = restore_checkpoint(path)
        for key, val in self._fingerprint().items():
            if meta.get(key) != val:
                raise CheckpointError(
                    f"checkpoint/engine mismatch on {key}: checkpoint "
                    f"has {meta.get(key)!r}, engine has {val!r}")
        for lvl, st, b in zip(self.levels, tree["levels"], meta["beta"]):
            lvl.load_state_tree(st, put=self._put_rep)
            lvl.beta = float(b)
        self._cache_x = [self._put_rep(np.asarray(x))
                         for x in tree["cache_x"]]
        self._cache_y = [self._put_rep(np.asarray(y))
                         for y in tree["cache_y"]]
        self._cache_n = [int(v) for v in meta["cache_n"]]
        self._cache_ptr = [int(v) for v in meta["cache_ptr"]]
        acct = tree["acct"]
        self.expert_calls[:] = np.asarray(acct["expert_calls"])
        self.total_cost[:] = np.asarray(acct["total_cost"])
        self.level_counts[:] = np.asarray(acct["level_counts"])
        self.items_seen[:] = np.asarray(acct["items_seen"])
        self.J_cum[:] = np.asarray(acct["J_cum"])
        self.t = int(meta["t"])
        self._route_beta = [float(b) for b in meta["route_beta"]]
        self._route_items = int(meta["route_items"])
        cs = meta["commit_stats"]
        self.commit_stats = {"lanes": int(cs["lanes"]),
                             "age_sum": int(cs["age_sum"]),
                             "age_max": int(cs.get("age_max", 0)),
                             "wall_sum": float(cs["wall_sum"])}
        self.commit_log = ([tuple(e) for e in meta["commit_log"]]
                           if meta["commit_log"] is not None else None)
        self.pipeline_stats = {k: int(v)
                               for k, v in meta["pipeline_stats"].items()}
        self.fault_stats = {k: int(v)
                            for k, v in meta["fault_stats"].items()}
        self.fleet_log = [tuple(int(x) for x in e)
                          for e in meta["fleet_log"]]
        self._pending.clear()
        for r_i, pm in enumerate(meta["pending"]):
            pt = tree[f"pending{r_i}"]
            self._pending.append(_PendingTick(
                # the ticket was resolved at save time (labels hold the
                # -1 sentinel where annotations were dropped), so the
                # restored record never needs docs for a requeue
                ticket=ExpertTicket(
                    labels=np.asarray(pt["labels"], np.int32)),
                t=int(pm["t"]),
                called=np.asarray(pt["called"], bool),
                sel_c=np.asarray(pt["sel_c"], np.int64),
                feats=[np.asarray(f) for f in pt["feats"]],
                probs=np.asarray(pt["probs"], np.float32),
                dprob=np.asarray(pt["dprob"], np.float32),
                cache_rngs=[generator_from_state(s)
                            for s in pm["cache_rngs"]],
                committed=int(pm["committed"]),
                lane_cache_rngs=(
                    [[generator_from_state(s) for s in lane]
                     for lane in pm["lane_cache_rngs"]]
                    if pm["lane_cache_rngs"] is not None else None),
                lanes=(np.asarray(pt["lanes"], np.int64)
                       if pm["has_lanes"] else None),
                wall=time.time(),
                idxs=[int(i) for i in np.asarray(pt["idxs"])],
                docs_k=None,
                requeues={int(lo): int(n)
                          for lo, n in pm["requeues"].items()}))
        # restored params invalidate anything dispatched before (there
        # is nothing in flight, but a later pipelined dispatch must not
        # compare equal to a pre-restore version)
        self._state_version += 1

    # -- per-stream metrics ---------------------------------------------
    def stream_metrics(self) -> dict:
        """Independent per-lane accounting (S rows each)."""
        seen = np.maximum(self.items_seen, 1)[:, None]
        return {
            "expert_calls": self.expert_calls.copy(),
            "items_seen": self.items_seen.copy(),
            "level_fractions": self.level_counts / seen,
            "total_cost_units": self.total_cost.copy(),
            "J_cum": self.J_cum.copy(),
        }

    # -- conveniences ----------------------------------------------------
    def run(self, stream, log_every: int = 0,
            checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None) -> dict:
        """Serve an entire stream, tick-major: tick T covers items
        [T*S, T*S + S) with lane s = offset.  Returns OnlineCascade-style
        summary metrics plus throughput and per-stream accounting.

        With ``pipeline_depth >= 1`` the loop drives
        ``submit_tick``/``drain`` — results land up to P ticks after
        submission and are mapped back through each output's "indices";
        with depth 0 it is the classic one-``process_tick``-per-tick
        loop.

        ``checkpoint_every=k`` saves live state to ``checkpoint_path``
        every k ticks (draining the route ring first — save_state's
        precondition).  On an engine that already holds restored state
        (``restore_state``), serving resumes at item ``self.t * S`` —
        the tick-major identity — and metrics cover the items this call
        served."""
        S = self.n_streams
        n = len(stream)
        preds = np.zeros(n, np.int32)
        done = 0                      # items with results already landed
        first = self.t * S            # 0 on a fresh engine; resume point
                                      # on a restored one

        def take(out):
            nonlocal done
            idxs = out["indices"]
            preds[idxs] = out["predictions"]
            done = max(done, int(idxs.max()) + 1) if idxs.size else done

        t0 = time.time()
        for start in range(first, n, S):
            stop = min(start + S, n)
            idxs = list(range(start, stop))
            docs = [stream.docs[i] for i in idxs]
            if self.pipeline_depth:
                for out in self.submit_tick(idxs, docs):
                    take(out)
            else:
                take(self.process_tick(idxs, docs))
            if (log_every and done
                    and (stop // log_every) > (start // log_every)):
                lo = min(first, done)
                acc = float(np.mean(preds[lo:done]
                                    == stream.labels[lo:done]))
                print(f"[{done}/{n}] acc={acc:.4f} "
                      f"expert_calls={self.expert_calls_total}")
            if (checkpoint_every and checkpoint_path
                    and self.t % checkpoint_every == 0 and stop < n):
                for out in self.drain():
                    take(out)
                self.save_state(checkpoint_path)
        for out in self.drain():
            take(out)
        self.flush()
        dt = time.time() - t0
        labels = stream.labels
        served = n - first
        acc = float(np.mean(preds[first:] == labels[first:]))
        metrics = {
            "accuracy": acc,
            "expert_calls": self.expert_calls_total,
            "total_cost_units": float(self.total_cost.sum()),
            "level_fractions": (self.level_counts.sum(axis=0)
                                / max(n, 1)).tolist(),
            "predictions": preds,
            "items_per_sec": served / max(dt, 1e-9),
            "per_stream": self.stream_metrics(),
        }
        return metrics
