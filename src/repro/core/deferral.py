"""Learned deferral functions f_i (paper §3, Confidence Calibration).

Each f_i is a small MLP over the level's predictive distribution.  Inputs
are permutation-robust features of m_i(x_t): the *sorted* probability
vector, the max probability, and the normalized entropy.  Output is a
deferral probability in (0, 1).

Training combines two signals (both via OGD, Eq. 5 + Eq. 1):
  * calibration MSE:  L(f_i(m_i(x)), z_i),  z_i = 1[argmax m_i(x) != y*]
    — only on expert-annotated queries (paper: "calibration is only
    performed on those input queries where the expert LLM is invoked").
  * MDP cost gradient:  dJ/df_i = p_reach_i * (mu * c_{i+1} - L_i)
    — pushes the gate open when deferral is cheaper than the expected
    prediction loss, closed otherwise.

The per-level ``calibration_factor`` (paper App. B.3, Tables 3/4) blends
the two: grad = cf * grad_MSE + (1 - cf) * grad_J.

The final bias is initialized positive so gates start open ("at startup,
the policy keeps its gates open, allowing all initial inputs to flow
through the cascade" — §1).

Re-exploration (beta floor)
---------------------------
Calibration only sees expert-annotated queries, which creates a feedback
loop once a gate starts closing: the only items still annotated are the
ones the gate *chose* to defer — the hard cases, where z is mostly 1 —
so the gate is pushed back open, while the easy majority that would pull
it shut is never annotated again ("From Deferral to Learning", Wu et al.
2025: cascades must keep learning after deferral stops).  The fix has two
halves, shared by both engines:

  * a decaying DAgger floor (``reexploration_floor``): the jump
    probability never falls below ``beta_floor / sqrt(t)``, so an
    *unbiased* trickle of expert annotations keeps flowing forever.  The
    floor adds O(sqrt(T)) exploration cost over T items — a vanishing
    average, so Theorem 3.2's no-regret guarantee is preserved;
  * every annotated item calibrates **every** gate (jump-annotated items
    included), not just the gates the item's walk happened to consult —
    otherwise the trickle never reaches the gates at all.
"""
from __future__ import annotations

from dataclasses import dataclass
from math import sqrt

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DeferralSpec:
    """Deferral-MLP shape: input class count, hidden width, init."""

    n_classes: int
    hidden: int = 32
    init_open: float = 2.0       # initial logit -> sigmoid(2.0) ~ 0.88


def reexploration_floor(beta_floor: float, t: int) -> float:
    """Minimum DAgger jump probability after ``t`` consumed items.

    ``beta_t = max(beta_t-1 * decay, reexploration_floor(floor0, t))``
    keeps a decaying trickle of unbiased expert annotations flowing after
    the exponential DAgger schedule has effectively hit zero, so the
    deferral gates never freeze in their last calibrated state (see
    module docstring).  The 1/sqrt(t) decay costs O(sqrt(T)) extra expert
    calls over T items — asymptotically free in average regret.
    """
    return beta_floor / sqrt(max(t, 1))


def _features(probs: jax.Array) -> jax.Array:
    """probs: (..., C) -> permutation-robust features (..., C+2)."""
    p = jnp.clip(probs, 1e-9, 1.0)
    sorted_p = jnp.sort(p, axis=-1)[..., ::-1]
    ent = -jnp.sum(p * jnp.log(p), axis=-1, keepdims=True) \
        / jnp.log(p.shape[-1])
    mx = jnp.max(p, axis=-1, keepdims=True)
    return jnp.concatenate([sorted_p, mx, ent], axis=-1)


def deferral_init(key, spec: DeferralSpec):
    """Initialize f_i's MLP params (final bias starts the gate open)."""
    d_in = spec.n_classes + 2
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d_in, spec.hidden)) * (d_in ** -0.5),
        "b1": jnp.zeros((spec.hidden,)),
        "w2": jax.random.normal(k2, (spec.hidden, 1)) * (spec.hidden ** -0.5),
        "b2": jnp.full((1,), spec.init_open),
    }


def deferral_logit(params, probs):
    """Pre-sigmoid deferral score for a (..., C) batch of probs."""
    h = jnp.tanh(_features(probs) @ params["w1"] + params["b1"])
    return (h @ params["w2"] + params["b2"])[..., 0]


def deferral_prob(params, probs):
    """Deferral probability f_i(probs) in (0, 1), batched."""
    return jax.nn.sigmoid(deferral_logit(params, probs))


def deferral_update_terms(probs, y, mu_defer_cost):
    """In-graph inputs for the deferral update, shared by both engines.

    probs: (B, C) float32; y: (B,) int expert labels; mu_defer_cost:
    scalar mu * c_{i+1}.  Returns (z, mcl) with z the error indicator
    1[argmax(probs) != y] and mcl = mu * c_{i+1} - L_i where
    L_i = -log p_i(y).  Computing these in float32 inside the jitted step
    (instead of host float64) is what keeps the sequential reference and
    the batched engine bit-identical.
    """
    pred = jnp.argmax(probs, axis=-1)
    z = (pred != y).astype(jnp.float32)
    p_y = jnp.take_along_axis(probs, y[:, None], axis=-1)[:, 0]
    mcl = mu_defer_cost - (-jnp.log(jnp.maximum(p_y, 1e-9)))
    return z, mcl


def deferral_loss_weighted(params, probs, z, reach, mu_cost_minus_loss, w,
                           calibration_factor: float):
    """Combined per-sample objective (Eq. 5 + Eq. 1), per-item weighted.

    probs: (B, C); z: (B,) error indicators; reach: (B,) p_reach_i;
    mu_cost_minus_loss: (B,) = mu * c_{i+1} - L_i (fixed, no grad);
    w: (B,) weights (1 for items that reached this level with an expert
    annotation, 0 otherwise).  With w == ones(1) this reduces bitwise to
    the unweighted single-item objective (sum/1 == mean over one item).
    """
    f = deferral_prob(params, probs)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    mse = jnp.sum(w * jnp.square(f - z)) / denom
    cost = jnp.sum(w * reach * f * mu_cost_minus_loss) / denom
    cf = calibration_factor
    return cf * mse + (1.0 - cf) * cost


deferral_grads_weighted = jax.grad(deferral_loss_weighted)
