"""The paper's episodic MDP (§2): states <x_t, i>, actions Y + defer.

``episode_cost`` evaluates Eq. (1)'s inner sum for one episode given the
per-level deferral probabilities and prediction losses:

  J_t(pi) = sum_i p_pi^{s_{t,i}} * C_pi(s_{t,i})
  C_pi(s_i) = f_i * mu * c_{i+1} + (1 - f_i) * L(pred_i | y_t)

with p_pi^{s_i} = prod_{j<i} f_j (probability of reaching level i).
The final level (the expert) never defers: f_N = 0 by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def episode_cost(defer_probs: jax.Array, pred_losses: jax.Array,
                 defer_costs: jax.Array, mu: float) -> jax.Array:
    """Eq. (1) inner term for one episode.

    defer_probs: (N,) with defer_probs[-1] == 0 (expert outputs).
    pred_losses: (N,) prediction loss L(a_i | y_t) at each level.
    defer_costs: (N,) penalty c_{i+1} paid when deferring *from* level i
                 (last entry unused).
    """
    n = defer_probs.shape[0]
    reach = jnp.concatenate(
        [jnp.ones((1,), defer_probs.dtype),
         jnp.cumprod(defer_probs[:-1])])
    immediate = defer_probs * mu * defer_costs \
        + (1.0 - defer_probs) * pred_losses
    return jnp.sum(reach * immediate), reach


def policy_value(defer_probs_seq: jax.Array, pred_losses_seq: jax.Array,
                 defer_costs: jax.Array, mu: float) -> jax.Array:
    """J(pi, T): Eq. (1) summed over T episodes (batched episode_cost)."""
    costs, _ = jax.vmap(
        lambda fs, ls: episode_cost(fs, ls, defer_costs, mu))(
            defer_probs_seq, pred_losses_seq)
    return jnp.sum(costs)
