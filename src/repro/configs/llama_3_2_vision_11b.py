"""llama-3.2-vision-11b — VLM with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

Assigned spec: 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256,
cross-attention image layers every 5th layer (8 of 40).  The ViT vision
encoder + projector is the sanctioned stub — ``input_specs`` supplies
precomputed patch embeddings (batch, n_image_tokens, d_model).
"""
from repro.configs.base import ATTN, CROSS, AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        d_ff=14336,
        vocab=128256,
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                        rope_theta=500_000.0),
        period=(ATTN, ATTN, ATTN, ATTN, CROSS),
        vision_stub=True,
        n_image_tokens=1600,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    ),
    smoke=ModelConfig(
        name="llama-3.2-vision-11b-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        rope_theta=500_000.0),
        period=(ATTN, CROSS),
        vision_stub=True,
        n_image_tokens=16,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    ),
)
