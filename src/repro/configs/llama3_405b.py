"""llama3-405b — dense GQA with 128k vocab [arXiv:2407.21783].

Assigned spec: 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import ATTN, AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama3-405b",
        family="dense",
        n_layers=126,
        d_model=16384,
        d_ff=53248,
        vocab=128256,
        attn=AttnConfig(n_heads=128, n_kv_heads=8, head_dim=128,
                        rope_theta=500_000.0),
        period=(ATTN,),
        source="arXiv:2407.21783",
    ),
    smoke=ModelConfig(
        name="llama3-405b-smoke",
        family="dense",
        n_layers=2,
        d_model=256,
        d_ff=512,
        vocab=512,
        attn=AttnConfig(n_heads=8, n_kv_heads=2, head_dim=32,
                        rope_theta=500_000.0),
        period=(ATTN,),
        source="arXiv:2407.21783",
    ),
)
