"""Unified model configuration for the repro model zoo.

Every assigned architecture is expressed as a ``ModelConfig``: a repeating
``period`` of block kinds (dense = 1-block period; jamba = 8-block period with
7 mamba + 1 attention; vlm = 5-block period with a trailing cross-attention
block), scanned ``n_periods`` times.  This keeps the lowered HLO small enough
that 80 AOT compiles on one CPU core are tractable, and mirrors how real
hybrids (Jamba) describe themselves.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

# Block kinds usable inside a period.
ATTN = "attn"            # self-attention (causal unless encoder)
MAMBA = "mamba"          # Mamba2 / SSD block
CROSS = "cross"          # self-attention + cross-attention (enc-dec / VLM)


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    window: Optional[int] = None          # sliding-window size; None = full
    rope_theta: float = 500_000.0
    causal: bool = True


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    load_balance_weight: float = 0.01
    router_z_weight: float = 1e-3
    # 'tensor'  : experts replicated, d_ff_expert sharded over 'model'
    # 'expert'  : expert dim sharded over 'model' (requires divisibility)
    sharding_mode: str = "tensor"
    # 'gshard'  : one-hot capacity dispatch einsums (dense, GSPMD friendly)
    # 'ragged'  : sort + lax.ragged_dot grouped matmul (lower dispatch FLOPs)
    dispatch_mode: str = "gshard"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (seamless-m4t).

    The modality frontend (mel-spectrogram + conv feature extractor) is a
    sanctioned stub: ``input_specs`` provides precomputed frame embeddings of
    shape (batch, frames, d_model).
    """
    n_layers: int = 12
    frontend: str = "audio"  # 'audio' (frame embeddings) | 'text'


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Repeating block pattern; len(period) must divide n_layers.
    period: Tuple[str, ...] = (ATTN,)
    # Indices within the period whose FFN is MoE (others use dense MLP).
    moe_period_idx: Tuple[int, ...] = ()
    encoder: Optional[EncoderConfig] = None
    # VLM: patch-embedding stub frontend (precomputed patch embeddings).
    vision_stub: bool = False
    n_image_tokens: int = 1024
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Sliding-window override applied to *full-attention* layers for the
    # long_500k shape (assignment-sanctioned sub-quadratic variant).
    long_context_window: int = 8192
    source: str = ""                 # citation

    def __post_init__(self):
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period {len(self.period)}")

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def with_window(self, window: int) -> "ModelConfig":
        """Return a copy whose attention layers use a sliding window."""
        if self.attn is None:
            return self
        return dataclasses.replace(
            self, attn=dataclasses.replace(self.attn, window=window))

    def param_count(self) -> int:
        """Total parameters (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += v * d                 # lm head
        per_period = 0
        for i, kind in enumerate(self.period):
            if kind in (ATTN, CROSS):
                a = self.attn
                qkv = d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim
                out = a.n_heads * a.head_dim * d
                per_period += qkv + out
                if kind == CROSS:          # second attention projection set
                    per_period += qkv + out
            elif kind == MAMBA:
                s = self.ssm
                d_in = s.expand * d
                n_h = d_in // s.head_dim
                # in_proj -> [z, x, B, C, dt], conv, A, D, out_proj
                per_period += d * (2 * d_in + 2 * s.d_state + n_h)
                per_period += s.d_conv * (d_in + 2 * s.d_state)
                per_period += 2 * n_h
                per_period += d_in * d
            # FFN
            if i in self.moe_period_idx and self.moe is not None:
                m = self.moe
                per_period += m.num_experts * (3 * d * m.d_ff_expert)
                per_period += d * m.num_experts          # router
            elif f > 0:
                n_mats = 3 if self.act == "swiglu" else 2
                per_period += n_mats * d * f
            per_period += 2 * d                          # norms
        total += per_period * self.n_periods
        if self.encoder is not None:
            # encoder blocks: self-attn + ffn
            a = self.attn
            enc_block = (d * a.n_heads * a.head_dim
                         + 2 * d * a.n_kv_heads * a.head_dim
                         + a.n_heads * a.head_dim * d
                         + (3 if self.act == "swiglu" else 2) * d * f + 2 * d)
            total += enc_block * self.encoder.n_layers
        return total

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive_per_moe_layer = (m.num_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        n_moe_layers = len(self.moe_period_idx) * self.n_periods
        return self.param_count() - inactive_per_moe_layer * n_moe_layers


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(config: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[config.name] = (config, smoke)
    return config


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name][0]


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name][1]


def list_architectures() -> list:
    _load_all()
    return sorted(_REGISTRY.keys())


_ARCH_MODULES = [
    "seamless_m4t_medium", "mixtral_8x22b", "jamba_1_5_large_398b",
    "internlm2_1_8b", "h2o_danube_3_4b", "llama_3_2_vision_11b",
    "qwen3_8b", "llama3_405b", "mamba2_370m", "dbrx_132b",
]


def _load_all():
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
