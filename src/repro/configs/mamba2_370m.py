"""mamba2-370m — attention-free SSM via state-space duality [arXiv:2405.21060].

Assigned spec: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128.  Pure Mamba2 blocks (no FFN), tied embeddings.
"""
from repro.configs.base import MAMBA, ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        d_ff=0,
        vocab=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        period=(MAMBA,),
        tie_embeddings=True,
        source="arXiv:2405.21060",
    ),
    smoke=ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        d_ff=0,
        vocab=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        period=(MAMBA,),
        tie_embeddings=True,
        source="arXiv:2405.21060",
    ),
)
