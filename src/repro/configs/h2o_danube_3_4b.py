"""h2o-danube-3-4b — dense GQA, llama+mistral mix with SWA [arXiv:2401.16818].

Assigned spec: 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, SWA.
head_dim = 3840/32 = 120 (not MXU-128 aligned — kept faithful; kernels pad
the head dim to 128 inside VMEM tiles).
"""
from repro.configs.base import ATTN, AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        d_ff=10240,
        vocab=32000,
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=120,
                        window=4096, rope_theta=10_000.0),
        period=(ATTN,),
        source="arXiv:2401.16818",
    ),
    smoke=ModelConfig(
        name="h2o-danube-3-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        window=64, rope_theta=10_000.0),
        period=(ATTN,),
        source="arXiv:2401.16818",
    ),
)
