"""internlm2-1.8b — dense GQA [arXiv:2403.17297].

Assigned spec: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from repro.configs.base import ATTN, AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        n_layers=24,
        d_model=2048,
        d_ff=8192,
        vocab=92544,
        attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=128,
                        rope_theta=1_000_000.0),
        period=(ATTN,),
        source="arXiv:2403.17297",
    ),
    smoke=ModelConfig(
        name="internlm2-1.8b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        d_ff=512,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        rope_theta=1_000_000.0),
        period=(ATTN,),
        source="arXiv:2403.17297",
    ),
)
