from repro.configs.base import (
    ATTN, CROSS, MAMBA,
    AttnConfig, EncoderConfig, MoEConfig, ModelConfig, SSMConfig,
    get_config, get_smoke_config, list_architectures, register,
)

__all__ = [
    "ATTN", "CROSS", "MAMBA",
    "AttnConfig", "EncoderConfig", "MoEConfig", "ModelConfig", "SSMConfig",
    "get_config", "get_smoke_config", "list_architectures", "register",
]
