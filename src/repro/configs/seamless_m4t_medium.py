"""seamless-m4t-medium — enc-dec multimodal (audio) [arXiv:2308.11596].

Assigned spec: 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.
We interpret "12L" as 12 encoder + 12 decoder layers (the M4T text decoder is
symmetric with its speech encoder); the conformer/mel frontend is the
sanctioned stub — ``input_specs`` supplies precomputed frame embeddings.
Decoder layers carry self + cross attention (CROSS block kind).
"""
from repro.configs.base import (
    CROSS, AttnConfig, EncoderConfig, ModelConfig, register)

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        n_layers=12,
        d_model=1024,
        d_ff=4096,
        vocab=256206,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=64,
                        rope_theta=10_000.0),
        period=(CROSS,),
        encoder=EncoderConfig(n_layers=12, frontend="audio"),
        norm="layernorm",
        act="gelu",
        source="arXiv:2308.11596",
    ),
    smoke=ModelConfig(
        name="seamless-m4t-medium-smoke",
        family="encdec",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32,
                        rope_theta=10_000.0),
        period=(CROSS,),
        encoder=EncoderConfig(n_layers=2, frontend="audio"),
        norm="layernorm",
        act="gelu",
        source="arXiv:2308.11596",
    ),
)
