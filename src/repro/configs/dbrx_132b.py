"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base].

Assigned spec: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4.  16 experts divide the 16-way model axis, so this arch
is the natural candidate for expert-parallel sharding (see §Perf).
"""
from repro.configs.base import ATTN, AttnConfig, MoEConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        d_ff=10752,
        vocab=100352,
        attn=AttnConfig(n_heads=48, n_kv_heads=8, head_dim=128,
                        rope_theta=500_000.0),
        moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
        period=(ATTN,),
        moe_period_idx=(0,),
        norm="layernorm",
        source="hf:databricks/dbrx-base",
    ),
    smoke=ModelConfig(
        name="dbrx-132b-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        rope_theta=500_000.0),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256),
        period=(ATTN,),
        moe_period_idx=(0,),
        norm="layernorm",
        source="hf:databricks/dbrx-base",
    ),
)
