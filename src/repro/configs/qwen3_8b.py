"""qwen3-8b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B].

Assigned spec: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936,
qk_norm, GQA.
"""
from repro.configs.base import ATTN, AttnConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        d_ff=12288,
        vocab=151936,
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                        qk_norm=True, rope_theta=1_000_000.0),
        period=(ATTN,),
        source="hf:Qwen/Qwen3-8B",
    ),
    smoke=ModelConfig(
        name="qwen3-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        qk_norm=True, rope_theta=1_000_000.0),
        period=(ATTN,),
        source="hf:Qwen/Qwen3-8B",
    ),
)
