"""jamba-1.5-large-398b — hybrid Mamba+attention with MoE [arXiv:2403.19887].

Assigned spec: 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
MoE 16 experts top-2, Mamba:attn 1:7 interleave.  We model the Jamba period
as 8 blocks — 7 Mamba + 1 attention (index 3, mid-period as in the paper's
figure) — with MoE replacing the MLP on every other block (e=2), giving
9 periods x 8 = 72 layers and 36 MoE layers.
"""
from repro.configs.base import (
    ATTN, MAMBA, AttnConfig, MoEConfig, ModelConfig, SSMConfig, register)

_PERIOD = (MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA, MAMBA)

CONFIG = register(
    ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        d_ff=24576,
        vocab=65536,
        attn=AttnConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                        rope_theta=1_000_000.0),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        period=_PERIOD,
        moe_period_idx=(1, 3, 5, 7),
        source="arXiv:2403.19887",
    ),
    smoke=ModelConfig(
        name="jamba-1.5-large-398b-smoke",
        family="hybrid",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        rope_theta=1_000_000.0),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        period=(MAMBA, ATTN),
        moe_period_idx=(1,),
        source="arXiv:2403.19887",
    ),
)
