"""mixtral-8x22b — sparse MoE with sliding-window attention [arXiv:2401.04088].

Assigned spec: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, SWA.  Every layer's FFN is MoE (Mixtral style).
"""
from repro.configs.base import ATTN, AttnConfig, MoEConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        d_ff=16384,
        vocab=32768,
        attn=AttnConfig(n_heads=48, n_kv_heads=8, head_dim=128,
                        window=4096, rope_theta=1_000_000.0),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
        period=(ATTN,),
        moe_period_idx=(0,),
        source="arXiv:2401.04088",
    ),
    smoke=ModelConfig(
        name="mixtral-8x22b-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        window=64, rope_theta=1_000_000.0),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256),
        period=(ATTN,),
        moe_period_idx=(0,),
        source="arXiv:2401.04088",
    ),
)
