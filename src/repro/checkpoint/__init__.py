"""Checkpoint save/restore for zoo model params and live engine state."""
from repro.checkpoint.ckpt import (CheckpointError, restore_checkpoint,
                                   save_checkpoint)

__all__ = ["save_checkpoint", "restore_checkpoint", "CheckpointError"]
