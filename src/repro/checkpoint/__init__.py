"""Checkpoint save/restore for zoo model params and train state."""
from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint

__all__ = ["save_checkpoint", "restore_checkpoint"]
