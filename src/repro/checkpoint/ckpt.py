"""Pytree checkpointing (npz + json manifest; no orbax offline).

Arrays are gathered to host (sharded arrays are fully addressable on the
single-process dry-run meshes) and stored flat; the manifest preserves tree
structure, dtypes, and user metadata (step counters, config name, ...).

The engines build on this for live-state checkpointing (ARCHITECTURE.md
§10): ``BatchedCascadeEngine.save_state`` / ``restore_state`` serialize
their full pytree of learned + queue state here and keep the non-array
live state (RNG generator states, commit cursors, stats) in ``metadata``.
"""
from __future__ import annotations

import json
import os
import tempfile
import zipfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


class CheckpointError(RuntimeError):
    """A checkpoint is missing, corrupted, or written for another config."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(f"#{p.idx}")
            else:
                parts.append(str(p))
        flat[_SEP.join(parts)] = leaf
    return flat


def _part_order(part: str):
    # list indices must sort numerically: "#10" comes after "#9", not
    # between "#1" and "#2" as a lexicographic sort would place it
    if part.startswith("#"):
        return (1, int(part[1:]), "")
    return (0, 0, part)


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Any = None

    def insert(node, parts, value):
        head = parts[0]
        is_idx = head.startswith("#")
        key = int(head[1:]) if is_idx else head
        if len(parts) == 1:
            if is_idx:
                while len(node) <= key:
                    node.append(None)
                node[key] = value
            else:
                node[key] = value
            return node
        nxt_idx = parts[1].startswith("#")
        if is_idx:
            while len(node) <= key:
                node.append(None)
            if node[key] is None:
                node[key] = [] if nxt_idx else {}
            insert(node[key], parts[1:], value)
        else:
            if key not in node:
                node[key] = [] if nxt_idx else {}
            insert(node[key], parts[1:], value)
        return node

    for k in sorted(flat.keys(),
                    key=lambda s: tuple(_part_order(p) for p in s.split(_SEP))):
        parts = k.split(_SEP)
        if root is None:
            root = [] if parts[0].startswith("#") else {}
        insert(root, parts, flat[k])
    return root


def _root_kind(tree) -> str:
    if tree is None:
        return "none"
    if isinstance(tree, (list, tuple)):
        return "list"
    if isinstance(tree, dict):
        return "dict"
    return "leaf"


def save_checkpoint(path: str, tree, metadata: Optional[dict] = None) -> str:
    """Atomically write ``tree`` (+ metadata) under ``path`` (a directory)."""
    os.makedirs(path, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    manifest = {
        "keys": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                 for k, v in flat.items()},
        "metadata": metadata or {},
        # empty trees flatten to nothing; record the container kind so an
        # empty dict restores as {} rather than None
        "root_kind": _root_kind(tree),
    }
    # NOTE: np.savez appends '.npz' unless the name already ends with it.
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    # bfloat16 is not a numpy-native dtype; store via uint16 view
    store = {}
    for k, v in flat.items():
        if v.dtype == jax.numpy.bfloat16:
            store[k] = v.view(np.uint16)
            manifest["keys"][k]["dtype"] = "bfloat16"
        else:
            store[k] = v
    np.savez(tmp, **store)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def restore_checkpoint(path: str) -> Tuple[Any, dict]:
    """Returns (tree, metadata); raises CheckpointError on damage."""
    manifest_path = os.path.join(path, "manifest.json")
    arrays_path = os.path.join(path, "arrays.npz")
    if not os.path.isfile(manifest_path):
        raise CheckpointError(f"no checkpoint manifest at {manifest_path}")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(f"corrupted manifest {manifest_path}: {e}") from e
    keys = manifest.get("keys")
    if keys:
        if not os.path.isfile(arrays_path):
            raise CheckpointError(f"manifest names arrays but {arrays_path} "
                                  "is missing (partial write?)")
        try:
            data = np.load(arrays_path)
        except (zipfile.BadZipFile, OSError, ValueError) as e:
            raise CheckpointError(
                f"corrupted array store {arrays_path}: {e}") from e
    else:
        data, keys = {}, {}
    flat = {}
    for k, info in keys.items():
        try:
            arr = data[k]
        except KeyError as e:
            raise CheckpointError(f"array {k!r} named in manifest is missing "
                                  f"from {arrays_path} (truncated?)") from e
        if info["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        flat[k] = arr
    tree = _unflatten(flat)
    if tree is None:
        kind = manifest.get("root_kind", "none")
        tree = {"dict": {}, "list": [], "none": None, "leaf": None}[kind]
    return tree, manifest["metadata"]
