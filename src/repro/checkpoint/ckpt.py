"""Pytree checkpointing (npz + json manifest; no orbax offline).

Arrays are gathered to host (sharded arrays are fully addressable on the
single-process dry-run meshes) and stored flat; the manifest preserves tree
structure, dtypes, and user metadata (step counters, config name, ...).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(f"#{p.idx}")
            else:
                parts.append(str(p))
        flat[_SEP.join(parts)] = leaf
    return flat


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Any = None

    def insert(node, parts, value):
        head = parts[0]
        is_idx = head.startswith("#")
        key = int(head[1:]) if is_idx else head
        if len(parts) == 1:
            if is_idx:
                while len(node) <= key:
                    node.append(None)
                node[key] = value
            else:
                node[key] = value
            return node
        nxt_idx = parts[1].startswith("#")
        if is_idx:
            while len(node) <= key:
                node.append(None)
            if node[key] is None:
                node[key] = [] if nxt_idx else {}
            insert(node[key], parts[1:], value)
        else:
            if key not in node:
                node[key] = [] if nxt_idx else {}
            insert(node[key], parts[1:], value)
        return node

    for k in sorted(flat.keys()):
        parts = k.split(_SEP)
        if root is None:
            root = [] if parts[0].startswith("#") else {}
        insert(root, parts, flat[k])
    return root


def save_checkpoint(path: str, tree, metadata: Optional[dict] = None) -> str:
    """Atomically write ``tree`` (+ metadata) under ``path`` (a directory)."""
    os.makedirs(path, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    manifest = {
        "keys": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                 for k, v in flat.items()},
        "metadata": metadata or {},
    }
    # NOTE: np.savez appends '.npz' unless the name already ends with it.
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    # bfloat16 is not a numpy-native dtype; store via uint16 view
    store = {}
    for k, v in flat.items():
        if v.dtype == jax.numpy.bfloat16:
            store[k] = v.view(np.uint16)
            manifest["keys"][k]["dtype"] = "bfloat16"
        else:
            store[k] = v
    np.savez(tmp, **store)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def restore_checkpoint(path: str) -> Tuple[Any, dict]:
    """Returns (tree, metadata)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for k, info in manifest["keys"].items():
        arr = data[k]
        if info["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        flat[k] = arr
    return _unflatten(flat), manifest["metadata"]
