from repro.data.streams import (
    StreamSpec, Stream, BENCHMARKS, make_stream, benchmark_spec,
)
from repro.data.features import hash_bow, hash_ids

__all__ = ["StreamSpec", "Stream", "BENCHMARKS", "make_stream",
           "benchmark_spec", "hash_bow", "hash_ids"]
