"""Simulated benchmark streams and hashing featurizers."""
from repro.data.features import hash_bow, hash_ids
from repro.data.streams import (
    BENCHMARKS, Request, Stream, StreamSpec, arrival_schedule,
    benchmark_spec, burst_requests, lockstep_requests, make_stream,
    poisson_requests)

__all__ = ["StreamSpec", "Stream", "BENCHMARKS", "make_stream",
           "benchmark_spec", "hash_bow", "hash_ids", "Request",
           "arrival_schedule", "lockstep_requests", "poisson_requests",
           "burst_requests"]
