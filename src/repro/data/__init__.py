"""Simulated benchmark streams and hashing featurizers."""
from repro.data.features import hash_bow, hash_ids
from repro.data.streams import (
    BENCHMARKS, Stream, StreamSpec, benchmark_spec, make_stream)

__all__ = ["StreamSpec", "Stream", "BENCHMARKS", "make_stream",
           "benchmark_spec", "hash_bow", "hash_ids"]
