"""Synthetic benchmark streams mirroring the paper's four datasets.

The real corpora (IMDB / HateSpeech / ISEAR / FEVER) are not available in
this offline container, so we generate seeded token streams that expose the
*same structural knobs the paper's analysis depends on* (DESIGN.md §4):

* dataset size, class count, class imbalance (HateSpeech 1:7.95),
* a **linear (bag-of-words) signal** — what logistic regression can learn,
* an **order signal** (marker-permutation encoding, BoW-invariant) — what
  only the sequence-aware tiny-transformer student can learn,
* length-correlated difficulty: longer docs dilute the signal and raise the
  simulated expert's error rate (paper Table 5),
* per-doc categories for the category-shift scenario (§5.4).

The expert LLM is simulated as ground truth + a per-dataset error rate
matched to the paper's Table 1 LLM rows, biased toward long inputs.  A real
in-repo model can replace it (core.experts.ModelExpert).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

VOCAB = 30_000
_N_CATEGORIES = 3          # the last category is withheld in the shift run
_MARKERS_PER_CLASS = 8     # marker tokens used by the order signal
_KEYWORDS_PER_CLASS = 40


@dataclass(frozen=True)
class StreamSpec:
    """Generator recipe for one benchmark stream (Table-1 statistics)."""

    name: str
    n_samples: int
    n_classes: int
    class_probs: tuple
    lr_separability: float        # per-token prob of a class-keyword token
    order_separability: float     # per-slot prob of a marker permutation
    mean_len: int
    len_sigma: float              # log-normal spread
    expert_acc: Dict[str, float]  # expert name -> paper accuracy
    length_difficulty: float = 0.5  # exponent tying expert error to length


BENCHMARKS: Dict[str, StreamSpec] = {
    # 25k balanced binary reviews; GPT-3.5 94.15 / Llama-2 93.33 (Table 1).
    "imdb": StreamSpec(
        name="imdb", n_samples=25_000, n_classes=2, class_probs=(0.5, 0.5),
        lr_separability=0.055, order_separability=0.04,
        mean_len=200, len_sigma=0.6,
        expert_acc={"gpt-3.5-turbo": 0.9415, "llama-2-70b-chat": 0.9333}),
    # 10,703 posts, 1:7.95 imbalance; GPT-3.5 83.34 / Llama-2 77.81.
    "hatespeech": StreamSpec(
        name="hatespeech", n_samples=10_703, n_classes=2,
        class_probs=(0.8883, 0.1117),
        lr_separability=0.08, order_separability=0.03,
        mean_len=80, len_sigma=0.7,
        expert_acc={"gpt-3.5-turbo": 0.8334, "llama-2-70b-chat": 0.7781}),
    # 7,666 across 7 balanced emotions; GPT-3.5 70.34 / Llama-2 68.23.
    "isear": StreamSpec(
        name="isear", n_samples=7_666, n_classes=7,
        class_probs=tuple([1 / 7] * 7),
        lr_separability=0.030, order_separability=0.05,
        mean_len=40, len_sigma=0.5,
        expert_acc={"gpt-3.5-turbo": 0.7034, "llama-2-70b-chat": 0.6823}),
    # 6,512 claims, binary, reasoning-heavy: LR ~ chance, TF learnable.
    "fever": StreamSpec(
        name="fever", n_samples=6_512, n_classes=2, class_probs=(0.5, 0.5),
        lr_separability=0.006, order_separability=0.10,
        mean_len=30, len_sigma=0.4,
        expert_acc={"gpt-3.5-turbo": 0.7998, "llama-2-70b-chat": 0.7715}),
}


def benchmark_spec(name: str) -> StreamSpec:
    """The committed :data:`BENCHMARKS` spec for dataset ``name``."""
    return BENCHMARKS[name]


@dataclass
class Stream:
    """A generated document stream plus its cached expert annotations."""

    spec: StreamSpec
    docs: List[np.ndarray]
    labels: np.ndarray            # ground truth
    categories: np.ndarray
    lengths: np.ndarray
    _expert_cache: dict = field(default_factory=dict)
    seed: int = 0
    # position -> index in the originally-generated corpus; identity for
    # freshly generated streams, a permutation after reorder().  Expert
    # annotation noise is drawn per ORIGINAL index, so the same doc gets
    # the same simulated-LLM label in every stream order
    orig_idx: Optional[np.ndarray] = None

    def __len__(self):
        return len(self.docs)

    def _orig_idx(self) -> np.ndarray:
        if self.orig_idx is None:
            return np.arange(len(self.docs))
        return self.orig_idx

    def expert_labels(self, expert: str) -> np.ndarray:
        """Simulated LLM annotations: ground truth corrupted at the paper's
        per-dataset error rate, biased toward longer docs (Table 5).

        The flip/wrong-class draws are tied to each doc's ORIGINAL corpus
        index, not its stream position — a reordered stream (length /
        category shift runs) annotates every doc identically to the
        default order, so distribution-shift experiments compare the same
        teacher on the same data, merely permuted."""
        if expert in self._expert_cache:
            return self._expert_cache[expert]
        spec = self.spec
        acc = spec.expert_acc[expert]
        # zlib.crc32, NOT hash(): str hashing is salted per process
        # (PYTHONHASHSEED), which made expert annotations — and every
        # downstream accuracy number — nondeterministic across runs.
        rng = np.random.default_rng(
            zlib.crc32(f"{self.seed}:{expert}:{spec.name}".encode()))
        rel = (self.lengths / max(np.mean(self.lengths), 1.0)) \
            ** spec.length_difficulty
        raw = rel / np.mean(rel) * (1.0 - acc)
        err = np.clip(raw, 0.0, 0.49)
        # renormalize after clipping so the mean error matches the paper
        for _ in range(4):
            scale = (1.0 - acc) / max(np.mean(err), 1e-9)
            err = np.clip(err * scale, 0.0, 0.49)
        # per-original-index draws (err itself is per-doc: a function of
        # the doc's own length and the permutation-invariant corpus mean)
        oi = self._orig_idx()
        flip_u = rng.random(len(self.docs))
        wrong_off = rng.integers(0, spec.n_classes - 1, len(self.docs))
        flip = flip_u[oi] < err
        wrong = (self.labels + 1 + wrong_off[oi]) % spec.n_classes
        out = np.where(flip, wrong, self.labels).astype(np.int32)
        self._expert_cache[expert] = out
        return out

    def reorder(self, order: str) -> "Stream":
        """'length' (ascending, §5.4) or 'category' (last category moved to
        the stream tail, the Comedy analogue)."""
        if order == "length":
            idx = np.argsort(self.lengths, kind="stable")
        elif order == "category":
            held = self.categories == (_N_CATEGORIES - 1)
            idx = np.concatenate([np.where(~held)[0], np.where(held)[0]])
        elif order == "default":
            return self
        else:
            raise ValueError(order)
        return Stream(
            spec=self.spec,
            docs=[self.docs[i] for i in idx],
            labels=self.labels[idx],
            categories=self.categories[idx],
            lengths=self.lengths[idx],
            seed=self.seed,
            orig_idx=self._orig_idx()[idx],
        )


def _marker_tokens(n_classes: int) -> np.ndarray:
    base = VOCAB - 500
    return np.arange(base, base + max(n_classes, 2))


def _keyword_tokens(c: int) -> np.ndarray:
    base = VOCAB - 5000 + c * _KEYWORDS_PER_CLASS
    return np.arange(base, base + _KEYWORDS_PER_CLASS)


def _category_tokens(g: int) -> np.ndarray:
    base = VOCAB - 2000 + g * 50
    return np.arange(base, base + 50)


def make_stream(name: str, seed: int = 0,
                order: str = "default",
                n_samples: Optional[int] = None) -> Stream:
    """Generate the named benchmark stream deterministically."""
    spec = BENCHMARKS[name]
    if n_samples is not None:
        from dataclasses import replace
        spec = replace(spec, n_samples=n_samples)
    # zlib.crc32, NOT hash(): str hashing is salted per process, which
    # silently regenerated a different corpus every run
    rng = np.random.default_rng(zlib.crc32(f"{seed}:{name}".encode()))
    n = spec.n_samples
    labels = rng.choice(spec.n_classes, size=n, p=np.array(spec.class_probs))
    cats = rng.integers(0, _N_CATEGORIES, size=n)
    lengths = np.clip(
        rng.lognormal(np.log(spec.mean_len), spec.len_sigma, n),
        12, spec.mean_len * 12).astype(np.int32)
    markers = _marker_tokens(spec.n_classes)
    k = len(markers)

    # Zipf-ish background over the first 25k token ids.
    bg_n = VOCAB - 5000
    ranks = np.arange(1, bg_n + 1)
    bg_p = 1.0 / ranks
    bg_p /= bg_p.sum()

    docs = []
    for i in range(n):
        L = int(lengths[i])
        y = int(labels[i])
        body = rng.choice(bg_n, size=L, p=bg_p)
        # linear (BoW) signal
        kw_mask = rng.random(L) < spec.lr_separability
        n_kw = int(kw_mask.sum())
        if n_kw:
            body[kw_mask] = rng.choice(_keyword_tokens(y), size=n_kw)
        # category tokens
        cat_mask = rng.random(L) < 0.05
        n_cat = int(cat_mask.sum())
        if n_cat:
            body[cat_mask] = rng.choice(_category_tokens(int(cats[i])),
                                        size=n_cat)
        # order signal: class-rotated marker permutation (BoW-invariant)
        n_slots = rng.binomial(max(L // (k + 1), 1), spec.order_separability
                               * (k + 1))
        segments = [body]
        for _ in range(max(n_slots, 1) if spec.order_separability > 0 else 0):
            perm = np.roll(markers, -y)
            segments.append(perm)
        doc = np.concatenate(segments)
        rng.shuffle(doc[:0])  # keep order of marker runs; body order random
        # interleave marker runs at random positions
        if len(segments) > 1:
            insert_at = np.sort(rng.integers(0, L + 1, len(segments) - 1))
            parts, prev = [], 0
            for j, pos in enumerate(insert_at):
                parts.append(body[prev:pos])
                parts.append(segments[j + 1])
                prev = pos
            parts.append(body[prev:])
            doc = np.concatenate(parts)
        docs.append(doc.astype(np.int32))

    stream = Stream(spec=spec, docs=docs, labels=labels.astype(np.int32),
                    categories=cats.astype(np.int32),
                    lengths=np.array([len(d) for d in docs], np.int32),
                    seed=seed)
    return stream.reorder(order)


# ---------------------------------------------------------------------------
# LM pretraining corpus (for the training example / train driver)
# ---------------------------------------------------------------------------
def lm_batches(vocab: int, batch: int, seq: int, steps: int, seed: int = 0):
    """Synthetic LM batches: Zipf tokens with Markov bigram structure so the
    loss has learnable signal."""
    rng = np.random.default_rng(seed)
    n_states = 64
    trans = rng.dirichlet(np.ones(n_states) * 0.2, size=n_states)
    emit_base = rng.integers(0, max(vocab - n_states * 8, 1), size=n_states)
    for _ in range(steps):
        toks = np.empty((batch, seq + 1), np.int32)
        state = rng.integers(0, n_states, size=batch)
        for t in range(seq + 1):
            offs = rng.integers(0, 8, size=batch)
            toks[:, t] = (emit_base[state] + offs) % vocab
            nxt = np.empty_like(state)
            for b in range(batch):
                nxt[b] = rng.choice(n_states, p=trans[state[b]])
            state = nxt
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


# ---------------------------------------------------------------------------
# Arrival schedules (continuous-batching front-end, core/admission.py)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Request:
    """One stream-of-queries request for the admission front-end.

    ``rid`` is the request's RNG stream identity (core/rng.py key — the
    id an isolated sequential run of the same request would use as its
    ``stream_id``); ``arrival`` the 1-based front-end tick it becomes
    admissible at (0 means "before serving starts"); ``items`` the
    indices into the base stream's corpus it consumes, in order.  A
    schedule partitions ``range(n_items)`` across its requests, so one
    ``SimulatedExpert`` over the base stream annotates every request."""
    rid: int
    arrival: int
    items: tuple


def lockstep_requests(n_items: int, n_lanes: int) -> List[Request]:
    """The degenerate all-at-t=0 schedule: ``n_lanes`` requests, request
    r taking the stride-``n_lanes`` subsequence r, r+S, r+2S, ...

    This is exactly the item->lane mapping of
    ``BatchedCascadeEngine.run`` (tick T serves items [T*S, T*S+S) with
    lane s = offset), so serving this schedule through the front-end
    must be bitwise the classic lockstep run — the admission parity pin
    (tests/test_admission.py)."""
    if n_lanes < 1:
        raise ValueError("n_lanes must be >= 1")
    return [Request(rid=r, arrival=0,
                    items=tuple(range(r, n_items, n_lanes)))
            for r in range(min(n_lanes, n_items))]


def _segment_lengths(n_items: int, mean_len: int, rng) -> List[int]:
    """Partition n_items into contiguous request lengths ~ Geometric."""
    if mean_len < 1:
        raise ValueError("mean_len must be >= 1")
    lens: List[int] = []
    left = n_items
    cap = max(8 * mean_len, 1)
    while left > 0:
        k = min(int(rng.geometric(1.0 / mean_len)), cap, left)
        lens.append(k)
        left -= k
    return lens


def poisson_requests(n_items: int, *, rate: float, mean_len: int = 8,
                     seed: int = 0) -> List[Request]:
    """Open-loop Poisson arrivals over contiguous corpus segments.

    Request r is the next ``~Geometric(1/mean_len)`` items of the base
    corpus; inter-arrival gaps are Exponential(1/rate) ticks (``rate``
    in requests per tick), binned to integer arrival ticks.  Fully
    determined by ``(n_items, rate, mean_len, seed)`` — the admission
    order and every downstream record is reproducible from the schedule
    alone."""
    if rate <= 0:
        raise ValueError("rate must be > 0 requests/tick")
    rng = np.random.default_rng(
        zlib.crc32(f"arrivals:poisson:{seed}:{rate}:{mean_len}".encode()))
    lens = _segment_lengths(n_items, mean_len, rng)
    gaps = rng.exponential(1.0 / rate, size=len(lens))
    arrivals = 1 + np.floor(np.cumsum(gaps)).astype(np.int64)
    reqs, start = [], 0
    for r, k in enumerate(lens):
        reqs.append(Request(rid=r, arrival=int(arrivals[r]),
                            items=tuple(range(start, start + k))))
        start += k
    return reqs


def burst_requests(n_items: int, *, burst: int = 8, every: int = 4,
                   mean_len: int = 8, seed: int = 0) -> List[Request]:
    """Bursty arrivals: groups of ``burst`` requests land together every
    ``every`` ticks — the overload shape the shedding policy is for."""
    if burst < 1 or every < 1:
        raise ValueError("burst and every must be >= 1")
    rng = np.random.default_rng(
        zlib.crc32(f"arrivals:burst:{seed}:{burst}:{every}:"
                   f"{mean_len}".encode()))
    lens = _segment_lengths(n_items, mean_len, rng)
    reqs, start = [], 0
    for r, k in enumerate(lens):
        reqs.append(Request(rid=r, arrival=1 + (r // burst) * every,
                            items=tuple(range(start, start + k))))
        start += k
    return reqs


def arrival_schedule(kind: str, n_items: int, **kw) -> List[Request]:
    """Named schedule dispatcher for serve.py / benchmarks: ``lockstep``
    (all at t=0, stride partition), ``poisson`` (open-loop, contiguous
    segments), ``burst`` (grouped arrivals)."""
    if kind == "lockstep":
        return lockstep_requests(n_items, kw.pop("n_lanes"))
    if kind == "poisson":
        return poisson_requests(n_items, **kw)
    if kind == "burst":
        return burst_requests(n_items, **kw)
    raise ValueError(f"unknown arrival schedule {kind!r} "
                     "(expected lockstep|poisson|burst)")
