"""Featurizers for cascade students (pure numpy, host-side)."""
from __future__ import annotations

import numpy as np

_HASH_PRIME = 2654435761


def hash_bow(tokens: np.ndarray, n_features: int = 2048) -> np.ndarray:
    """Hashed bag-of-words counts, l2-normalized.  tokens: (L,) int."""
    idx = (tokens.astype(np.int64) * _HASH_PRIME % (1 << 31)) % n_features
    feats = np.bincount(idx, minlength=n_features).astype(np.float32)
    norm = np.linalg.norm(feats)
    return feats / norm if norm > 0 else feats


def hash_ids(tokens: np.ndarray, vocab: int = 4096,
             max_len: int = 128) -> np.ndarray:
    """Hashed token ids for the tiny-transformer student; 0 is pad.

    Only the first ``max_len`` tokens are hashed — everything past the
    truncation point is dropped anyway, and this runs per item in the
    serving hot path."""
    tokens = tokens[:max_len]
    ids = (tokens.astype(np.int64) * _HASH_PRIME % (1 << 31)) % (vocab - 1) + 1
    out = np.zeros((max_len,), np.int32)
    out[:len(ids)] = ids
    return out
