"""Roofline math for TPU v5e + HLO collective-bytes parser.

The container is CPU-only, so the three roofline terms are *derived* from
the compiled artifact of the multi-device dry-run:

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` reports the per-device partitioned module, so
we multiply by ``chips`` to get the global numerators (and the chips cancel:
terms are per-device seconds).  Collective bytes are not in cost_analysis —
we parse the optimized HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class HW:
    """Per-chip hardware envelope used by the roofline terms."""

    name: str
    peak_flops: float      # bf16 FLOP/s per chip
    hbm_bw: float          # bytes/s per chip
    ici_bw: float          # bytes/s per link
    hbm_bytes: float       # capacity per chip


V5E = HW(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
         hbm_bytes=16e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,4096]{1,0}"  (layout braces optional)
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_COLL_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(?P<shape>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum *result* bytes of collective ops in optimized HLO text.

    Result size is the per-device wire proxy: an all-gather materializes the
    full gathered buffer on each device; an all-reduce's result equals its
    operand; reduce-scatter/all-to-all results bound the received bytes.
    Async '-done' halves are skipped (the '-start' carries the shape).

    Returns {'all-reduce': bytes, ..., 'total': bytes, 'count': n_ops}.
    """
    out = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.match(line)
        if m is None:
            continue
        if m.group("suffix") == "-done":
            continue
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(m.group("shape")))
        out[m.group("op")] += nbytes
        count += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["count"] = count
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: HW = V5E) -> Dict:
    """Per-device seconds for each roofline term + the dominant one."""
    t_compute = flops_per_dev / hw.peak_flops
    t_memory = bytes_per_dev / hw.hbm_bw
    t_coll = coll_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    terms.update({
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        # fraction of the bound that is useful compute (1.0 = at roofline)
        "compute_fraction": t_compute / bound if bound > 0 else 0.0,
    })
    return terms


def model_flops_6nd(cfg: ModelConfig, n_tokens: int) -> float:
    """MODEL_FLOPS = 6 * N_active * D for training; callers use 2*N*D for a
    forward pass."""
    return 6.0 * cfg.active_param_count() * n_tokens
