from repro.metrics.costs import (
    lr_flops, tinytf_flops, expert_prefill_flops, expert_decode_flops,
    relative_costs, CostModel,
)
from repro.metrics.roofline import (
    HW, V5E, roofline_terms, parse_collective_bytes, model_flops_6nd,
)

__all__ = [
    "lr_flops", "tinytf_flops", "expert_prefill_flops",
    "expert_decode_flops", "relative_costs", "CostModel",
    "HW", "V5E", "roofline_terms", "parse_collective_bytes",
    "model_flops_6nd",
]
