"""Analytic FLOP cost models and the v5e roofline calculator."""
from repro.metrics.costs import (
    CostModel, expert_decode_flops, expert_prefill_flops, lr_flops,
    relative_costs, tinytf_flops)
from repro.metrics.roofline import (
    HW, V5E, model_flops_6nd, parse_collective_bytes, roofline_terms)

__all__ = [
    "lr_flops", "tinytf_flops", "expert_prefill_flops",
    "expert_decode_flops", "relative_costs", "CostModel",
    "HW", "V5E", "roofline_terms", "parse_collective_bytes",
    "model_flops_6nd",
]
