"""FLOP cost model for the cascade (paper App. C.1 rebuilt for our models).

The paper counts inference cost in "model cost units" where logistic
regression = 1.  We recompute those units from analytic FLOP counts of our
own models so the MDP deferral penalties c_i reflect the deployed cascade
(DESIGN.md §4: TPU cost model, not the paper's A100 numbers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig
from repro.models.kernel_students import SSMStudentSpec, TinyTFFlashSpec
from repro.models.students import LRSpec, MLPSpec, TinyTFSpec


def lr_flops(spec: LRSpec, train: bool = False) -> float:
    """Analytic FLOPs of one logistic-regression forward (per item)."""
    f = 2.0 * spec.n_features * spec.n_classes
    return 2.0 * f if train else f     # paper C.1: training ~ 2x inference


def mlp_flops(spec: MLPSpec, train: bool = False) -> float:
    """Analytic FLOPs of one deep-MLP student forward (per item)."""
    h, nl = spec.hidden, spec.n_layers
    f = 2.0 * (spec.n_features * h + (nl - 1) * h * h
               + h * spec.n_classes)
    return 2.0 * f if train else f


def tinytf_flops(spec: TinyTFSpec, train: bool = False) -> float:
    """Analytic FLOPs of one dense tiny-transformer forward (per item)."""
    L, d, f = spec.max_len, spec.d_model, spec.d_ff
    per_layer = (8.0 * L * d * d          # qkvo projections
                 + 4.0 * L * L * d        # scores + AV
                 + 4.0 * L * d * f)       # mlp
    total = per_layer * spec.n_layers + 2.0 * L * d * spec.vocab / spec.vocab
    total += 2.0 * d * spec.n_classes
    return 2.0 * total if train else total


def tinytf_flash_flops(spec: TinyTFFlashSpec, train: bool = False) -> float:
    """Analytic FLOPs of one ``tinytf_flash`` forward (per item).

    Causal attention halves the score/AV term relative to the full
    ``tinytf`` mask (the flash kernel skips fully-masked kv tiles); the
    decode-attention readout adds its k/v projections plus one
    (1 x L) attention row."""
    L, d, f = spec.max_len, spec.d_model, spec.d_ff
    per_layer = (8.0 * L * d * d          # qkvo projections
                 + 2.0 * L * L * d        # causal scores + AV (~L^2/2 pairs)
                 + 4.0 * L * d * f)       # mlp
    total = per_layer * spec.n_layers
    total += 4.0 * L * d * d              # readout k/v projections
    total += 4.0 * L * d                  # decode readout scores + AV
    total += 2.0 * d * spec.n_classes
    return 2.0 * total if train else total


def ssm_student_flops(spec: SSMStudentSpec, train: bool = False) -> float:
    """Analytic FLOPs of one ``ssm`` student forward (per item).

    SSD chunked terms per block: in_proj, depthwise conv, intra-chunk
    (L x Lc) scores + outputs, chunk-state build + inter-chunk read
    (each 2*L*N*d_inner), gate + out_proj."""
    L, d = spec.max_len, spec.d_model
    d_in = spec.expand * d
    N = spec.d_state
    H = d_in // spec.head_dim
    Lc = min(spec.chunk, L)
    per_block = (2.0 * L * d * (2 * d_in + 2 * N + H)   # in_proj
                 + 2.0 * L * spec.d_conv * (d_in + 2 * N)  # causal conv
                 + 2.0 * L * Lc * (N + d_in)            # intra-chunk SSD
                 + 4.0 * L * N * d_in                   # chunk states in/out
                 + 2.0 * L * d_in * d)                  # out_proj
    total = per_block * spec.n_layers + 2.0 * d * spec.n_classes
    return 2.0 * total if train else total


def _attn_flops(cfg: ModelConfig, q_tokens: float, kv_tokens: float) -> float:
    a = cfg.attn
    if a is None:
        return 0.0
    n_attn = sum(1 for k in cfg.period if k in ("attn", "cross")) \
        * cfg.n_periods
    return 4.0 * q_tokens * kv_tokens * a.n_heads * a.head_dim * n_attn


def expert_prefill_flops(cfg: ModelConfig, length: int) -> float:
    """First-token cost of a classification call (paper App. B.1: prefill
    dominates).  2 * N_active * L + attention term."""
    a = cfg.attn
    dense = 2.0 * cfg.active_param_count() * length
    if a is None:
        return dense
    kv = min(length, a.window) if a.window else length
    # causal: average kv length is ~L/2 for full attention
    kv_eff = kv if a.window else length / 2.0
    return dense + _attn_flops(cfg, length, kv_eff)


def expert_decode_flops(cfg: ModelConfig, cache_len: int) -> float:
    """Per-token decode cost of the expert at KV-cache length ``cache_len``."""
    a = cfg.attn
    dense = 2.0 * cfg.active_param_count()
    if a is None:
        return dense
    kv = min(cache_len, a.window) if a.window else cache_len
    return dense + _attn_flops(cfg, 1.0, kv)


@dataclass(frozen=True)
class CostModel:
    """Deferral penalties c_i for the MDP, normalized to c_1 (LR) = 1."""
    units: Dict[str, float]

    def cost(self, level_name: str) -> float:
        """The deferral penalty c_i of ``level_name`` in LR units."""
        return self.units[level_name]


def relative_costs(lr_spec: LRSpec, tf_spec: TinyTFSpec,
                   expert_cfg: ModelConfig = None,
                   doc_len: int = 256,
                   mlp_spec: MLPSpec = None,
                   tf_flash_spec: TinyTFFlashSpec = None,
                   ssm_spec: SSMStudentSpec = None,
                   extra: Dict[str, float] = None) -> CostModel:
    """Build the c_i table (LR = 1) from the analytic per-model FLOPs;
    optional specs add their level kind to the table."""
    base = lr_flops(lr_spec)
    units = {"lr": 1.0, "tinytf": tinytf_flops(tf_spec) / base}
    if mlp_spec is not None:
        units["mlp"] = mlp_flops(mlp_spec) / base
    if tf_flash_spec is not None:
        units["tinytf_flash"] = tinytf_flash_flops(tf_flash_spec) / base
    if ssm_spec is not None:
        units["ssm"] = ssm_student_flops(ssm_spec) / base
    if expert_cfg is not None:
        units["expert"] = expert_prefill_flops(expert_cfg, doc_len) / base
    if extra:
        units.update(extra)
    return CostModel(units=units)
