"""Pure-jnp oracle: sequential (per-token) SSD recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, adt, dt, B, C) -> jax.Array:
    """Sequential recurrence, the ground-truth semantics:

    h_t = h_{t-1} * exp(adt_t) + dt_t * B_t (x) x_t
    y_t = C_t . h_t

    x: (Bsz, S, H, hp); adt, dt: (Bsz, S, H); B, C: (Bsz, S, N).
    """
    Bsz, S, H, hp = x.shape
    N = B.shape[-1]

    def step(h, inputs):
        xt, adt_t, dt_t, Bt, Ct = inputs
        dA = jnp.exp(adt_t)                       # (Bsz, H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t, Bt, xt)
        h = h * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, hp, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          adt.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
