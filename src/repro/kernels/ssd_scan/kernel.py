"""Mamba2 SSD chunked scan kernel (state-space duality, arXiv:2405.21060).

TPU-native schedule (DESIGN.md §4): the sequence is split into chunks of
length L; all *intra-chunk* work is dense (L x L) and (L x d_state)
matmuls that feed the MXU, and the *inter-chunk* recurrence carries a
(head_dim x d_state) state in VMEM scratch across the sequential chunk
grid dimension — the TPU analogue of the CUDA selective-scan, with the
parallel-scan replaced by the grid's guaranteed sequential order.

Grid: (batch, heads, n_chunks).  Per-step VMEM: chunk inputs
(L x head_dim + 2 L x d_state + 2 L) + state (head_dim x d_state) fp32
~ 0.5 MB at L=256, hp=64, N=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, adt_ref, dt_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)     # (L, hp)
    adt = adt_ref[0, 0, 0].astype(jnp.float32)  # (L,)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)   # (L,)
    B = b_ref[0, 0].astype(jnp.float32)        # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)        # (L, N)

    cum = jnp.cumsum(adt)                      # (L,)
    # intra-chunk: scores[i, j] = (C_i . B_j) * exp(cum_i - cum_j) * (i >= j)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(li >= lj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    cb = C @ B.T                               # (L, L)
    y_intra = (cb * decay) @ (x * dt[:, None])

    # inter-chunk: y_i += (C_i * exp(cum_i)) @ h_prev^T
    h_prev = h_scr[...]                        # (hp, N)
    y_inter = (C * jnp.exp(cum)[:, None]) @ h_prev.T

    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = h * exp(cum_L) + sum_j exp(cum_L - cum_j) dt_j x_j B_j^T
    decay_out = jnp.exp(cum[-1] - cum)         # (L,)
    xw = x * (decay_out * dt)[:, None]         # (L, hp)
    h_scr[...] = h_prev * jnp.exp(cum[-1]) + xw.T @ B


def ssd_scan_chunked(x, adt, dt, B, C, *, chunk: int = 256,
                     interpret: bool = True) -> jax.Array:
    """x: (Bsz, S, H, hp); adt, dt: (Bsz, S, H); B, C: (Bsz, S, N).

    Returns y: (Bsz, S, H, hp).  n_groups = 1 (B/C shared across heads).
    """
    Bsz, S, H, hp = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    # kernel layouts: x (Bsz, H, nc, L, hp); adt/dt (Bsz, H, nc, L);
    # B/C (Bsz, nc, L, N)
    xk = x.reshape(Bsz, nc, chunk, H, hp).transpose(0, 3, 1, 2, 4)
    adtk = adt.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2)
    dtk = dt.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2)
    Bk = B.reshape(Bsz, nc, chunk, N)
    Ck = C.reshape(Bsz, nc, chunk, N)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    yk = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, hp),
                         lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, hp),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, nc, chunk, hp), x.dtype),
        scratch_shapes=[pltpu.VMEM((hp, N), jnp.float32)],
        interpret=interpret,
    )(xk, adtk, dtk, Bk, Ck)
    return yk.transpose(0, 2, 3, 1, 4).reshape(Bsz, S, H, hp)
