"""Public jit'd wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_chunked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, adt, dt, B, C, *, chunk: int = 256,
             interpret: Optional[bool] = None) -> jax.Array:
    """Mamba2 SSD: x (Bsz,S,H,hp); adt/dt (Bsz,S,H); B/C (Bsz,S,N)."""
    if interpret is None:
        interpret = not _on_tpu()
    return ssd_scan_chunked(x, adt, dt, B, C, chunk=chunk,
                            interpret=interpret)
