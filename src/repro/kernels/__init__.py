"""Pallas TPU kernels for the serving hot spots (DESIGN.md §4).

The paper's cost center is LLM first-token inference (App. B.1: quadratic
attention prefill dominates, OOMs at batch 2 on 8xA100).  These kernels are
the TPU-native answer for the expert level of the cascade:

  flash_attention/  — prefill attention, causal + sliding-window + GQA
  decode_attention/ — single-token GQA attention over a (ring) KV cache
  moe_gmm/          — grouped expert matmul for MoE FFNs
  ssd_scan/         — Mamba2 chunked state-space-dual scan

Each kernel package ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (auto interpret=True off-TPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps
"""
