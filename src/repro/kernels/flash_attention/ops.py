"""Public jit'd wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, hd), pads non-MXU-aligned head dims
(h2o-danube's 120 -> 128), and picks interpret mode automatically when not
running on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) -> (B, Sq, H, hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    hd = q.shape[-1]
    pad = (-hd) % 128 if not interpret else 0
    sm_scale = hd ** -0.5
    if pad:
        zq = [(0, 0)] * 3 + [(0, pad)]
        q = jnp.pad(q, zq)
        k = jnp.pad(k, zq)
        v = jnp.pad(v, zq)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               sm_scale=sm_scale, interpret=interpret)
    out = out.transpose(0, 2, 1, 3)
    if pad:
        out = out[..., :hd]
    return out
