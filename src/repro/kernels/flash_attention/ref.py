"""Pure-jnp oracle: naive O(S^2) attention with explicit masks."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  sm_scale: Optional[float] = None) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd).  Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    group = H // K
    sm_scale = sm_scale if sm_scale is not None else hd ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
