"""Flash-attention prefill kernel (pl.pallas_call + BlockSpec VMEM tiling).

Schedule: grid (batch, q_heads, q_blocks, kv_blocks), kv innermost so the
online-softmax running state (m, l, acc) lives in VMEM scratch across kv
iterations.  GQA is expressed in the K/V index_maps (q head h reads kv head
h // group_size); causal and sliding-window masks are built from block
offsets with iota; fully-masked kv blocks are skipped with pl.when (on TPU
the MXU never sees them).

Tile sizes default to (block_q=512, block_kv=512) x head_dim — with fp32
scratch that is ~2.5 MB of VMEM at head_dim 128, comfortably under the
~16 MB/core budget while keeping the matmul dims MXU-aligned (>=128).
Head dims that are not multiples of 128 (h2o-danube's 120) are zero-padded
by the ops.py wrapper.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_kv: int, causal: bool,
                  window: Optional[int], n_kv_blocks: int, sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Block-level skip: with causal masking, kv blocks strictly above the
    # diagonal contribute nothing; with a window, kv blocks entirely left of
    # the band contribute nothing.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run,
                              k_start + block_kv - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale     # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                        # (bq, bkv)
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l_fin = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l_fin).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None,
                         block_q: int = 512, block_kv: int = 512,
                         sm_scale: Optional[float] = None,
                         interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd); H % K == 0.

    Returns (B, H, Sq, hd).  hd should be a multiple of 8 (the wrapper pads
    to 128 on real TPU).
    """
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    group = H // K
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    n_q = Sq // block_q
    n_kv = Skv // block_kv
    sm_scale = sm_scale if sm_scale is not None else hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_kv=block_kv, causal=causal,
        window=window, n_kv_blocks=n_kv, sm_scale=sm_scale)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
