"""Pure-jnp oracle for single-token decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, sm_scale=None) -> jax.Array:
    """q: (B, K, G, hd); k, v: (B, W, K, hd); pos: (B, W) with -1 = empty."""
    hd = q.shape[-1]
    sm_scale = sm_scale if sm_scale is not None else hd ** -0.5
    s = jnp.einsum("bkgd,bwkd->bkgw", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    valid = (pos >= 0)[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
