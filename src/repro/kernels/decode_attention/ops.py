"""Public jit'd wrapper for decode attention (model layout adapter)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_grouped


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q, k, v, pos, *, block_kv: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, 1, H, hd) one new token; k, v: (B, W, K, hd) ring cache;
    pos: (W,) or (B, W) slot positions (-1 empty).  Returns (B, 1, H, hd).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, _, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None], (B, pos.shape[0]))
    pad = (-hd) % 128 if not interpret else 0
    sm_scale = hd ** -0.5
    if pad:
        q = jnp.pad(q, [(0, 0)] * 3 + [(0, pad)])
        k = jnp.pad(k, [(0, 0)] * 3 + [(0, pad)])
        v = jnp.pad(v, [(0, 0)] * 3 + [(0, pad)])
    qg = q[:, 0].reshape(B, K, G, q.shape[-1])
    out = decode_attention_grouped(qg, k, v, pos, block_kv=block_kv,
                                   sm_scale=sm_scale, interpret=interpret)
    out = out.reshape(B, 1, H, out.shape[-1])
    if pad:
        out = out[..., :hd]
    return out
