"""Single-token GQA decode attention over a (ring) KV cache.

The decode step is memory-bound: every step streams the whole cache from
HBM once.  The kernel's job is (a) to touch each cache byte exactly once,
and (b) to keep the MXU busy despite Sq == 1 — so the q heads sharing a kv
head are grouped into a (group x block_kv) matmul instead of G rank-1
products (DESIGN.md §4, TPU adaptation).

Grid (batch, kv_heads, kv_blocks); scratch carries the online-softmax state
across kv blocks.  Ring-buffer semantics come for free: the cache's
position array marks empty slots with -1 and the kernel masks on pos >= 0 —
no scalar arguments needed (windowing is enforced by the ring buffer
itself, which only retains the last W positions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   n_kv_blocks: int, sm_scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (bkv, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    pos = pos_ref[0]                                    # (bkv,)

    s = q @ k.T                                         # (G, bkv)
    valid = (pos >= 0)[None, :]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l_fin = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l_fin).astype(o_ref.dtype)


def decode_attention_grouped(q, k, v, pos, *, block_kv: int = 512,
                             sm_scale=None, interpret: bool = True):
    """q: (B, K, G, hd) one token per batch, G = q-heads per kv head.
    k, v: (B, W, K, hd) ring caches; pos: (B, W) slot positions (-1 empty).

    Returns (B, K, G, hd).
    """
    B, K, G, hd = q.shape
    W = k.shape[1]
    block_kv = min(block_kv, W)
    assert W % block_kv == 0
    n_kv = W // block_kv
    sm_scale = sm_scale if sm_scale is not None else hd ** -0.5

    kernel = functools.partial(_decode_kernel, n_kv_blocks=n_kv,
                               sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=(B, K, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, hd),
                         lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_kv, 1, hd),
                         lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_kv), lambda b, h, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, pos)
