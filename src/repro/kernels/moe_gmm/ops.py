"""Public jit'd wrappers: grouped matmul + fused expert SwiGLU FFN."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm.kernel import gmm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def moe_gmm(x, w, *, block_c: int = 256, block_f: int = 512,
            block_d: int = 512, interpret: Optional[bool] = None):
    """Grouped matmul over capacity-bucketed expert tokens; interpret
    mode auto-selected off-TPU."""
    if interpret is None:
        interpret = not _on_tpu()
    return gmm(x, w, block_c=block_c, block_f=block_f, block_d=block_d,
               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_expert_ffn(x, w_in, w_gate, w_out,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Capacity-bucketed expert FFN: three grouped matmuls + SwiGLU."""
    if interpret is None:
        interpret = not _on_tpu()
    h = moe_gmm(x, w_in, interpret=interpret)
    g = moe_gmm(x, w_gate, interpret=interpret)
    h = (jax.nn.silu(g.astype(jnp.float32)) * h.astype(jnp.float32)
         ).astype(x.dtype)
    return moe_gmm(h, w_out, interpret=interpret)
