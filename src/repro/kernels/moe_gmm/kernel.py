"""Grouped expert matmul: (E, C, D) x (E, D, F) -> (E, C, F).

This is the MoE FFN hot loop after capacity dispatch (GShard-style, see
models/moe.py).  On GPU this is usually a scatter into per-expert buffers +
cuBLAS grouped GEMM; the TPU-native form is a 4-D sequential grid
(expert, c_block, f_block, d_block) with an fp32 VMEM accumulator carried
across the contraction (d) blocks — each (c x d) x (d x f) tile is a single
MXU issue, no gather/scatter (DESIGN.md §4).

VMEM per step: bc*bd + bd*bf + bc*bf fp32 ~= 3 * 256KB at 256x512 tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, n_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)      # (bc, bd)
    w = w_ref[0].astype(jnp.float32)      # (bd, bf)
    acc_scr[...] += x @ w

    @pl.when(di == n_d_blocks - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def gmm(x, w, *, block_c: int = 256, block_f: int = 512, block_d: int = 512,
        interpret: bool = True) -> jax.Array:
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    n_d = D // block_d

    kernel = functools.partial(_gmm_kernel, n_d_blocks=n_d)
    return pl.pallas_call(
        kernel,
        grid=(E, C // block_c, F // block_f, n_d),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
