from repro.kernels.moe_gmm.ops import moe_gmm, moe_expert_ffn

__all__ = ["moe_gmm", "moe_expert_ffn"]
