from repro.kernels.moe_gmm.ops import moe_expert_ffn, moe_gmm

__all__ = ["moe_gmm", "moe_expert_ffn"]
