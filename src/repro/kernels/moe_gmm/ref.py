"""Pure-jnp oracles for the grouped matmul + full expert FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x, w) -> jax.Array:
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def expert_ffn_ref(x, w_in, w_gate, w_out) -> jax.Array:
    """SwiGLU expert FFN: (E, C, D) -> (E, C, D)."""
    h = gmm_ref(x, w_in)
    g = gmm_ref(x, w_gate)
    h = (jax.nn.silu(g.astype(jnp.float32)) * h.astype(jnp.float32)
         ).astype(x.dtype)
    return gmm_ref(h, w_out)
