"""Train a zoo model with the production training path (pjit + remat +
AdamW built in-repo).

By default trains the reduced internlm2 config for a quick CPU run; with
--hundred-m it builds a ~100M-parameter variant and trains a few hundred
steps (the full-scale example from the assignment; expect hours on 1 CPU
core, minutes on real accelerators).

  PYTHONPATH=src python examples/train_expert_lm.py --steps 30
"""
import argparse

from repro.configs import get_smoke_config
from repro.configs.base import ATTN, AttnConfig, ModelConfig, register
from repro.launch.train import train


def hundred_m_config() -> ModelConfig:
    """~100M-param dense GQA model (internlm2 family, scaled down)."""
    return ModelConfig(
        name="internlm2-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        d_ff=2048,
        vocab=32_000,
        attn=AttnConfig(n_heads=12, n_kv_heads=4, head_dim=64,
                        rope_theta=1e6),
        period=(ATTN,),
        source="scaled from arXiv:2403.17297",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args()

    if args.hundred_m:
        cfg = hundred_m_config()
        register(cfg, smoke=get_smoke_config("internlm2-1.8b"))
        print(f"training {cfg.name}: {cfg.param_count()/1e6:.0f}M params")
        losses = train("internlm2-100m", smoke=False, steps=args.steps,
                       batch=args.batch, seq=args.seq, ckpt=args.ckpt)
    else:
        losses = train("internlm2-1.8b", smoke=True, steps=args.steps,
                       batch=args.batch, seq=args.seq, ckpt=args.ckpt)
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
