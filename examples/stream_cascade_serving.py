"""End-to-end driver: serve a small model with batched requests behind an
online cascade (deliverable b).

Everything is real compute: the expert is an in-repo transformer trained on
ground truth (standing in for the zero-shot LLM); deferred queries are
batched into single expert forwards; students and deferral MLPs update
online from the expert's annotations.

  PYTHONPATH=src python examples/stream_cascade_serving.py \
      --dataset hatespeech --samples 1500 --microbatch 16
"""
import argparse

from repro.launch.serve import serve_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech")
    ap.add_argument("--samples", type=int, default=1500)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--microbatch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve_stream(args.dataset, args.samples, args.mu, args.microbatch,
                 expert_kind="model", seed=args.seed)


if __name__ == "__main__":
    main()
