"""Continuous-batching serving example (dynamic lane admission).

Every other example serves a fixed lockstep batch: S lanes that start
together at tick 0 and end together.  Real traffic doesn't — requests
arrive over time with their own lengths.  This demo serves the same
stream twice through the admission front-end (core/admission.py):

* ``--arrivals lockstep`` — all requests at t=0, stride-partitioned:
  bitwise the classic lockstep run (the parity pin in
  tests/test_admission.py), reported with per-stream records;
* ``--arrivals poisson`` — open-loop staggered traffic: requests queue
  for a lane, run to completion, retire and recycle the lane, and the
  report shows admission/queueing/latency per stream — p50/p99
  time-to-answer in ticks plus lane occupancy.

Try overload: raise --arrival-rate (or switch --admission shed) and
watch queue delay / shedding absorb the excess.

  PYTHONPATH=src python examples/load_serving.py \
      --dataset hatespeech --samples 640 --lanes 8 \
      --arrival-rate 0.8 --request-len 6
"""
import argparse

from repro.launch.serve import serve_stream_batched


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech")
    ap.add_argument("--samples", type=int, default=640)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--arrival-rate", type=float, default=0.8,
                    help="offered load, requests per tick")
    ap.add_argument("--request-len", type=int, default=6,
                    help="mean request length in items")
    ap.add_argument("--admission", default="queue",
                    choices=["queue", "shed"])
    ap.add_argument("--queue-limit", type=int, default=0)
    ap.add_argument("--async-delay", type=int, default=0)
    ap.add_argument("--pipeline-depth", type=int, default=0)
    ap.add_argument("--expert", default="simulated",
                    choices=["model", "simulated"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("== all-at-t=0 (lockstep schedule through the front-end) ==")
    m_lock = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.lanes,
        expert_kind=args.expert, seed=args.seed,
        async_delay=args.async_delay,
        pipeline_depth=args.pipeline_depth,
        arrivals="lockstep")
    print(f"\n== staggered poisson arrivals "
          f"(rate={args.arrival_rate}/tick, mean len "
          f"{args.request_len}) ==")
    m_pois = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.lanes,
        expert_kind=args.expert, seed=args.seed,
        async_delay=args.async_delay,
        pipeline_depth=args.pipeline_depth,
        arrivals="poisson", admission=args.admission,
        queue_limit=args.queue_limit,
        arrival_rate=args.arrival_rate, request_len=args.request_len)
    print(f"\nlockstep occupancy {m_lock['occupancy_mean']:.2f} vs "
          f"poisson {m_pois['occupancy_mean']:.2f} of {args.lanes} "
          f"lanes; poisson tta p50={m_pois['tta_p50']:.0f} "
          f"p99={m_pois['tta_p99']:.0f} ticks "
          f"(shed={m_pois['shed']})")


if __name__ == "__main__":
    main()
