"""Expert-pool serving example (per-lane commits, multi-worker expert).

The PR-3 async queue keeps the expert off the critical path, but still
commits a routed tick's annotations as one block through one annotation
worker: a slow batch delays every lane behind it, and extra expert
capacity goes unused.  With ``--expert-workers W --per-lane-commit``
each deferred batch is sharded over W concurrent annotation workers
(``expert.submit_many``, per-item ticket completion) and each lane's
annotation commits on its own deterministic sub-deadline inside the
delay window — per-item updates in strict (tick, lane) order, bitwise
invariant to worker count and latency (core/batched.py "per-lane commit
granularity" contract).

The demo serves the same stream with the per-tick drain and with the
per-lane pool, and prints the annotation-commit latency both ways:

  PYTHONPATH=src python examples/pool_serving.py \
      --dataset hatespeech --samples 1280 --batch 32 \
      --async-delay 2 --expert-workers 4
"""
import argparse

from repro.launch.serve import serve_stream_batched


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech")
    ap.add_argument("--samples", type=int, default=1280)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--async-delay", type=int, default=2)
    ap.add_argument("--expert-workers", type=int, default=4)
    ap.add_argument("--expert", default="model",
                    choices=["model", "simulated"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"== per-tick commit (D={args.async_delay}, 1 worker) ==")
    m_tick = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed,
        async_delay=args.async_delay)
    print(f"\n== per-lane commit (D={args.async_delay}, "
          f"{args.expert_workers} workers) ==")
    m_lane = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed,
        async_delay=args.async_delay,
        expert_workers=args.expert_workers, per_lane=True)
    print(f"\nper-lane vs per-tick: accuracy "
          f"{m_tick['accuracy']:.4f} -> {m_lane['accuracy']:.4f}, "
          f"expert calls {m_tick['expert_calls']} -> "
          f"{m_lane['expert_calls']} "
          f"(annotation-commit latency printed above per run)")


if __name__ == "__main__":
    main()
