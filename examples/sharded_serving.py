"""Lane-sharded multi-stream serving on a device mesh.

The batched engine's S stream lanes shard over the mesh's ('pod','data')
axes with `NamedSharding`: each device runs the per-level student
forwards for its own lane shard, while the shared cascade state (student
params, deferral MLPs, demonstration ring buffers) stays replicated.
Routing is identical to the unsharded engine on the same tick keys
(tests/test_sharded.py asserts it), so sharding is purely a throughput
knob.

This demo virtualizes the mesh on CPU — the XLA flag must be set before
jax initializes, which is why it is exported at the top of this file.
On real multi-chip hardware, drop the flag and pass the actual mesh
shape (e.g. --mesh data=8 on an 8-chip host, or pod=2,data=4 across
pods).

  PYTHONPATH=src python examples/sharded_serving.py \
      --dataset hatespeech --samples 1280 --batch 64 --mesh data=8
"""
import argparse
import os

if "XLA_FLAGS" not in os.environ:
    # 8 virtual CPU devices for the demo; must precede any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech")
    ap.add_argument("--samples", type=int, default=1280)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--mesh", default="data=8",
                    help="e.g. 'data=8' or 'pod=2,data=4'")
    ap.add_argument("--updates", default="single",
                    choices=["single", "scaled"])
    ap.add_argument("--expert", default="model",
                    choices=["model", "simulated"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.launch.mesh import parse_mesh_spec
    from repro.launch.serve import serve_stream_batched

    mesh = parse_mesh_spec(args.mesh)
    metrics = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed, mesh=mesh,
        updates_per_tick=args.updates)
    calls = metrics["per_stream"]["expert_calls"]
    placement = (f"lanes sharded {dict(mesh.shape)!r}, state replicated"
                 if mesh is not None else "unsharded")
    print(f"per-lane expert calls: min={int(calls.min())} "
          f"median={int(np.median(calls))} max={int(calls.max())} "
          f"({placement})")


if __name__ == "__main__":
    main()
