"""Distribution-shift robustness demo (paper §5.4).

Streams IMDB-like data in three orders — default, length-ascending, and
category-held-out (the Comedy analogue) — and shows the cascade adapting
online in each case.

  PYTHONPATH=src python examples/distribution_shift_demo.py --samples 1500
"""
import argparse

from repro.core import OnlineCascade, SimulatedExpert, default_cascade_config
from repro.data import make_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=1500)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    results = {}
    for order in ("default", "length", "category"):
        stream = make_stream("imdb", seed=args.seed,
                             n_samples=args.samples, order=order)
        expert = SimulatedExpert(stream, "gpt-3.5-turbo")
        cfg = default_cascade_config(n_classes=2, mu=args.mu,
                                     seed=args.seed)
        cascade = OnlineCascade(cfg, expert)
        m = cascade.run(stream)
        results[order] = m
        print(f"{order:>9}: acc={m['accuracy']:.4f} "
              f"calls={m['expert_calls']}")

    base = results["default"]["accuracy"]
    for order in ("length", "category"):
        delta = results[order]["accuracy"] - base
        print(f"shift '{order}': delta accuracy {delta:+.4f} "
              f"(paper Table 2: -0.54% / +0.08%)")


if __name__ == "__main__":
    main()
