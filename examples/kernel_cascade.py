"""Kernel-ladder serving example (real models on the Pallas path).

Serves the same stream twice through ``BatchedCascadeEngine``: once with
the default dense-student ladder (lr -> tinytf) and once with the kernel
ladder (lr -> tinytf_flash -> ssm), whose upper levels route their
batched route-pass forwards through the repo's Pallas kernels — flash
attention for the causal layers, decode attention for the learned-query
readout, the SSD chunked scan for the Mamba2 blocks (models/
kernel_students.py, docs/MODELS.md).  Training still differentiates the
jnp reference path; the two paths are tolerance-pinned by the tier-1
parity tests.

By default the CI-sized specs serve (``--ladder kernel-ci`` shapes) so
the demo finishes in minutes on CPU, where Pallas runs in interpret
mode; pass ``--full-specs`` on a TPU host for the default sizes.

  PYTHONPATH=src python examples/kernel_cascade.py \
      --dataset hatespeech --samples 384 --batch 8
"""
import argparse

from repro.launch.serve import serve_stream_batched


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech")
    ap.add_argument("--samples", type=int, default=384)
    ap.add_argument("--mu", type=float, default=3e-6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--expert", default="simulated",
                    choices=["model", "simulated"])
    ap.add_argument("--full-specs", action="store_true",
                    help="default-size level specs (TPU-appropriate; "
                         "interpret-slow on CPU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    ladder = "kernel" if args.full_specs else "kernel-ci"

    print("== default ladder (lr -> tinytf, dense jnp students) ==")
    m_dense = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed, log_every=0)
    print(f"\n== kernel ladder (lr -> tinytf_flash -> ssm, "
          f"{ladder}) ==")
    m_kernel = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed, log_every=0,
        ladder=ladder)
    print(f"\nkernel vs dense ladder: accuracy "
          f"{m_dense['accuracy']:.4f} -> {m_kernel['accuracy']:.4f}, "
          f"expert calls {m_dense['expert_calls']} -> "
          f"{m_kernel['expert_calls']}")


if __name__ == "__main__":
    main()
