"""Batched multi-stream serving example (the default production path).

S concurrent stream lanes advance in lockstep through one shared cascade:
per-level batched student forwards over the lanes still alive at each
level, ONE batched expert forward per tick for all deferred lanes, and
per-tick weighted online updates.  With --batch 1 the engine is
bit-for-bit the sequential Algorithm-1 reference (see core/batched.py for
the RNG/equivalence contract); larger batches trade per-item update
granularity for an order-of-magnitude throughput win while online
learning is active.

Per-lane accounting stays independent — the demo prints the spread of
expert usage across lanes at the end.

  PYTHONPATH=src python examples/batched_serving.py \
      --dataset hatespeech --samples 1280 --batch 64
"""
import argparse

import numpy as np

from repro.launch.serve import serve_stream_batched


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech")
    ap.add_argument("--samples", type=int, default=1280)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--expert", default="model",
                    choices=["model", "simulated"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    metrics = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed)
    per = metrics["per_stream"]
    calls = per["expert_calls"]
    print(f"per-lane expert calls: min={int(calls.min())} "
          f"median={int(np.median(calls))} max={int(calls.max())} "
          f"(independent accounting across {len(calls)} lanes)")


if __name__ == "__main__":
    main()
