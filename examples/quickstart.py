"""Quickstart: online cascade learning over a streaming benchmark.

Runs Algorithm 1 (LR -> tiny transformer -> LLM expert) on an IMDB-like
stream and prints the paper's headline numbers: accuracy vs the expert and
the fraction of LLM calls saved.

  PYTHONPATH=src python examples/quickstart.py [--samples 2000] [--mu 3e-7]
"""
import argparse

import numpy as np

from repro.core import OnlineCascade, SimulatedExpert, default_cascade_config
from repro.data import make_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb")
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--mu", type=float, default=3e-7,
                    help="cost weighting factor (paper's budget knob)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    stream = make_stream(args.dataset, seed=args.seed,
                         n_samples=args.samples)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    config = default_cascade_config(n_classes=stream.spec.n_classes,
                                    mu=args.mu, seed=args.seed)
    cascade = OnlineCascade(config, expert)
    metrics = cascade.run(stream, log_every=500)

    expert_acc = float(np.mean(
        stream.expert_labels("gpt-3.5-turbo") == stream.labels))
    saving = 1 - metrics["expert_calls"] / args.samples
    print(f"\ncascade accuracy : {metrics['accuracy']:.4f}")
    print(f"expert accuracy  : {expert_acc:.4f}")
    print(f"LLM calls        : {metrics['expert_calls']} "
          f"/ {args.samples}  (cost saving {saving:.1%})")
    print(f"level fractions  : "
          f"{[round(f, 3) for f in metrics['level_fractions']]}")


if __name__ == "__main__":
    main()
