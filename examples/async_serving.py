"""Async expert-queue serving example (bounded annotation delay).

The synchronous batched engine waits for the expert's batched forward
every tick.  With ``--async-delay D >= 1`` the deferred lanes answer
provisionally with the last student's prediction, the expert annotation
is computed on a background thread (overlapping the next ticks' student
compute), and the online updates land within D ticks — same routing
draws, same annotations, only the update timing shifts (core/batched.py
"Async expert queue" contract).

The demo serves the same stream synchronously and with the requested
delay, and prints the throughput/accuracy trade:

  PYTHONPATH=src python examples/async_serving.py \
      --dataset hatespeech --samples 1280 --batch 32 --async-delay 2
"""
import argparse

from repro.launch.serve import serve_stream_batched


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hatespeech")
    ap.add_argument("--samples", type=int, default=1280)
    ap.add_argument("--mu", type=float, default=3e-7)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--async-delay", type=int, default=2)
    ap.add_argument("--expert", default="model",
                    choices=["model", "simulated"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("== synchronous (max_delay=0) ==")
    m_sync = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed, async_delay=0)
    print(f"\n== async (max_delay={args.async_delay}) ==")
    m_async = serve_stream_batched(
        args.dataset, args.samples, args.mu, batch=args.batch,
        expert_kind=args.expert, seed=args.seed,
        async_delay=args.async_delay)
    speed = m_async["items_per_sec"] / max(m_sync["items_per_sec"], 1e-9)
    print(f"\nasync vs sync: {speed:.2f}x throughput, "
          f"accuracy {m_sync['accuracy']:.4f} -> "
          f"{m_async['accuracy']:.4f} "
          f"({m_async['accuracy'] - m_sync['accuracy']:+.4f}), "
          f"expert calls {m_sync['expert_calls']} -> "
          f"{m_async['expert_calls']}")


if __name__ == "__main__":
    main()
