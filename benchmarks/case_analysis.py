"""Paper Figures 5-8: per-dataset case analysis at a fixed budget — the
level-usage composition over the stream and the headline cost savings
(IMDB ~70%, HateSpeech ~90%, ISEAR ~30%, FEVER ~20%)."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import run_cascade, save_json

# mu per dataset tuned to land near the paper's case-study budgets
CASES = {
    "imdb": 3e-7,        # paper N=3671/12500 ~ 70% savings
    "hatespeech": 6e-7,  # paper N=507/5352   ~ 90% savings
    "isear": 5e-7,   # ~30% savings regime on reduced streams
    "fever": 5e-7,   # ~50% savings regime on reduced streams
}


def run(samples: int = 2000, seed: int = 0, quick: bool = False):
    out = []
    cases = list(CASES) if not quick else ["hatespeech"]
    for ds in cases:
        m = run_cascade(ds, "gpt-3.5-turbo", CASES[ds], samples=samples,
                        seed=seed)
        lv = np.array(m.pop("history_level"))
        m.pop("history_J")
        n_levels = int(lv.max())
        # composition over quarters of the stream (Fig 5-8 stacked plot)
        comp = []
        for q in range(4):
            sl = lv[q * len(lv) // 4:(q + 1) * len(lv) // 4]
            comp.append([float(np.mean(sl == i))
                         for i in range(n_levels + 1)])
        savings = 1.0 - m["expert_calls"] / samples
        rec = {
            "dataset": ds, "mu": CASES[ds], "samples": samples,
            "accuracy": m["accuracy"], "recall": m.get("recall"),
            "expert_accuracy": m["expert_accuracy"],
            "expert_calls": m["expert_calls"],
            "cost_savings": savings,
            "composition_by_quarter": comp,
            "us_per_call": m["us_per_call"],
        }
        out.append(rec)
        print(f"{ds}: acc={rec['accuracy']:.3f} "
              f"(LLM {rec['expert_accuracy']:.3f}) "
              f"savings={savings:.1%} "
              f"final-quarter composition={comp[-1]}", flush=True)
    save_json("case_analysis.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=1500)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.samples, args.seed, args.quick)


if __name__ == "__main__":
    main()
