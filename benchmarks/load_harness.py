"""Open-loop load harness for the continuous-batching front-end.

Latency under load, not just items/sec: requests arrive on a wall-clock
Poisson process at a configured fraction of the engine's measured
capacity, are admitted through ``core/admission.py``'s lane pool, and
each answered request's **time-to-answer** is measured from its
*scheduled* arrival instant (the open-loop convention — measuring from
the actual offer call would hide queueing behind coordinated omission).

Three phases:

1. **capacity** — a full-occupancy lockstep run over a calibration
   slice measures the engine's service rate C (items/sec), jits warm;
2. **under-capacity** (default 0.6 C) — p50/p99 time-to-answer shows
   pure service latency: arrivals rarely wait for a lane;
3. **over-capacity** (default 1.5 C) — the queue grows for the whole
   run, p99 blows up with backlog while goodput saturates at ~C.  With
   ``--admission shed`` excess arrivals are dropped instead and goodput
   holds with bounded latency — the overload trade the policy exists
   for.

Measured wall-clock on a shared CPU host: report the *shape* (p99
under vs over, goodput vs offered), not the absolute numbers.

Usage:
  PYTHONPATH=src python benchmarks/load_harness.py [--quick | --smoke]
"""
from __future__ import annotations

import argparse
import time
import zlib

import numpy as np

from repro.core import (BatchedCascadeEngine, CascadeFrontEnd,
                        SimulatedExpert, default_cascade_config)
from repro.data import make_stream, poisson_requests


def _drive_open_loop(engine, stream, requests, arrival_wall,
                     admission: str, queue_limit: int) -> CascadeFrontEnd:
    """Serve ``requests`` with request r offered when the wall clock
    passes ``arrival_wall[r]`` (seconds from start); ticks run back to
    back whenever any lane is occupied."""
    fe = CascadeFrontEnd(engine, stream, admission=admission,
                         queue_limit=queue_limit)
    t0 = time.time()
    i = 0
    while i < len(requests) or fe.active():
        now = time.time() - t0
        while i < len(requests) and arrival_wall[i] <= now:
            fe.offer(requests[i])
            fe.records[requests[i].rid].arrival_wall = t0 + arrival_wall[i]
            i += 1
        if fe.active():
            fe.step()
        elif i < len(requests):
            time.sleep(min(arrival_wall[i] - now, 0.01))
    fe.finish()
    return fe


def _point(engine, stream, *, load: float, capacity: float, mean_len: int,
           seed: int, admission: str, queue_limit: int) -> dict:
    """One offered-load point: Poisson arrivals at ``load * capacity``
    items/sec over the whole corpus, reported open-loop."""
    engine.reset()
    requests = poisson_requests(len(stream), rate=1.0, mean_len=mean_len,
                                seed=seed)
    offered_rate = load * capacity                      # items/sec
    req_rate = offered_rate / mean_len                  # requests/sec
    rng = np.random.default_rng(
        zlib.crc32(f"load:{seed}:{load}".encode()))
    arrival_wall = np.cumsum(
        rng.exponential(1.0 / req_rate, size=len(requests)))
    t0 = time.time()
    fe = _drive_open_loop(engine, stream, requests, arrival_wall,
                          admission, queue_limit)
    dt = time.time() - t0
    recs = [r for r in fe.records.values() if r.answered]
    tta = np.array([r.answer_wall - r.arrival_wall for r in recs])
    m = fe.metrics()
    return {
        "load": load,
        "offered_items_per_sec": offered_rate,
        "goodput_items_per_sec": m["items_done"] / max(dt, 1e-9),
        "tta_p50_s": float(np.percentile(tta, 50)) if tta.size else 0.0,
        "tta_p99_s": float(np.percentile(tta, 99)) if tta.size else 0.0,
        "answered": m["answered"],
        "shed": m["shed"],
        "occupancy_mean": m["occupancy_mean"],
        "seconds": dt,
    }


def run(samples: int = 2048, seed: int = 0, lanes: int = 8,
        mean_len: int = 8, loads=(0.6, 1.5), cal_items: int = 512,
        admission: str = "queue", queue_limit: int = 0,
        quick: bool = False, smoke: bool = False) -> dict:
    """Measure capacity, then p50/p99 time-to-answer + goodput at each
    offered-load multiple in ``loads`` (>= one under- and one
    over-capacity point by default)."""
    if quick:
        samples, cal_items = min(samples, 768), 256
    if smoke:
        samples, lanes, mean_len, cal_items = 192, 4, 6, 96
    stream = make_stream("hatespeech", seed=seed, n_samples=samples)
    cfg = default_cascade_config(n_classes=stream.spec.n_classes,
                                 mu=3e-7, seed=seed)
    engine = BatchedCascadeEngine(cfg, SimulatedExpert(stream),
                                  n_streams=lanes, history_limit=0,
                                  commit_log=True)
    # phase 1: full-occupancy service rate (also warms every jit)
    cal = make_stream("hatespeech", seed=seed + 1, n_samples=cal_items)
    t0 = time.time()
    engine.run(cal)
    capacity = cal_items / max(time.time() - t0, 1e-9)
    # re-measure warm: the first run pays every compile
    engine.reset()
    t0 = time.time()
    engine.run(cal)
    capacity = cal_items / max(time.time() - t0, 1e-9)
    print(f"capacity: {capacity:.1f} items/s at full occupancy "
          f"({lanes} lanes, {cal_items} calibration items)")
    points = []
    for load in loads:
        p = _point(engine, stream, load=load, capacity=capacity,
                   mean_len=mean_len, seed=seed, admission=admission,
                   queue_limit=queue_limit)
        points.append(p)
        print(f"load={load:.2f}x offered={p['offered_items_per_sec']:.1f}/s"
              f" goodput={p['goodput_items_per_sec']:.1f}/s  "
              f"tta p50={p['tta_p50_s'] * 1e3:.0f}ms "
              f"p99={p['tta_p99_s'] * 1e3:.0f}ms  "
              f"answered={p['answered']} shed={p['shed']} "
              f"occ={p['occupancy_mean']:.2f}/{lanes}")
    under = min(points, key=lambda p: p["load"])
    over = max(points, key=lambda p: p["load"])
    out = {
        "capacity_items_per_sec": capacity,
        "points": points,
        "headline_goodput_over": over["goodput_items_per_sec"],
        "headline_p99_under_s": under["tta_p99_s"],
        "headline_p99_over_s": over["tta_p99_s"],
    }
    if over is not under and under["tta_p99_s"] > 0:
        ratio = over["tta_p99_s"] / under["tta_p99_s"]
        print(f"overload p99 blowup: {ratio:.1f}x "
              f"({under['tta_p99_s'] * 1e3:.0f}ms -> "
              f"{over['tta_p99_s'] * 1e3:.0f}ms), goodput held at "
              f"{over['goodput_items_per_sec']:.1f}/s vs "
              f"{over['offered_items_per_sec']:.1f}/s offered")
        out["headline_p99_ratio"] = ratio
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lanes", type=int, default=8,
                    help="lane-pool capacity (concurrent streams)")
    ap.add_argument("--mean-len", type=int, default=8,
                    help="mean request length in items")
    ap.add_argument("--loads", type=float, nargs="*", default=[0.6, 1.5],
                    help="offered-load multiples of measured capacity "
                         "(default one under-, one over-capacity point)")
    ap.add_argument("--admission", default="queue",
                    choices=["queue", "shed"])
    ap.add_argument("--queue-limit", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (benchmarks/run.py --quick)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, bounded runtime")
    args = ap.parse_args()
    run(samples=args.samples, seed=args.seed, lanes=args.lanes,
        mean_len=args.mean_len, loads=tuple(args.loads),
        admission=args.admission, queue_limit=args.queue_limit,
        quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
