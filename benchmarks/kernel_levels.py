"""Kernel-backed cascade levels: path timing, cost/accuracy, roofline.

Three sections, each honest about what the 1-core CPU container can and
cannot measure:

* ``paths`` — batched forward latency of each kernel-backed level down
  its two paths: the Pallas kernel path (what the route pass serves) and
  the jnp reference path (what the weighted loss differentiates).  On
  CPU the kernels run in **interpret mode**, which is an emulation and
  *slower* than the fused jnp reference — the number documents the
  correctness-checking overhead, not TPU performance.  TPU-relevant
  projections come from the roofline section instead.
* ``cascade`` — the lr -> tinytf_flash -> ssm ladder
  (``kernel_cascade_config``, CI-sized specs) served end-to-end by
  ``BatchedCascadeEngine``, reporting accuracy and paid cost units
  against the expert-only stream: the paper's cost-vs-accuracy claim on
  the kernel path.
* ``roofline`` — analytic per-item FLOPs/bytes of the *default* (full
  size) level specs pushed through ``metrics.roofline.roofline_terms``
  on the v5e envelope: where each level sits on the roofline and the
  projected per-item latency floor the kernels are chasing.

CSV convention: name,us_per_call,derived.
"""
from __future__ import annotations

import time


def _timed(fn, *args, iters: int = 3) -> float:
    """Median-free honest wall timing: warm once (compile), then average
    ``iters`` synchronous calls."""
    import jax
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def _bench_paths(tf_spec, ssm_spec, batch: int, seed: int) -> list:
    import jax
    import jax.numpy as jnp

    from repro.models.kernel_students import (
        ssm_student_init, ssm_student_logits, tinytf_flash_init,
        tinytf_flash_logits)

    key = jax.random.PRNGKey(seed)
    rows = []
    for name, spec, init, logits in (
            ("tinytf_flash", tf_spec, tinytf_flash_init,
             tinytf_flash_logits),
            ("ssm", ssm_spec, ssm_student_init, ssm_student_logits)):
        params = init(key, spec)
        toks = jax.random.randint(jax.random.fold_in(key, 1),
                                  (batch, spec.max_len), 1, spec.vocab,
                                  jnp.int32)
        fk = jax.jit(lambda p, t, s=spec: logits(p, t, s,
                                                 use_kernels=True))
        fr = jax.jit(lambda p, t, s=spec: logits(p, t, s,
                                                 use_kernels=False))
        tk, tr = _timed(fk, params, toks), _timed(fr, params, toks)
        rows.append({"level": name, "batch": batch,
                     "kernel_us_per_item": tk / batch * 1e6,
                     "ref_us_per_item": tr / batch * 1e6,
                     "interpret_overhead": tk / tr})
        print(f"[kernel_levels] {name:>13} batch={batch:<3d} "
              f"kernel={tk / batch * 1e6:9.1f} us/item  "
              f"ref={tr / batch * 1e6:9.1f} us/item  "
              f"(interpret overhead {tk / tr:.1f}x)")
    return rows


def _bench_cascade(tf_spec, ssm_spec, samples: int, seed: int) -> dict:
    import numpy as np

    from repro.core import (BatchedCascadeEngine, SimulatedExpert,
                            kernel_cascade_config)
    from repro.data import make_stream

    stream = make_stream("hatespeech", seed=seed, n_samples=samples)
    cfg = kernel_cascade_config(n_classes=stream.spec.n_classes, mu=3e-6,
                                seed=seed, tf_flash_spec=tf_spec,
                                ssm_spec=ssm_spec)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")
    eng = BatchedCascadeEngine(cfg, expert, n_streams=8)
    t0 = time.time()
    m = eng.run(stream)
    dt = time.time() - t0
    expert_acc = float(np.mean(stream.expert_labels("gpt-3.5-turbo")
                               == stream.labels))
    paid = float(m["total_cost_units"])
    always = cfg.expert_cost * len(stream)
    row = {
        "samples": samples, "accuracy": m["accuracy"],
        "expert_accuracy": expert_acc,
        "expert_calls": int(np.sum(eng.expert_calls)),
        "cost_units": paid, "expert_only_cost_units": always,
        "cost_savings": 1.0 - paid / always,
        "level_fractions": m["level_fractions"],
        "items_per_sec": samples / dt,
    }
    print(f"[kernel_levels] cascade acc={row['accuracy']:.3f} "
          f"(LLM {expert_acc:.3f})  cost={paid:.3g}/{always:.3g} units "
          f"(savings {row['cost_savings']:.1%})  "
          f"expert_calls={row['expert_calls']}/{samples}")
    return row


def _bench_roofline(batch: int = 8) -> list:
    """Analytic v5e placement of the *default-size* level specs."""
    from repro.metrics.costs import (ssm_student_flops,
                                     tinytf_flash_flops)
    from repro.metrics.roofline import V5E, roofline_terms
    from repro.models.kernel_students import (SSMStudentSpec,
                                              TinyTFFlashSpec)

    tf, sm = TinyTFFlashSpec(), SSMStudentSpec()
    rows = []
    for name, spec, flops in (
            ("tinytf_flash", tf, tinytf_flash_flops(tf)),
            ("ssm", sm, ssm_student_flops(sm))):
        # bytes/item: params read once per batch + activations streamed
        # (fp32).  Embedding rows are gathered, not streamed whole.
        n_params = sum(_param_count(name, spec))
        act = spec.max_len * spec.d_model * 4.0 * 6  # resid/qkv/ff traffic
        bytes_item = n_params * 4.0 / batch + act
        t = roofline_terms(flops * batch, bytes_item * batch, 0.0, V5E)
        rows.append({"level": name, "flops_per_item": flops,
                     "bytes_per_item": bytes_item, **t})
        print(f"[kernel_levels] roofline {name:>13} "
              f"{flops:10.3g} FLOP/item  dominant={t['dominant']:<7} "
              f"floor={t['bound_s'] / batch * 1e6:7.2f} us/item "
              f"cf={t['compute_fraction']:.2f}")
    return rows


def _param_count(name, spec):
    """Coarse parameter tally (embeddings dominate both students)."""
    d = spec.d_model
    yield spec.vocab * d
    if name == "tinytf_flash":
        yield spec.max_len * d
        yield spec.n_layers * (4 * d * d + 2 * d * spec.d_ff)
        yield 2 * d * d        # readout k/v
    else:
        d_in = spec.expand * d
        yield spec.n_layers * (d * (2 * d_in + 2 * spec.d_state
                                    + d_in // spec.head_dim)
                               + d_in * d)
    yield d * spec.n_classes


def run(samples: int = 192, seed: int = 0, quick: bool = False) -> dict:
    """Entry point (wired into benchmarks.run)."""
    from repro.models.kernel_students import TINY_SSM_CI, TINY_TF_CI

    # CI-sized specs: interpret-mode Pallas on CPU; matches the tier-1
    # parity shapes (tests/test_kernel_levels.py).
    tf_spec, ssm_spec = TINY_TF_CI, TINY_SSM_CI
    if quick:
        samples = min(samples, 96)

    paths = _bench_paths(tf_spec, ssm_spec, batch=8, seed=seed)
    cascade = _bench_cascade(tf_spec, ssm_spec, samples, seed)
    roofline = _bench_roofline()
    return {"paths": paths, "cascade": cascade, "roofline": roofline,
            "headline_savings": cascade["cost_savings"],
            "headline_accuracy": cascade["accuracy"]}


if __name__ == "__main__":
    run()
