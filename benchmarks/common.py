"""Shared helpers for the paper-reproduction benchmarks.

Scale note: the paper streams the full datasets (6.5k-25k items).  On this
1-core CPU container each benchmark defaults to a reduced stream
(--samples) so the whole suite finishes in minutes; pass --full for
paper-scale runs.  Budgets N are scaled proportionally.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (
    OnlineCascade, OnlineEnsemble, SimulatedExpert, default_cascade_config,
    distill_students)
from repro.data import make_stream

ART_DIR = os.environ.get("REPRO_ARTIFACTS", "artifacts/benchmarks")

EXPERTS = {"gpt-3.5-turbo": "GPT-3.5 Turbo",
           "llama-2-70b-chat": "Llama 2 70B Chat"}


def art_path(name: str) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    return os.path.join(ART_DIR, name)


def save_json(name: str, obj) -> str:
    p = art_path(name)
    with open(p, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return p


def run_cascade(dataset: str, expert_name: str, mu: float, *, samples: int,
                seed: int = 0, order: str = "default",
                hard_budget=None, large: bool = False) -> dict:
    stream = make_stream(dataset, seed=seed, n_samples=samples, order=order)
    expert = SimulatedExpert(stream, expert_name)
    cfg = default_cascade_config(n_classes=stream.spec.n_classes, mu=mu,
                                 seed=seed, large=large)
    if hard_budget is not None:
        from dataclasses import replace
        cfg = replace(cfg, hard_budget=hard_budget)
    cas = OnlineCascade(cfg, expert)
    t0 = time.time()
    m = cas.run(stream)
    m["seconds"] = time.time() - t0
    m["us_per_call"] = m["seconds"] / max(samples, 1) * 1e6
    m.pop("predictions", None)
    m["expert_accuracy"] = float(
        np.mean(stream.expert_labels(expert_name) == stream.labels))
    m["history_level"] = cas.history["level"]
    m["history_J"] = cas.history["J"]
    return m


def run_ensemble(dataset: str, expert_name: str, budget: int, *,
                 samples: int, seed: int = 0, order: str = "default",
                 decay: float = 0.999) -> dict:
    stream = make_stream(dataset, seed=seed, n_samples=samples, order=order)
    expert = SimulatedExpert(stream, expert_name)
    cfg = default_cascade_config(n_classes=stream.spec.n_classes, seed=seed)
    ens = OnlineEnsemble(cfg, expert, expert_prob_decay=decay)
    m = ens.run(stream, hard_budget=budget)
    m.pop("predictions", None)
    return m


def run_distill(dataset: str, expert_name: str, budget: int, *,
                samples: int, seed: int = 0) -> dict:
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    expert = SimulatedExpert(stream, expert_name)
    res = distill_students(stream, expert, budget_n=budget, epochs=3,
                           seed=seed)
    res.pop("test_idx", None)
    return res
