"""Paper Appendix C.1: training & inference cost equilibrium, rebuilt from
our FLOP model (TPU deployment, DESIGN.md §4).

Reports per-model FLOPs, the cascade's relative cost units (LR = 1), and
the equilibrium M = xC / (3 - 2x): the largest aggregate student size that
still saves cost when students handle x of the queries.
"""
from __future__ import annotations

import argparse

from benchmarks.common import save_json
from repro.configs import get_config, list_architectures
from repro.metrics.costs import (
    expert_decode_flops, expert_prefill_flops, lr_flops, tinytf_flops)
from repro.models.students import LRSpec, TinyTFSpec


def run(doc_len: int = 512, quick: bool = False):
    lr_spec = LRSpec()
    tf_spec = TinyTFSpec()
    out = {
        "students": {
            "lr_inference_flops": lr_flops(lr_spec),
            "lr_train_flops": lr_flops(lr_spec, train=True),
            "tinytf_inference_flops": tinytf_flops(tf_spec),
            "tinytf_train_flops": tinytf_flops(tf_spec, train=True),
        },
        "experts": {},
        "equilibrium": {},
    }
    archs = list_architectures() if not quick else ["internlm2-1.8b",
                                                    "mixtral-8x22b"]
    base = lr_flops(lr_spec)
    for arch in archs:
        cfg = get_config(arch)
        pf = expert_prefill_flops(cfg, doc_len)
        out["experts"][arch] = {
            "prefill_flops": pf,
            "decode_flops_32k": expert_decode_flops(cfg, 32768),
            "cost_units_vs_lr": pf / base,
        }
    # paper C.1: 100%*C = x*M + (1-x)*(M + 2M + C)  =>  M = xC/(3-2x)
    C = out["experts"].get("mixtral-8x22b",
                           list(out["experts"].values())[0])[
        "prefill_flops"]
    for x in (0.3, 0.5, 0.7, 0.9):
        M = x * C / (3 - 2 * x)
        out["equilibrium"][f"x={x}"] = {
            "max_student_flops": M,
            "paper_formula": "M = xC/(3-2x)",
        }
    students_total = (out["students"]["lr_inference_flops"]
                      + out["students"]["tinytf_inference_flops"])
    out["cascade_students_total_flops"] = students_total
    out["students_below_equilibrium_at_x=0.5"] = bool(
        students_total < out["equilibrium"]["x=0.5"]["max_student_flops"])
    print(f"LR={out['students']['lr_inference_flops']:.2e} FLOPs, "
          f"tinyTF={out['students']['tinytf_inference_flops']:.2e} FLOPs")
    for arch, d in out["experts"].items():
        print(f"{arch}: prefill({doc_len})={d['prefill_flops']:.3e} FLOPs "
              f"= {d['cost_units_vs_lr']:.1e} LR-units")
    print(f"equilibrium x=0.5: students may aggregate up to "
          f"{out['equilibrium']['x=0.5']['max_student_flops']:.3e} FLOPs; "
          f"ours={students_total:.3e} -> saves="
          f"{out['students_below_equilibrium_at_x=0.5']}")
    save_json("cost_equilibrium.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--doc-len", type=int, default=512)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.doc_len, args.quick)


if __name__ == "__main__":
    main()
