"""Batched multi-stream engine vs the sequential Algorithm-1 loop.

Measures end-to-end serving throughput (items/sec) of
``BatchedCascadeEngine`` against the per-item ``OnlineCascade`` reference
on identical streams, seeds, and configs.  Both engines are warmed on the
stream once (compiling every jitted step) and then ``reset()`` — so the
timed pass measures the algorithm, not XLA compilation.

Two regimes are reported per batch size:

* ``learning`` — an exploration-heavy online-learning stream (slow DAgger
  decay, expert annotations and student/deferral updates throughout).
  This is where batching pays hardest: the sequential loop dispatches
  cache inserts plus four optimizer steps per expert item, the batched
  engine amortizes one update pass over the whole tick.
* ``converged`` — the same stream with the default fast-decaying
  schedule, dominated by student forwards after the gates settle.  On
  CPU the student GEMMs are already near machine throughput at batch 1,
  so the win here is dispatch amortization only; the honest number is
  small and reported as such.

CSV convention: name,us_per_call,derived.
"""
from __future__ import annotations

import time
from dataclasses import replace


def _time_run(engine, stream) -> float:
    t0 = time.time()
    engine.run(stream)
    return time.time() - t0


def _measure(cfg, stream, batch: int):
    """Warm + reset + time both engines on the same stream/config."""
    from repro.core import (BatchedCascadeEngine, OnlineCascade,
                            SimulatedExpert)
    n = len(stream)
    expert = SimulatedExpert(stream, "gpt-3.5-turbo")

    bat = BatchedCascadeEngine(cfg, expert, n_streams=batch)
    bat.run(stream)                 # compile + warm every jitted step
    bat.reset()
    bat_dt = _time_run(bat, stream)

    seq = OnlineCascade(cfg, expert)
    seq.run(stream)
    seq.reset()
    seq_dt = _time_run(seq, stream)

    return {
        "batched_items_per_sec": n / bat_dt,
        "sequential_items_per_sec": n / seq_dt,
        "speedup": seq_dt / bat_dt,
        "batched_expert_calls": int(bat.expert_calls_total),
        "sequential_expert_calls": int(seq.expert_calls),
    }


def run(samples: int = 512, seed: int = 0, batches=(64,),
        dataset: str = "hatespeech", mu: float = 3e-7,
        quick: bool = False) -> dict:
    from repro.core import default_cascade_config
    from repro.data import make_stream

    if quick:
        samples = min(samples, 256)
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    base = default_cascade_config(n_classes=stream.spec.n_classes,
                                  mu=mu, seed=seed)
    # learning regime: DAgger exploration (and therefore online updates)
    # stays active across the whole measured stream
    learn_cfg = replace(base, levels=tuple(
        replace(lvl, beta_decay=0.995) for lvl in base.levels))

    rows = []
    for batch in batches:
        r = _measure(learn_cfg, stream, batch)
        r.update(regime="learning", batch=batch)
        rows.append(r)
        r2 = _measure(base, stream, batch)
        r2.update(regime="converged", batch=batch)
        rows.append(r2)

    for r in rows:
        print(f"[batched_throughput] {r['regime']:>9} batch={r['batch']:<3d} "
              f"batched={r['batched_items_per_sec']:8.1f} it/s  "
              f"sequential={r['sequential_items_per_sec']:7.1f} it/s  "
              f"speedup={r['speedup']:.1f}x  "
              f"(expert calls {r['batched_expert_calls']}"
              f"/{r['sequential_expert_calls']})")
    headline = max(r["speedup"] for r in rows
                   if r["regime"] == "learning")
    return {"rows": rows, "headline_speedup": headline,
            "samples": samples}


if __name__ == "__main__":
    run()
