"""Paper Figures 3/4: accuracy(/recall) vs cost trade-off curves, produced
by sweeping the cost weighting factor mu (the paper's budget knob)."""
from __future__ import annotations

import argparse

from benchmarks.common import EXPERTS, run_cascade, save_json

MUS = [3e-6, 1e-6, 5e-7, 3e-7, 2e-7, 1e-7, 5e-8]


def run(samples: int = 1500, seed: int = 0, quick: bool = False):
    datasets = ["imdb", "hatespeech", "isear", "fever"]
    experts = list(EXPERTS)
    mus = MUS
    if quick:
        datasets, experts, mus = ["imdb"], ["gpt-3.5-turbo"], MUS[1:6:2]
    curves = []
    for ds in datasets:
        for expert in experts:
            pts = []
            for mu in mus:
                m = run_cascade(ds, expert, mu, samples=samples, seed=seed)
                pts.append({
                    "mu": mu, "expert_calls": m["expert_calls"],
                    "call_fraction": m["expert_calls"] / samples,
                    "accuracy": m["accuracy"],
                    "recall": m.get("recall"),
                    "f1": m.get("f1"),
                    "us_per_call": m["us_per_call"],
                })
                print(f"{ds}/{expert} mu={mu:g}: "
                      f"calls={pts[-1]['expert_calls']} "
                      f"acc={pts[-1]['accuracy']:.3f}", flush=True)
            curves.append({"dataset": ds, "expert": expert,
                           "llm_accuracy": m["expert_accuracy"],
                           "points": pts})
    save_json("tradeoff_curves.json", curves)
    return curves


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=1500)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.samples, args.seed, args.quick)


if __name__ == "__main__":
    main()
