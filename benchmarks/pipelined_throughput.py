"""Pipelined route passes: does ``pipeline_depth >= 1`` actually hide
host featurization/routing behind device compute?

The unpipelined engine syncs every tick: featurize on the host, dispatch
the level-0 forward, then *block* on dprob before routing — the device
idles through every featurization and the host idles through every
forward.  The pipelined engine (core/batched.py ``pipeline_depth``)
keeps a P-deep ring of dispatched ticks, so tick t+1's host work runs
while tick t's forward and D2H transfer are still in flight.

Two regimes, same stream/seed:

* ``converged`` — the single-exit steady state the ROADMAP calls out: a
  deep dense (MLP) student serves every lane, no expert traffic and no
  updates (``hard_budget=0`` suppresses jumps), so ticks are independent
  and speculation never fences.  This is where the pipeline pays.
* ``learning`` — expert calls and updates active.  Every committing tick
  fences or refetches (results stay exact), so the pipeline degenerates
  to the synchronous engine; reported honestly alongside the engine's
  ``pipeline_stats``.

Measurement methodology (small shared-core hosts):

* wall-clock items/sec per depth is timed INTERLEAVED against depth 0
  (alternating repetitions, median of paired ratios) so load drift
  cancels.  On a 2-core container the "device" (XLA CPU threadpool) and
  the host loop compete for the same cores, so measured overlap
  under-reports what a real accelerator realizes;
* the ``projected`` figure decomposes one unpipelined tick into its
  blocking jit roundtrip t_jit (the level-0 forward + transfer) and the
  host remainder t_host (featurize, RNG, masks, accounting), and
  projects the perfectly-overlapped tick a device-parallel host
  realizes:

      projected_speedup = (t_host + t_jit) / max(t_host, t_jit)

  Both numbers are always printed.

CSV convention: name,us_per_call,derived.
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np


def _converged_config(n_classes: int, seed: int):
    """Single dense-MLP level, no expert traffic: the post-closure
    steady state (the sharded_throughput construction, sized so the
    level-0 forward and the host work per tick are comparable — the
    regime where hiding one behind the other is worth a near-2x)."""
    from repro.core import default_cascade_config
    from repro.core.cascade import LevelSpec
    from repro.models.students import MLPSpec
    base = default_cascade_config(n_classes=n_classes, mu=3e-7, seed=seed)
    mlp_level = LevelSpec(kind="mlp", cost=120.0, cache_size=32,
                          batch_size=16, student_lr=1e-3, beta_decay=0.95,
                          calibration_factor=0.3)
    return replace(base, levels=(mlp_level,), hard_budget=0,
                   mlp_spec=MLPSpec(hidden=512, n_layers=3))


def _learning_config(n_classes: int, seed: int):
    """Default cascade with slow DAgger decay: updates stay active."""
    from repro.core import default_cascade_config
    base = default_cascade_config(n_classes=n_classes, mu=3e-7, seed=seed)
    return replace(base, levels=tuple(
        replace(lvl, beta_decay=0.995) for lvl in base.levels))


def _warm_engine(cfg, stream, expert, batch, depth):
    from repro.core import BatchedCascadeEngine
    engine = BatchedCascadeEngine(cfg, expert, n_streams=batch,
                                  pipeline_depth=depth)
    engine.run(stream)              # compile + warm every jitted step
    engine.reset()
    return engine


def _paired_rates(cfg, stream, make_expert, batch, depth, reps):
    """Interleaved wall-clock: depth-0 vs depth-P, median of paired
    ratios so machine-load drift cancels."""
    e0 = _warm_engine(cfg, stream, make_expert(), batch, 0)
    eP = _warm_engine(cfg, stream, make_expert(), batch, depth)
    n = len(stream)
    r0s, rPs, ratios = [], [], []
    for _ in range(reps):
        t0 = time.time()
        e0.run(stream)
        a = n / (time.time() - t0)
        e0.reset()
        t0 = time.time()
        mP = eP.run(stream)
        b = n / (time.time() - t0)
        stats = dict(eP.pipeline_stats)
        eP.reset()
        r0s.append(a)
        rPs.append(b)
        ratios.append(b / a)
    del mP
    return {
        "depth": depth,
        "depth0_items_per_sec": float(np.median(r0s)),
        f"depth{depth}_items_per_sec": float(np.median(rPs)),
        "wall_speedup": float(np.median(ratios)),
        "pipeline_stats": stats,
    }, e0


def _projection(e0, stream, batch, reps):
    """Decompose one unpipelined converged tick into the blocking jit
    roundtrip and the host remainder; project the overlapped tick."""
    lvl = e0.levels[0]
    n = len(stream)
    fi = np.stack([lvl.featurize(stream.docs[i]) for i in range(batch)])
    pd = e0._predict_defer[0]
    xb = e0._put_lane(fi)
    pd(lvl.params, lvl.dparams, xb)[0].block_until_ready()

    def jit_roundtrip(calls=8):
        t0 = time.time()
        for _ in range(calls):
            probs, dprob = pd(lvl.params, lvl.dparams, xb)
            np.asarray(probs), np.asarray(dprob)   # D2H, like routing
        return (time.time() - t0) / calls

    jits, ticks = [], []
    for _ in range(max(reps, 5)):
        jits.append(jit_roundtrip())
        t0 = time.time()
        e0.run(stream)
        ticks.append((time.time() - t0) / (n / batch))
        e0.reset()
    t_jit = float(np.median(jits))
    t_tick = float(np.median(ticks))
    t_host = max(t_tick - t_jit, 0.0)
    projected = (t_host + t_jit) / max(t_host, t_jit, 1e-12)
    return {
        "t_jit_ms": t_jit * 1e3,
        "t_host_ms": t_host * 1e3,
        "t_tick_ms": t_tick * 1e3,
        "projected_speedup": float(projected),
    }


def run(samples: int = 512, seed: int = 0, batch: int = 32,
        dataset: str = "hatespeech", depths=(1, 2),
        quick: bool = False) -> dict:
    """Measure converged-regime pipelined throughput + honest learning-
    regime behavior; returns a dict with per-depth rows and the
    device-parallel projection."""
    from repro.core import SimulatedExpert
    from repro.data import make_stream

    if quick:
        samples = min(samples, 256)
        depths = tuple(d for d in depths if d <= 1)
    reps = 3 if quick else 5
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    n_classes = stream.spec.n_classes

    def make_expert():
        return SimulatedExpert(stream, "gpt-3.5-turbo")

    out = {"samples": samples, "batch": batch}

    conv_cfg = _converged_config(n_classes, seed)
    conv_rows = []
    e0 = None
    for d in depths:
        row, e0 = _paired_rates(conv_cfg, stream, make_expert, batch, d,
                                reps)
        conv_rows.append(row)
        st = row["pipeline_stats"]
        assert st["refetches"] == 0 and st["update_fences"] == 0, (
            "converged regime must never fence")
        print(f"[pipelined_throughput] converged depth={d} "
              f"{row[f'depth{d}_items_per_sec']:8.1f} it/s vs depth0 "
              f"{row['depth0_items_per_sec']:8.1f} it/s "
              f"(wall {row['wall_speedup']:.2f}x)")
    proj = _projection(e0, stream, batch, reps)
    print(f"[pipelined_throughput] converged projected on a "
          f"device-parallel host: {proj['projected_speedup']:.2f}x "
          f"(t_jit {proj['t_jit_ms']:.1f}ms + t_host "
          f"{proj['t_host_ms']:.1f}ms per tick; wall-clock on this "
          f"core-starved host is reported above, honestly)")
    out["converged"] = {"rows": conv_rows, **proj}

    learn_cfg = _learning_config(n_classes, seed)
    lrow, _ = _paired_rates(learn_cfg, stream, make_expert, batch,
                            max(depths), reps)
    st = lrow["pipeline_stats"]
    print(f"[pipelined_throughput] learning  depth={lrow['depth']} "
          f"wall {lrow['wall_speedup']:.2f}x — updates force a sync "
          f"(refetches={st['refetches']} "
          f"update_fences={st['update_fences']} of "
          f"{st['submitted']} ticks); exactness preserved, overlap "
          f"honestly ~1x")
    out["learning"] = lrow

    out["headline_wall_speedup"] = max(
        r["wall_speedup"] for r in conv_rows)
    out["headline_projected_speedup"] = proj["projected_speedup"]
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(samples=args.samples, seed=args.seed, batch=args.batch,
        quick=args.quick)
