"""Theorems 3.1/3.2: empirical no-regret validation.

Two experiments:
1. Convex case (Thm 3.1 setting): an online-OGD logistic regression with
   eta_t = t^{-1/2} vs the best fixed model in hindsight (trained to
   convergence on the full prefix).  Average regret gamma/T must decay.
2. Full cascade (Thm 3.2): the average episode cost J(pi, t)/t over the
   stream must trend to a plateau (no-regret against the eventually-fixed
   policy).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import run_cascade, save_json
from repro.data import make_stream
from repro.data.features import hash_bow
from repro.models.students import LRSpec, lr_init, lr_loss
from repro.optim import adam, ogd_sqrt_t


def convex_regret(samples: int = 1500, seed: int = 0, n_features: int = 512):
    """OGD logistic regression regret vs best-fixed-in-hindsight."""
    stream = make_stream("imdb", seed=seed, n_samples=samples)
    X = np.stack([hash_bow(d, n_features) for d in stream.docs])
    y = stream.labels
    spec = LRSpec(n_features=n_features, n_classes=2)

    opt = ogd_sqrt_t(1.0)
    params = lr_init(jax.random.PRNGKey(seed), spec)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, yy):
        loss, grads = jax.value_and_grad(
            lambda p: lr_loss(p, x[None], yy[None]))(params)
        params, state = opt.step(params, grads, state)
        return params, state, loss

    online_losses = []
    for t in range(samples):
        params, state, loss = step(params, state, jnp.asarray(X[t]),
                                   jnp.asarray(y[t]))
        online_losses.append(float(loss))
    online_cum = np.cumsum(online_losses)

    # best fixed model in hindsight: train to convergence on all data
    best = lr_init(jax.random.PRNGKey(seed + 1), spec)
    bopt = adam(0.05)
    bstate = bopt.init(best)

    @jax.jit
    def bstep(params, state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: lr_loss(p, xb, yb))(params)
        params, state = bopt.step(params, grads, state)
        return params, state, loss

    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    for _ in range(300):
        best, bstate, _ = bstep(best, bstate, Xj, yj)
    fixed_losses = np.asarray(jax.vmap(
        lambda x, yy: lr_loss(best, x[None], yy[None]))(Xj, yj))
    fixed_cum = np.cumsum(fixed_losses)

    T = np.arange(1, samples + 1)
    avg_regret = (online_cum - fixed_cum) / T
    checkpoints = [samples // 8, samples // 4, samples // 2, samples - 1]
    curve = [{"t": int(t), "avg_regret": float(avg_regret[t])}
             for t in checkpoints]
    decreasing = avg_regret[checkpoints[-1]] < avg_regret[checkpoints[0]]
    print("convex OGD avg regret:",
          " ".join(f"t={c['t']}:{c['avg_regret']:.4f}" for c in curve),
          f"decreasing={decreasing}")
    return {"curve": curve, "decreasing": bool(decreasing),
            "final_avg_regret": float(avg_regret[-1])}


def cascade_cost_trend(samples: int = 1500, seed: int = 0):
    m = run_cascade("imdb", "gpt-3.5-turbo", 3e-7, samples=samples,
                    seed=seed)
    J = np.array(m["history_J"])
    T = np.arange(1, len(J) + 1)
    avg = np.cumsum(J) / T
    q = len(J) // 4
    rec = {
        "avg_J_quarters": [float(np.mean(J[i * q:(i + 1) * q]))
                           for i in range(4)],
        "avg_J_final": float(avg[-1]),
        "decreasing": bool(np.mean(J[-q:]) < np.mean(J[:q])),
    }
    print(f"cascade avg J by quarter: {rec['avg_J_quarters']} "
          f"decreasing={rec['decreasing']}")
    return rec


def run(samples: int = 1500, seed: int = 0, quick: bool = False):
    n = 600 if quick else samples
    out = {"convex_ogd": convex_regret(n, seed),
           "cascade_J": cascade_cost_trend(n, seed)}
    save_json("regret.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=1500)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.samples, args.seed, args.quick)


if __name__ == "__main__":
    main()
