"""Paper Table 1: accuracy (and recall for HateSpeech) of all methods under
matched annotation budgets N, for both experts.

Budgets are the paper's N values scaled by the reduced stream size
(paper-scale with --full).  The cascade enforces N via the hard budget
(the paper's 'maximum allowable LLM calls'), with mu supplying the
cost-pressure.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (
    EXPERTS, run_cascade, run_distill, run_ensemble, save_json)

# paper budgets on the full streams
PAPER = {
    "imdb": {"full": 25_000, "budgets": [1300, 3800, 5200]},
    "hatespeech": {"full": 10_703, "budgets": [600, 2700, 4900]},
    "isear": {"full": 7_666, "budgets": [1200, 1500, 2700]},
    "fever": {"full": 6_512, "budgets": [700, 2000, 2800]},
}


def run(samples_per_ds: int = 1500, seed: int = 0, quick: bool = False):
    rows = []
    datasets = list(PAPER) if not quick else ["imdb", "hatespeech"]
    experts = list(EXPERTS) if not quick else ["gpt-3.5-turbo"]
    for ds in datasets:
        info = PAPER[ds]
        n = min(samples_per_ds, info["full"])
        budgets = [max(int(b / info["full"] * n), 20)
                   for b in info["budgets"]]
        if quick:
            budgets = budgets[:2]
        for expert in experts:
            for b_paper, b in zip(info["budgets"], budgets):
                cas = run_cascade(ds, expert, mu=2e-7, samples=n,
                                  seed=seed, hard_budget=b)
                ens = run_ensemble(ds, expert, b, samples=n, seed=seed)
                dis = run_distill(ds, expert, b, samples=n, seed=seed)
                row = {
                    "dataset": ds, "expert": expert,
                    "budget_paper": b_paper, "budget": b, "samples": n,
                    "llm_accuracy": cas["expert_accuracy"],
                    "cascade_accuracy": cas["accuracy"],
                    "cascade_recall": cas.get("recall"),
                    "cascade_calls": cas["expert_calls"],
                    "ensemble_accuracy": ens["accuracy"],
                    "ensemble_recall": ens.get("recall"),
                    "distill_lr_accuracy": dis["lr"]["accuracy"],
                    "distill_tf_accuracy": dis["tinytf"]["accuracy"],
                    "distill_lr_recall": dis["lr"].get("recall"),
                    "distill_tf_recall": dis["tinytf"].get("recall"),
                    "us_per_call": cas["us_per_call"],
                }
                rows.append(row)
                print(f"{ds}/{expert} N={b}: "
                      f"LLM={row['llm_accuracy']:.3f} "
                      f"cascade={row['cascade_accuracy']:.3f} "
                      f"ens={row['ensemble_accuracy']:.3f} "
                      f"dLR={row['distill_lr_accuracy']:.3f} "
                      f"dTF={row['distill_tf_accuracy']:.3f}", flush=True)
    save_json("table1.json", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=1500)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(10 ** 9 if args.full else args.samples, args.seed, args.quick)


if __name__ == "__main__":
    main()
