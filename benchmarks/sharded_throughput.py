"""Lane-sharded vs single-device cascade serving throughput.

Measures `BatchedCascadeEngine` on a `data=N` virtual-device mesh against
the same engine on `data=1`, in a subprocess carrying the XLA
device-count flag (the parent process keeps its single device).  Two
regimes:

* ``converged`` — the compute-bound steady state after the gates close:
  a deep dense (MLP) student serves every lane, no expert traffic and no
  updates.  This is where lane sharding pays: the per-tick forward over
  S lanes partitions into N independent per-device programs with no
  collectives in the serving path.
* ``learning`` — online-learning regime (expert calls + student/deferral
  updates active).  The update steps run replicated (the cascade state
  is shared), so this regime scales worse — reported honestly.

Measurement methodology (this host virtualizes N devices onto few
physical cores, and wall-clock on a shared box is noisy):

* wall-clock items/sec for data=1 and data=N are timed **interleaved**
  (alternating repetitions, median of paired ratios) so machine-load
  drift cancels;
* the ``projected`` figure times the *actual per-device program* (the
  per-level jitted forward at bucket S/N) against the full-bucket
  program on one device, in the same process back-to-back, and projects
  the tick speedup a real N-device mesh realizes when each device runs
  its lane shard concurrently:

      projected_speedup = (t_host + t_jit_full) / (t_host + t_jit_shard)

  Virtual CPU devices share this host's cores, so measured wall-clock
  under-reports that concurrency; both numbers are always printed.

CSV convention: name,us_per_call,derived.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SUBPROC_SNIPPET = """
import os
ndev, S, n, reps, seed = (PARAMS["ndev"], PARAMS["batch"],
                          PARAMS["samples"], PARAMS["reps"],
                          PARAMS["seed"])
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=%d" % ndev)
import sys, time, json
sys.path.insert(0, PARAMS["src"])
import numpy as np
from dataclasses import replace
from repro.core import (BatchedCascadeEngine, SimulatedExpert,
                        default_cascade_config)
from repro.core.cascade import LevelSpec
from repro.models.students import MLPSpec
from repro.data import make_stream
from repro.launch.mesh import make_mesh

stream = make_stream("hatespeech", seed=seed, n_samples=n)
base = default_cascade_config(n_classes=stream.spec.n_classes, mu=3e-7,
                              seed=seed)

# converged regime: one deep dense student serves every lane
# (hard_budget=0 suppresses jumps and expert calls — the post-closure
# steady state, which is pure batched student forwards)
mlp_level = LevelSpec(kind="mlp", cost=120.0, cache_size=32, batch_size=16,
                      student_lr=1e-3, beta_decay=0.95,
                      calibration_factor=0.3)
conv_cfg = replace(base, levels=(mlp_level,), hard_budget=0,
                   mlp_spec=MLPSpec(hidden=1024, n_layers=8))
# learning regime: the default cascade with slow DAgger decay (expert
# calls and online updates active throughout)
learn_cfg = replace(base, levels=tuple(
    replace(lvl, beta_decay=0.995) for lvl in base.levels))


def engine(cfg, nd):
    mesh = make_mesh((nd, 1), ("data", "model"))
    e = BatchedCascadeEngine(cfg, SimulatedExpert(stream, "gpt-3.5-turbo"),
                             n_streams=S, mesh=mesh)
    e.run(stream)        # compile + warm
    e.reset()
    return e


def paired_rates(cfg):
    e1, eN = engine(cfg, 1), engine(cfg, ndev)
    r1s, rNs, ratios = [], [], []
    for _ in range(reps):          # interleaved: load drift cancels
        t0 = time.time(); e1.run(stream); a = n / (time.time() - t0)
        e1.reset()
        t0 = time.time(); eN.run(stream); b = n / (time.time() - t0)
        eN.reset()
        r1s.append(a); rNs.append(b); ratios.append(b / a)
    return e1, (float(np.median(r1s)), float(np.median(rNs)),
                float(np.median(ratios)))


def projection(e1):
    # time the per-level jitted forward at the full bucket vs the
    # per-device shard bucket, same device, INTERLEAVED (alternating
    # pairs, median of paired ratios) so host-load drift cancels just
    # like the wall-clock measurement
    lvl = e1.levels[0]
    fi = np.stack([lvl.featurize(stream.docs[i]) for i in range(S)])
    pd = e1._predict_defer[0]
    xb_full = e1._put_lane(fi)
    xb_shard = e1._put_lane(fi[: max(S // ndev, 1)])
    pd(lvl.params, lvl.dparams, xb_full)[0].block_until_ready()
    pd(lvl.params, lvl.dparams, xb_shard)[0].block_until_ready()

    def one(xb, calls=8):
        t0 = time.time()
        for _ in range(calls):
            p, d = pd(lvl.params, lvl.dparams, xb)
        p.block_until_ready()
        return (time.time() - t0) / calls

    fulls, shards = [], []
    for _ in range(max(reps, 5)):
        fulls.append(one(xb_full))
        shards.append(one(xb_shard))
    t_full = float(np.median(fulls))
    t_shard = float(np.median(shards))
    # non-jit share of a tick (featurize, RNG, masks, transfers)
    t0 = time.time()
    e1.run(stream)
    tick_wall = (time.time() - t0) / (n / S)
    e1.reset()
    t_host = max(tick_wall - t_full, 0.0)
    ratios = sorted((t_host + f) / (t_host + s)
                    for f, s in zip(fulls, shards))
    return (float(np.median(ratios)),
            {"t_jit_full_ms": t_full * 1e3, "t_jit_shard_ms": t_shard * 1e3,
             "t_host_ms": t_host * 1e3})


out = {"ndev": ndev, "batch": S, "samples": n}
e1, (r1, rN, wall) = paired_rates(conv_cfg)
proj, detail = projection(e1)
out["converged"] = {
    "data1_items_per_sec": r1, f"data{ndev}_items_per_sec": rN,
    "wall_speedup": wall, "projected_speedup": proj,
    f"data{ndev}_projected_items_per_sec": r1 * proj, **detail,
}
_, (r1l, rNl, walll) = paired_rates(learn_cfg)
out["learning"] = {
    "data1_items_per_sec": r1l, f"data{ndev}_items_per_sec": rNl,
    "wall_speedup": walll,
}
print("RESULT " + json.dumps(out))
"""


def run(samples: int = 512, seed: int = 0, devices: int = 8,
        batch: int = 64, quick: bool = False) -> dict:
    if quick:
        samples = min(samples, 256)
    reps = 3 if quick else 5
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    params = dict(ndev=devices, src=src, batch=batch, samples=samples,
                  seed=seed, reps=reps)
    code = f"PARAMS = {params!r}\n" + SUBPROC_SNIPPET
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=3000,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded_throughput subprocess failed:\n"
                           f"{proc.stderr[-3000:]}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])

    c, le = res["converged"], res["learning"]
    nd = res["ndev"]
    print(f"[sharded_throughput] converged batch={batch} "
          f"data1={c['data1_items_per_sec']:8.1f} it/s  "
          f"data{nd}={c[f'data{nd}_items_per_sec']:8.1f} it/s "
          f"(wall {c['wall_speedup']:.2f}x)")
    print(f"[sharded_throughput] converged projected on a real "
          f"{nd}-device mesh: "
          f"{c[f'data{nd}_projected_items_per_sec']:8.1f} it/s "
          f"({c['projected_speedup']:.2f}x; per-device shard "
          f"{c['t_jit_shard_ms']:.1f}ms vs full bucket "
          f"{c['t_jit_full_ms']:.1f}ms + host {c['t_host_ms']:.1f}ms)")
    print(f"[sharded_throughput] learning  batch={batch} "
          f"data1={le['data1_items_per_sec']:8.1f} it/s  "
          f"data{nd}={le[f'data{nd}_items_per_sec']:8.1f} it/s "
          f"(wall {le['wall_speedup']:.2f}x; updates replicated)")
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(samples=args.samples, seed=args.seed, devices=args.devices,
        batch=args.batch, quick=args.quick)
