"""Expert pool + per-lane commit granularity: does the pool actually
scale annotation throughput, and does per-lane commit actually cut
annotation-commit latency?

Two measurements, reported honestly on this host:

1. **Commit latency** (SimulatedExpert behind a per-ANNOTATION wall
   clock pad — a rate-limited remote LLM endpoint stand-in — learning
   regime, D=2).  Three rows isolate the two tentpole axes:

   * ``tick W=1`` — the PR-3 drain: one worker, whole-tick commits at
     age exactly D; when per-tick annotation demand exceeds one
     worker's rate the queue backlog shows up directly as commit wall
     latency;
   * ``tick W=4`` — pool only: sharded ``submit_many`` capacity clears
     the backlog, commits still land at age D;
   * ``lane W=4`` — pool + the per-lane spread schedule
     (core/batched.py ``lanes_due``): mean commit age drops toward
     (D+1)/2.  (Per-lane is a different — documented — update
     trajectory with per-item update dispatch, so expert-call counts
     and engine throughput differ; both are reported.)

2. **Pool throughput scaling** (``submit_many`` microbench): time k
   annotations submit->resolve at workers W in {1, 2, 4}, in two expert
   regimes:

   * ``padded`` — each annotation pays the per-item latency pad, so a
     shard of m items costs m*pad at its worker (the rate-limited
     endpoint): shards wait concurrently and throughput should scale
     ~linearly in W;
   * ``model`` — the in-repo transformer ``ModelExpert``: shard
     forwards share this host's CPU, so scaling is bounded by how much
     the jitted forwards actually interleave (GIL released during
     device execution); reported honestly, expect well under linear on
     a small box.

CSV convention: name,us_per_call,derived.
"""
from __future__ import annotations

import time


class _PaddedSimulatedExpert:
    """SimulatedExpert plus a wall-clock pad per ANNOTATION (so a shard
    of m items costs m*pad at its worker — a rate-limited remote
    endpoint stand-in), with the full pooled async interface."""

    def __init__(self, base, pad_s: float, workers: int = 1):
        from concurrent.futures import ThreadPoolExecutor
        self.base = base
        self.pad_s = pad_s
        self.workers = max(int(workers), 1)
        self.cost = base.cost
        self.name = f"{base.name}+{pad_s * 1e3:.0f}ms/ann"
        self._pool = ThreadPoolExecutor(max_workers=self.workers)

    def _annotate(self, idxs, docs):
        time.sleep(self.pad_s * max(len(idxs), 1))
        return self.base.label_batch(idxs, docs)

    def label(self, idx, doc):
        time.sleep(self.pad_s)
        return self.base.label(idx, doc)

    def label_batch(self, idxs, docs):
        return self._annotate(idxs, docs)

    def submit(self, idxs, docs):
        from repro.core.experts import ExpertTicket
        return ExpertTicket(
            future=self._pool.submit(self._annotate, list(idxs),
                                     list(docs)))

    def submit_many(self, idxs, docs):
        from repro.core.experts import ExpertTicket, shard_bounds
        idxs, docs = list(idxs), list(docs)
        shards = [(lo, hi, self._pool.submit(self._annotate, idxs[lo:hi],
                                             docs[lo:hi]))
                  for lo, hi in shard_bounds(len(idxs), self.workers)]
        return ExpertTicket(shards=shards)

    def poll(self, ticket, block=True):
        from repro.core.experts import poll_ticket
        return poll_ticket(ticket, block)

    def close(self):
        self._pool.shutdown(wait=True)


def _commit_latency(cfg, stream, batch, pad_ms, per_lane, workers):
    from repro.core import BatchedCascadeEngine, SimulatedExpert
    expert = _PaddedSimulatedExpert(
        SimulatedExpert(stream, "gpt-3.5-turbo"), pad_ms / 1e3,
        workers=workers)
    engine = BatchedCascadeEngine(cfg, expert, n_streams=batch,
                                  max_delay=2, per_lane=per_lane,
                                  history_limit=0)
    engine.run(stream)              # compile + warm
    engine.reset()
    t0 = time.time()
    m = engine.run(stream)
    dt = time.time() - t0
    cs = engine.commit_stats
    expert.close()
    lanes = max(cs["lanes"], 1)
    return {
        "mode": "lane" if per_lane else "tick",
        "workers": workers,
        "items_per_sec": len(stream) / dt,
        "mean_commit_age_ticks": cs["age_sum"] / lanes,
        "mean_commit_latency_ms": cs["wall_sum"] / lanes * 1e3,
        "expert_calls": m["expert_calls"],
        "accuracy": m["accuracy"],
    }


def _pool_scaling(stream, k, workers_list, pad_ms, repeats=5):
    """submit_many -> result wall time per W, padded + model regimes."""
    from repro.core import ModelExpert, SimulatedExpert
    from repro.core.experts import train_model_expert

    model = train_model_expert(stream, stream.spec.n_classes,
                               d_model=128, n_layers=2, epochs=1,
                               max_samples=min(512, len(stream)), seed=0)
    idxs = list(range(k))
    docs = stream.docs[:k]
    out = {"padded": [], "model": []}
    for regime in ("padded", "model"):
        for w in workers_list:
            if regime == "padded":
                exp = _PaddedSimulatedExpert(
                    SimulatedExpert(stream, "gpt-3.5-turbo"),
                    pad_ms / 1e3, workers=w)
            else:
                exp = ModelExpert(params=model.params, spec=model.spec,
                                  cost=model.cost, workers=w)
            exp.poll(exp.submit_many(idxs, docs))      # warm the pool
            t0 = time.time()
            for _ in range(repeats):
                exp.poll(exp.submit_many(idxs, docs))
            dt = (time.time() - t0) / repeats
            exp.close()
            out[regime].append({"workers": w, "dt": dt,
                                "anns_per_sec": k / dt})
        base = out[regime][0]["dt"]
        for r in out[regime]:
            r["speedup_vs_w1"] = base / r["dt"]
    model.close()
    return out


def run(samples: int = 384, seed: int = 0, batch: int = 16,
        dataset: str = "hatespeech", mu: float = 3e-7,
        pad_ms: float = 25.0, quick: bool = False) -> dict:
    from dataclasses import replace

    from repro.core import default_cascade_config
    from repro.data import make_stream

    if quick:
        samples = min(samples, 256)
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    base = default_cascade_config(n_classes=stream.spec.n_classes,
                                  mu=mu, seed=seed)
    # learning regime: slow DAgger decay keeps annotations flowing, so
    # the commit drain (not an empty queue) is what gets measured
    cfg = replace(base, levels=tuple(
        replace(lvl, beta_decay=0.995) for lvl in base.levels))

    rows = [
        _commit_latency(cfg, stream, batch, pad_ms, per_lane=False,
                        workers=1),
        _commit_latency(cfg, stream, batch, pad_ms, per_lane=False,
                        workers=4),
        _commit_latency(cfg, stream, batch, pad_ms, per_lane=True,
                        workers=4),
    ]
    for r in rows:
        print(f"[pool_throughput] commit={r['mode']:>4} W={r['workers']} "
              f"mean_age={r['mean_commit_age_ticks']:.2f} ticks  "
              f"mean_latency={r['mean_commit_latency_ms']:7.1f} ms  "
              f"{r['items_per_sec']:7.1f} it/s  "
              f"acc={r['accuracy']:.4f} calls={r['expert_calls']}")

    scaling = _pool_scaling(stream, k=64 if quick else 96,
                            workers_list=(1, 2, 4), pad_ms=4.0,
                            repeats=3 if quick else 5)
    for regime, rws in scaling.items():
        for r in rws:
            print(f"[pool_throughput] {regime:>6} W={r['workers']} "
                  f"{r['anns_per_sec']:8.1f} ann/s  "
                  f"speedup={r['speedup_vs_w1']:.2f}x")

    out = {
        "commit_latency": rows,
        "pool_scaling": scaling,
        "samples": samples,
        # per-lane spread vs the per-tick drain, same W=4 pool
        "headline_age_ratio": (rows[1]["mean_commit_age_ticks"]
                               / max(rows[2]["mean_commit_age_ticks"],
                                     1e-9)),
        # pool capacity vs the single PR-3 worker, same per-tick drain
        "headline_pool_latency_ratio": (
            rows[0]["mean_commit_latency_ms"]
            / max(rows[1]["mean_commit_latency_ms"], 1e-9)),
        "headline_padded_w4": scaling["padded"][-1]["speedup_vs_w1"],
        "headline_model_w4": scaling["model"][-1]["speedup_vs_w1"],
    }
    return out


if __name__ == "__main__":
    run()
