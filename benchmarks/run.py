"""Benchmark entrypoint: one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the repo convention.
Default is a reduced --quick-ish pass sized for the 1-core CPU container;
``--full`` runs paper-scale streams.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--samples N]
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest configuration (CI-sized)")
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip", nargs="*", default=[],
                    help="benchmark names to skip")
    ap.add_argument("--roofline-artifacts", default="artifacts/dryrun",
                    help="dry-run artifact dir aggregated by the "
                         "roofline report (see docs/MODELS.md)")
    args = ap.parse_args()

    from benchmarks import (async_throughput, batched_throughput,
                            case_analysis, cost_equilibrium,
                            distribution_shift, fault_tolerance,
                            kernel_levels, load_harness,
                            pipelined_throughput, pool_throughput,
                            prefill_cost, regret, roofline_report,
                            sharded_throughput, table1,
                            tradeoff_curves)

    quick = args.quick
    n = args.samples or (800 if quick else 1000)
    csv = []

    def record(name, t0, derived):
        us = (time.time() - t0) * 1e6
        csv.append(f"{name},{us:.0f},{derived}")

    if "batched" not in args.skip:
        t0 = time.time()
        bt = batched_throughput.run(samples=min(n, 512), seed=args.seed,
                                    batches=(64,), quick=quick)
        record("batched_throughput", t0,
               f"batch64_speedup={bt['headline_speedup']:.1f}x")

    if "async" not in args.skip:
        t0 = time.time()
        at = async_throughput.run(samples=min(n, 384), seed=args.seed,
                                  quick=quick)
        record("async_throughput", t0,
               f"padded_overlap="
               f"{at['headline_overlap_speedup']:.2f}x")

    if "pipelined" not in args.skip:
        t0 = time.time()
        pt = pipelined_throughput.run(samples=min(n, 512), seed=args.seed,
                                      quick=quick)
        record("pipelined_throughput", t0,
               f"converged_wall={pt['headline_wall_speedup']:.2f}x_"
               f"projected={pt['headline_projected_speedup']:.2f}x")

    if "pool" not in args.skip:
        t0 = time.time()
        pl = pool_throughput.run(samples=min(n, 384), seed=args.seed,
                                 quick=quick)
        record("pool_throughput", t0,
               f"commit_age_ratio={pl['headline_age_ratio']:.2f}x_"
               f"pool_latency={pl['headline_pool_latency_ratio']:.1f}x_"
               f"padded_w4={pl['headline_padded_w4']:.2f}x")

    if "sharded" not in args.skip:
        t0 = time.time()
        st = sharded_throughput.run(samples=min(n, 512), seed=args.seed,
                                    quick=quick)
        c = st["converged"]
        record("sharded_throughput", t0,
               f"data{st['ndev']}_projected="
               f"{c['projected_speedup']:.1f}x_wall="
               f"{c['wall_speedup']:.2f}x")

    if "table1" not in args.skip:
        t0 = time.time()
        rows = table1.run(samples_per_ds=n, seed=args.seed, quick=quick)
        acc = np.mean([r["cascade_accuracy"] for r in rows])
        record("table1", t0, f"mean_cascade_acc={acc:.4f}")

    if "tradeoff" not in args.skip:
        t0 = time.time()
        curves = tradeoff_curves.run(samples=max(n // 2, 500),
                                     seed=args.seed, quick=quick)
        npts = sum(len(c["points"]) for c in curves)
        record("tradeoff_curves", t0, f"points={npts}")

    if "case" not in args.skip:
        t0 = time.time()
        cases = case_analysis.run(samples=n, seed=args.seed, quick=quick)
        sv = {c["dataset"]: round(c["cost_savings"], 3) for c in cases}
        record("case_analysis", t0, f"savings={sv}")

    if "shift" not in args.skip:
        t0 = time.time()
        rows = distribution_shift.run(samples=max(n // 2, 500),
                                      seed=args.seed, quick=quick)
        d = rows[0]["length_shift_delta"]
        record("distribution_shift", t0, f"length_delta={d:+.4f}")

    if "regret" not in args.skip:
        t0 = time.time()
        rr = regret.run(samples=max(n // 2, 500), seed=args.seed,
                        quick=quick)
        record("regret", t0,
               f"avg_regret={rr['convex_ogd']['final_avg_regret']:.4f}")

    if "equilibrium" not in args.skip:
        t0 = time.time()
        cost_equilibrium.run(quick=quick)
        record("cost_equilibrium", t0, "see artifacts")

    if "load" not in args.skip:
        t0 = time.time()
        lh = load_harness.run(samples=min(n, 1024), seed=args.seed,
                              quick=quick)
        record("load_harness", t0,
               f"goodput_over={lh['headline_goodput_over']:.0f}/s_"
               f"p99_under={lh['headline_p99_under_s'] * 1e3:.0f}ms_"
               f"p99_over={lh['headline_p99_over_s'] * 1e3:.0f}ms")

    if "faults" not in args.skip:
        t0 = time.time()
        ft = fault_tolerance.run(samples=min(n, 768), seed=args.seed,
                                 quick=quick)
        record("fault_tolerance", t0,
               f"goodput_ratio={ft['headline_goodput_ratio']:.2f}x_"
               f"drops={ft['headline_drop_frac']:.1%}_"
               f"age={ft['headline_age_mean']:.2f}")

    if "prefill" not in args.skip:
        t0 = time.time()
        pf = prefill_cost.run(quick=quick)
        sp = pf["rows"][0]["speedup_vs_paper_baseline"]
        record("prefill_cost", t0, f"speedup_vs_8xA100={sp:.0f}x")

    if "kernel_levels" not in args.skip:
        t0 = time.time()
        kl = kernel_levels.run(samples=min(n, 192), seed=args.seed,
                               quick=quick)
        record("kernel_levels", t0,
               f"cascade_acc={kl['headline_accuracy']:.3f}_"
               f"savings={kl['headline_savings']:.2f}")

    if "roofline" not in args.skip:
        t0 = time.time()
        rs = roofline_report.run(art_dir=args.roofline_artifacts)
        record("roofline_report", t0,
               f"rows={rs.get('n_rows', 0)}")

    print("\nname,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
