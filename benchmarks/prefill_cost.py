"""Paper Appendix B.1: the prefill-cost experiment, on TPU terms.

The paper measured 3.6 s to prefill one 8192-token prompt on 8xA100
(LLaMA-65B, unbatched — batching OOMed).  We derive the equivalent for our
expert zoo on the v5e production mesh from the roofline model: analytic
FLOPs/bytes per prefill vs chip peaks, plus the flash-attention memory
bound that makes batched 8k prefill feasible at all (DESIGN.md §4).
"""
from __future__ import annotations

import argparse

from benchmarks.common import save_json
from repro.configs import get_config, list_architectures
from repro.metrics.costs import expert_prefill_flops
from repro.metrics.roofline import V5E

PAPER_BASELINE = {"model": "llama-65b", "gpus": "8xA100",
                  "seconds_per_8k_prompt": 3.6, "batch": 1,
                  "note": "batching OOMed (quadratic attention)"}


def run(seq: int = 8192, chips: int = 256, quick: bool = False):
    rows = []
    archs = list_architectures() if not quick else ["llama3-405b",
                                                    "mixtral-8x22b"]
    for arch in archs:
        cfg = get_config(arch)
        flops = expert_prefill_flops(cfg, seq)
        t_compute = flops / (chips * V5E.peak_flops)
        # weights read once per prefill (memory bound floor)
        wbytes = cfg.active_param_count() * 2
        t_memory = wbytes / (chips * V5E.hbm_bw)
        t = max(t_compute, t_memory)
        rows.append({
            "arch": arch, "seq": seq, "chips": chips,
            "prefill_flops": flops,
            "seconds_per_prompt": t,
            "compute_s": t_compute, "memory_s": t_memory,
            "speedup_vs_paper_baseline": PAPER_BASELINE[
                "seconds_per_8k_prompt"] / t,
        })
        print(f"{arch}: prefill({seq}) = {flops:.3e} FLOPs -> "
              f"{t*1000:.2f} ms on {chips}xv5e "
              f"({rows[-1]['speedup_vs_paper_baseline']:.0f}x the paper's "
              f"8xA100 65B baseline)", flush=True)
    out = {"paper_baseline": PAPER_BASELINE, "rows": rows}
    save_json("prefill_cost.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=8192)
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.seq, args.chips, args.quick)


if __name__ == "__main__":
    main()
