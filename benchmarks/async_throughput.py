"""Async expert queue: does ``max_delay >= 1`` actually overlap the
expert forward with student compute?

The synchronous batched engine (``max_delay=0``) serializes every tick:
route the lanes, then *wait* for the expert's batched forward, then
update.  With a real ``ModelExpert`` the expert call is the latency wall
— devices sit idle while the host drives the expert.  The async queue
(core/batched.py route/commit split) submits the deferred subset to a
worker thread and lets the next tick's student compute proceed; the
annotation lands within ``max_delay`` ticks.

Two expert regimes are measured, same stream/seed/config:

* ``model`` — the in-repo transformer ``ModelExpert``.  Its forward runs
  on the same host the students use, so the measurable overlap on a
  small CPU container is bounded by how much the two workloads actually
  interleave (jitted dispatch releases the GIL); reported honestly.
* ``padded`` — the same ModelExpert plus a fixed per-call latency pad
  (stands in for a remote LLM endpoint where network + queueing
  dominate).  Here the expert wall-clock is pure waiting, so the async
  engine should hide nearly all of it; this is the serving-realistic
  regime the ROADMAP's async item targets.

Accuracy and expert-call counts are reported per delay: the bounded
annotation delay trades a (small, measured) accuracy hit on the
provisionally-answered deferred lanes for the overlap win — routing
draws and annotations themselves are delay-invariant by construction.

CSV convention: name,us_per_call,derived.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import replace


class _PaddedExpert:
    """A base expert plus a fixed per-call latency pad (remote-endpoint
    stand-in).  Implements the full sync + async annotation interface."""

    def __init__(self, base, pad_s: float):
        self.base = base
        self.pad_s = pad_s
        self.cost = base.cost
        self.name = f"{getattr(base, 'name', 'expert')}+{pad_s * 1e3:.0f}ms"
        self._executor = None

    def label(self, idx, doc):
        time.sleep(self.pad_s)
        return self.base.label(idx, doc)

    def label_batch(self, idxs, docs):
        time.sleep(self.pad_s)
        return self.base.label_batch(idxs, docs)

    def submit(self, idxs, docs):
        from repro.core.experts import ExpertTicket
        if self._executor is None:
            self._executor = ThreadPoolExecutor(max_workers=1)
        return ExpertTicket(future=self._executor.submit(
            self.label_batch, list(idxs), list(docs)))

    def poll(self, ticket, block=True):
        from repro.core.experts import poll_ticket
        return poll_ticket(ticket, block)

    def close(self):
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None


def _measure(cfg, stream, expert, batch: int, delay: int) -> dict:
    from repro.core import BatchedCascadeEngine
    engine = BatchedCascadeEngine(cfg, expert, n_streams=batch,
                                  max_delay=delay)
    engine.run(stream)              # compile + warm every jitted step
    engine.reset()
    t0 = time.time()
    m = engine.run(stream)
    dt = time.time() - t0
    return {
        "delay": delay,
        "items_per_sec": len(stream) / dt,
        "dt": dt,
        "accuracy": m["accuracy"],
        "expert_calls": m["expert_calls"],
    }


def run(samples: int = 384, seed: int = 0, batch: int = 32,
        dataset: str = "hatespeech", mu: float = 3e-7,
        delays=(0, 1, 2), pad_ms: float = 100.0,
        quick: bool = False) -> dict:
    from repro.core import default_cascade_config
    from repro.core.experts import train_model_expert
    from repro.data import make_stream

    if quick:
        samples = min(samples, 256)
        delays = tuple(d for d in delays if d <= 1)
    stream = make_stream(dataset, seed=seed, n_samples=samples)
    expert = train_model_expert(stream, stream.spec.n_classes,
                                d_model=128, n_layers=2, epochs=1,
                                max_samples=min(512, samples), seed=seed)
    base = default_cascade_config(n_classes=stream.spec.n_classes,
                                  mu=mu, seed=seed, expert_cost=expert.cost)
    # learning regime: slow DAgger decay keeps expert annotations (and
    # therefore the expert on the critical path) throughout the stream
    cfg = replace(base, levels=tuple(
        replace(lvl, beta_decay=0.995) for lvl in base.levels))

    padded = _PaddedExpert(expert, pad_ms / 1e3)
    regimes = {"model": expert, "padded": padded}
    out = {}
    for regime, exp in regimes.items():
        rows = [_measure(cfg, stream, exp, batch, d) for d in delays]
        sync = rows[0]
        for r in rows:
            r["speedup_vs_sync"] = sync["dt"] / r["dt"]
            r["accuracy_delta"] = r["accuracy"] - sync["accuracy"]
            print(f"[async_throughput] {regime:>6} delay={r['delay']} "
                  f"{r['items_per_sec']:8.1f} it/s  "
                  f"speedup={r['speedup_vs_sync']:.2f}x  "
                  f"acc={r['accuracy']:.4f} "
                  f"({r['accuracy_delta']:+.4f})  "
                  f"expert_calls={r['expert_calls']}")
        out[regime] = rows
    padded.close()
    expert.close()
    async_rows = [r for r in out["padded"] if r["delay"] >= 1]
    out["headline_overlap_speedup"] = max(
        r["speedup_vs_sync"] for r in async_rows) if async_rows else 1.0
    out["samples"] = samples
    return out


if __name__ == "__main__":
    run()
