"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

For a chosen (arch, shape) pair this runs the paper-faithful baseline and a
ladder of candidate changes (each an explicit dry-run option), recording
the three roofline terms before/after into artifacts/perf/.  The napkin
math and confirmed/refuted verdicts are written into EXPERIMENTS.md §Perf
by hand — this driver produces the measurements.

  PYTHONPATH=src python -m benchmarks.perf_hillclimb \
      --arch llama3-405b --shape train_4k
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json

# candidate ladders per step kind; each entry: (name, hypothesis, kwargs)
TRAIN_LADDER = [
    ("baseline", "paper-faithful: AdamW fp32, remat, TP+DP sharding", {}),
    ("zero", "ZeRO-shard optimizer moments over data: HBM/dev down by "
             "~8B/param/dp; no FLOP/collective change in the step itself",
     {"zero": True}),
    ("bf16_opt", "bf16 moments halve optimizer bytes again",
     {"zero": True, "opt_dtype": "bfloat16"}),
    ("seq_parallel", "shard activation seq dim over model: scan carries "
                     "/16, TP all-reduce -> RS/AG halves wire bytes",
     {"zero": True, "opt_dtype": "bfloat16", "seq_parallel": True}),
    ("loss_chunk", "chunk the softmax xent: (B,S,V) fp32 logits+grad "
                   "never materialized",
     {"zero": True, "opt_dtype": "bfloat16", "seq_parallel": True,
      "loss_chunk": 512}),
]

PREFILL_LADDER = [
    ("baseline", "paper-faithful prefill sharding", {}),
    ("seq_parallel", "seq-parallel activations: carries and norms sharded "
                     "over model", {"seq_parallel": True}),
]

DECODE_LADDER = [
    ("baseline", "paper-faithful decode sharding (weights TP over model, "
                 "replicated over data)", {}),
    ("fsdp_weights", "serving has no optimizer binding weights to data "
                     "ranks: shard every weight's first free dim over "
                     "(pod,data) too -> weight bytes/dev /=dp at the cost "
                     "of an all-gather per use; decode is weight-read "
                     "bound so HBM/dev should drop sharply",
     {"shard_params_data": True}),
]

MOE_EXTRA = [
    ("expert_parallel", "shard the expert dim over model instead of "
                        "expert-ff: full-width expert GEMMs, dispatch "
                        "replicated, same psum",
     {"moe_mode": "expert"}),
]


def run(arch: str, shape: str, out_dir: str = "artifacts/perf"):
    from repro.configs import get_config
    from repro.launch.dryrun import dryrun_one

    cfg = get_config(arch)
    if shape == "train_4k":
        ladder = list(TRAIN_LADDER)
    elif shape == "prefill_32k":
        ladder = list(PREFILL_LADDER)
    else:
        ladder = list(DECODE_LADDER)
    if cfg.moe is not None and cfg.moe.num_experts % 16 == 0:
        ladder += [(n, h, {**ladder[-1][2], **kw})
                   for n, h, kw in MOE_EXTRA]

    os.makedirs(out_dir, exist_ok=True)
    results = []
    base = None
    for name, hypothesis, kwargs in ladder:
        print(f"\n### {arch} x {shape} :: {name}")
        print(f"hypothesis: {hypothesis}", flush=True)
        try:
            r = dryrun_one(arch, shape, **kwargs)
        except Exception as e:  # noqa: BLE001
            print(f"variant {name} FAILED: {e!r}")
            results.append({"variant": name, "hypothesis": hypothesis,
                            "error": repr(e)[:300]})
            continue
        r["variant"] = name
        r["hypothesis"] = hypothesis
        if base is None:
            base = r
        t, tb = r["roofline"], base["roofline"]
        r["delta_vs_baseline"] = {
            "compute_s": t["compute_s"] - tb["compute_s"],
            "memory_s": t["memory_s"] - tb["memory_s"],
            "collective_s": t["collective_s"] - tb["collective_s"],
            "hbm_gb": r["hbm_per_device_gb"] - base["hbm_per_device_gb"],
        }
        print(f"delta vs baseline: {r['delta_vs_baseline']}")
        results.append(r)
        with open(os.path.join(out_dir,
                               f"{arch}__{shape}__{name}.json"), "w") as f:
            json.dump(r, f, indent=1)
    with open(os.path.join(out_dir, f"{arch}__{shape}__ladder.json"),
              "w") as f:
        json.dump([{k: v for k, v in r.items()
                    if k in ("variant", "hypothesis", "roofline",
                             "hbm_per_device_gb", "fits_hbm",
                             "collective_bytes_per_device",
                             "flops_per_device", "bytes_per_device",
                             "delta_vs_baseline", "error")}
                   for r in results], f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()
    run(args.arch, args.shape, args.out)


if __name__ == "__main__":
    main()
