"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
roofline table and nominate the three hillclimb pairs (§Perf):
worst compute-fraction, most collective-bound, most paper-representative.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(art_dir: str, multipod: bool = False):
    rows = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        name = os.path.basename(p)
        if name.endswith("_mp.json") != multipod:
            continue
        if "__" not in name:
            continue
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_table(rows):
    hdr = (f"{'arch':<22} {'shape':<12} {'dom':<10} "
           f"{'compute_s':>10} {'memory_s':>10} {'floor_s':>9} "
           f"{'coll_s':>9} {'cf':>5} {'hbm_gb':>7} {'fit':>4} {'6ND/HLO':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        t = r["roofline"]
        ratio = r.get("model_flops_ratio")
        lines.append(
            f"{r['arch']:<22} {r['shape']:<12} {t['dominant']:<10} "
            f"{t['compute_s']:>10.4f} {t['memory_s']:>10.4f} "
            f"{t.get('memory_floor_s', 0):>9.4f} "
            f"{t['collective_s']:>9.4f} {t['compute_fraction']:>5.2f} "
            f"{r['hbm_per_device_gb']:>7.2f} "
            f"{'y' if r['fits_hbm'] else 'N':>4} "
            f"{ratio if ratio else 0:>8.3f}")
    return "\n".join(lines)


def pick_hillclimb(rows):
    """Three most interesting pairs per the assignment."""
    if not rows:
        return {}
    worst_cf = min(rows, key=lambda r: r["roofline"]["compute_fraction"])
    most_coll = max(rows, key=lambda r: r["roofline"]["collective_s"])
    # paper-representative: the expert serving step that OCL defers to —
    # large-batch decode on a large dense model.
    decode = [r for r in rows if r["shape"] == "decode_32k"]
    rep = max(decode, key=lambda r: r["flops_per_device"]) if decode \
        else rows[0]
    return {
        "worst_compute_fraction": (worst_cf["arch"], worst_cf["shape"]),
        "most_collective_bound": (most_coll["arch"], most_coll["shape"]),
        "paper_representative": (rep["arch"], rep["shape"]),
    }


def fmt_markdown(rows):
    lines = [
        "| arch | shape | dominant | compute_s | memory_s (floor) | "
        "collective_s | compute-frac | HBM GB/dev | fits | 6ND/HLO |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        t = r["roofline"]
        ratio = r.get("model_flops_ratio") or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['dominant']} "
            f"| {t['compute_s']:.4f} "
            f"| {t['memory_s']:.4f} ({t.get('memory_floor_s', 0):.4f}) "
            f"| {t['collective_s']:.4f} | {t['compute_fraction']:.2f} "
            f"| {r['hbm_per_device_gb']:.2f} "
            f"| {'yes' if r['fits_hbm'] else 'NO'} | {ratio:.3f} |")
    return "\n".join(lines)


def run(art_dir: str = "artifacts/dryrun", multipod: bool = False,
        markdown_out: str = None):
    rows = load(art_dir, multipod)
    if not rows:
        print(f"no dry-run artifacts in {art_dir} "
              f"(multipod={multipod}) — run repro.launch.dryrun first")
        return {}
    print(fmt_table(rows))
    if markdown_out:
        with open(markdown_out, "w") as f:
            f.write(fmt_markdown(rows) + "\n")
    picks = pick_hillclimb(rows)
    print("\nhillclimb picks:", json.dumps(picks, indent=1))
    summary = {"n_rows": len(rows), "picks": picks,
               "dominant_counts": {}}
    for r in rows:
        d = r["roofline"]["dominant"]
        summary["dominant_counts"][d] = \
            summary["dominant_counts"].get(d, 0) + 1
    print("dominant terms:", summary["dominant_counts"])
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--markdown-out", default=None)
    args = ap.parse_args()
    run(args.dir, args.multipod, args.markdown_out)


if __name__ == "__main__":
    main()
