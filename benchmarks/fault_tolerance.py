"""Goodput + commit-age under injected expert-fleet failures.

The elastic fleet's contract (core/experts.py, core/batched.py): under
worker deaths and shard timeouts every deferred item still commits
exactly once — requeued within its D-tick deadline when a retry lands,
or degraded to the provisional student answer (counted in
``dropped_annotations``) after ``max_requeues``.  This harness measures
what that costs: for a sweep of injected fault rates it reports

* **goodput** — items served per second (the requeue path's wall-clock
  overhead: re-submitted shards, timeout waits);
* **mean/max commit age** — how close annotation commits run to the
  D-tick deadline as faults push retries later;
* **drop fraction** — annotations degraded per deferred item (the
  accuracy-relevant loss: each drop is one missed online update);
* the full ``fault_stats`` accounting (timeouts, deaths, requeues).

The deterministic default schedule keeps routing/commit decisions
bitwise invariant to fault timing, so rate sweeps are comparable
run-to-run: only wall clock and the drop set move.

Usage:
  PYTHONPATH=src python benchmarks/fault_tolerance.py [--quick | --smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (BatchedCascadeEngine, FlakyExpert, SimulatedExpert,
                        default_cascade_config)
from repro.data import make_stream


def _engine(stream, cfg, lanes: int, rates: dict, seed: int,
            workers: int = 2, autoscale=None) -> BatchedCascadeEngine:
    inner = SimulatedExpert(stream,
                            workers="auto" if autoscale else workers)
    expert = FlakyExpert(inner, seed=seed, **rates) if rates else inner
    return BatchedCascadeEngine(cfg, expert, n_streams=lanes,
                                max_delay=2, per_lane=True,
                                history_limit=0,
                                expert_timeout=0.05, max_requeues=2,
                                autoscale=autoscale)


def _point(stream, cfg, lanes: int, *, rate: float, seed: int,
           autoscale=None) -> dict:
    """One injected-fault-rate point (rate split across timeout/death)."""
    rates = ({"timeout_rate": rate / 2, "death_rate": rate / 2}
             if rate else {})
    eng = _engine(stream, cfg, lanes, rates, seed, autoscale=autoscale)
    t0 = time.time()
    m = eng.run(stream)
    dt = time.time() - t0
    cs, fs = eng.commit_stats, eng.fault_stats
    deferred = max(int(np.sum(np.asarray(eng.expert_calls))), 1)
    out = {
        "rate": rate,
        "goodput_items_per_sec": len(stream) / max(dt, 1e-9),
        "accuracy": m["accuracy"],
        "commit_age_mean": (cs["age_sum"] / cs["lanes"]
                            if cs["lanes"] else 0.0),
        "commit_age_max": cs["age_max"],
        "drop_frac": fs["dropped_annotations"] / deferred,
        "timeouts": fs["timeouts"],
        "worker_deaths": fs["worker_deaths"],
        "requeues": fs["requeues"],
        "dropped_annotations": fs["dropped_annotations"],
        "fleet_resizes": len(eng.fleet_log),
        "seconds": dt,
    }
    eng.close()
    return out


def run(samples: int = 1536, seed: int = 0, lanes: int = 8,
        rates=(0.0, 0.05, 0.2), autoscale=None, quick: bool = False,
        smoke: bool = False) -> dict:
    """Sweep injected fault rates; report goodput, commit age, drops.

    The ``rate=0`` point is the fault-free baseline every other point
    is normalized against."""
    if quick:
        samples = min(samples, 768)
    if smoke:
        samples, lanes, rates = 192, 4, (0.0, 0.25)
    stream = make_stream("hatespeech", seed=seed, n_samples=samples)
    cfg = default_cascade_config(n_classes=stream.spec.n_classes,
                                 mu=3e-7, seed=seed)
    points = []
    for rate in rates:
        p = _point(stream, cfg, lanes, rate=rate, seed=seed,
                   autoscale=autoscale)
        points.append(p)
        print(f"rate={rate:.2f}  "
              f"goodput={p['goodput_items_per_sec']:.1f}/s  "
              f"acc={p['accuracy']:.4f}  "
              f"commit age mean={p['commit_age_mean']:.2f} "
              f"max={p['commit_age_max']}  "
              f"requeues={p['requeues']} "
              f"drops={p['dropped_annotations']} "
              f"({p['drop_frac']:.1%} of deferred)"
              + (f"  resizes={p['fleet_resizes']}"
                 if autoscale else ""))
    base = points[0]
    worst = points[-1]
    out = {"points": points,
           "headline_goodput_ratio":
               worst["goodput_items_per_sec"]
               / max(base["goodput_items_per_sec"], 1e-9),
           "headline_drop_frac": worst["drop_frac"],
           "headline_age_mean": worst["commit_age_mean"]}
    if base is not worst:
        print(f"at rate={worst['rate']:.2f}: goodput held at "
              f"{out['headline_goodput_ratio']:.2f}x fault-free, "
              f"drops={worst['drop_frac']:.1%}, commit age "
              f"{base['commit_age_mean']:.2f} -> "
              f"{worst['commit_age_mean']:.2f} ticks "
              f"(deadline bound {worst['commit_age_max']} <= D)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--samples", type=int, default=1536)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--rates", type=float, nargs="*",
                    default=[0.0, 0.05, 0.2],
                    help="injected per-(submit, shard) fault rates "
                         "(split evenly between timeouts and deaths); "
                         "0.0 is the baseline point")
    ap.add_argument("--autoscale", default="",
                    help="elastic fleet bounds 'LO:HI' (empty = fixed "
                         "2-worker pool)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (benchmarks/run.py --quick)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, bounded runtime")
    args = ap.parse_args()
    autoscale = None
    if args.autoscale:
        lo, _, hi = args.autoscale.partition(":")
        autoscale = (int(lo), int(hi))
    run(samples=args.samples, seed=args.seed, lanes=args.lanes,
        rates=tuple(args.rates), autoscale=autoscale,
        quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
