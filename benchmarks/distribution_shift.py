"""Paper Table 2 / Figure 9: robustness to input distribution shifts.

Reorders the IMDB stream by ascending length (semantic-complexity shift)
and by held-out category (the Comedy analogue: last third of the stream is
a category never seen before), then compares average accuracy across
budgets with the default order.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import EXPERTS, run_cascade, save_json

MUS = [6e-7, 3e-7, 1e-7]


def run(samples: int = 1500, seed: int = 0, quick: bool = False):
    experts = list(EXPERTS) if not quick else ["gpt-3.5-turbo"]
    mus = MUS if not quick else MUS[1:2]
    rows = []
    for expert in experts:
        accs = {}
        for order in ("default", "length", "category"):
            vals = []
            for mu in mus:
                m = run_cascade("imdb", expert, mu, samples=samples,
                                seed=seed, order=order)
                vals.append(m["accuracy"])
            accs[order] = float(np.mean(vals))
        row = {
            "expert": expert,
            "avg_accuracy_default": accs["default"],
            "avg_accuracy_length_shift": accs["length"],
            "length_shift_delta": accs["length"] - accs["default"],
            "avg_accuracy_category_shift": accs["category"],
            "category_shift_delta": accs["category"] - accs["default"],
            "mus": mus, "samples": samples,
        }
        rows.append(row)
        print(f"{expert}: default={accs['default']:.4f} "
              f"length={accs['length']:.4f} "
              f"(d={row['length_shift_delta']:+.4f}) "
              f"category={accs['category']:.4f} "
              f"(d={row['category_shift_delta']:+.4f})", flush=True)
    save_json("distribution_shift.json", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=1500)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.samples, args.seed, args.quick)


if __name__ == "__main__":
    main()
